"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; fixed cases pin the model's actual
shapes. assert_allclose against ref.py is the core correctness signal.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.interact import interact
from compile.kernels.matmul import matmul, vmem_bytes
from compile.kernels.mlp import mlp_layer

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------------
# matmul
# ----------------------------------------------------------------------


@hypothesis.given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    r = rng(seed)
    a = r.standard_normal((m, k), dtype=np.float32)
    b = r.standard_normal((k, n), dtype=np.float32)
    got = matmul(jnp.asarray(a), jnp.asarray(b))
    want = ref.matmul_ref(jnp.asarray(a), jnp.asarray(b))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(256, 128, 64), (128, 128, 128), (384, 256, 128)])
def test_matmul_mxu_shapes(shape):
    m, k, n = shape
    r = rng(0)
    a = r.standard_normal((m, k), dtype=np.float32)
    b = r.standard_normal((k, n), dtype=np.float32)
    got = matmul(jnp.asarray(a), jnp.asarray(b))
    assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 32), (128, 128, 128)])
def test_matmul_block_sweep_same_answer(blocks):
    bm, bn, bk = blocks
    r = rng(1)
    a = r.standard_normal((128, 128), dtype=np.float32)
    b = r.standard_normal((128, 128), dtype=np.float32)
    got = matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk)
    assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_bf16_inputs_accumulate_f32():
    r = rng(2)
    a = jnp.asarray(r.standard_normal((64, 64)), dtype=jnp.bfloat16)
    b = jnp.asarray(r.standard_normal((64, 64)), dtype=jnp.bfloat16)
    got = matmul(a, b)
    assert got.dtype == jnp.float32
    want = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_vmem_budget_default_blocks():
    # default 128³ tiling must fit VMEM with double-buffering headroom
    assert vmem_bytes() * 2 < 16 * 1024 * 1024


# ----------------------------------------------------------------------
# fused MLP layer
# ----------------------------------------------------------------------


@hypothesis.given(
    b=st.integers(1, 64),
    i=st.integers(1, 48),
    o=st.integers(1, 48),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_mlp_layer_matches_ref(b, i, o, relu, seed):
    r = rng(seed)
    x = r.standard_normal((b, i), dtype=np.float32)
    w = r.standard_normal((i, o), dtype=np.float32)
    bias = r.standard_normal(o, dtype=np.float32)
    got = mlp_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), relu)
    want = ref.mlp_layer_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias))
    if not relu:
        want = jnp.asarray(x) @ jnp.asarray(w) + jnp.asarray(bias)[None, :]
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_mlp_layer_gradients_match_jnp():
    r = rng(3)
    x = jnp.asarray(r.standard_normal((32, 16), dtype=np.float32))
    w = jnp.asarray(r.standard_normal((16, 8), dtype=np.float32))
    b = jnp.asarray(r.standard_normal(8, dtype=np.float32))

    def loss_pallas(x, w, b):
        return jnp.sum(mlp_layer(x, w, b, True) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.mlp_layer_ref(x, w, b) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
# pairwise interaction
# ----------------------------------------------------------------------


@hypothesis.given(
    b=st.integers(1, 16),
    f=st.integers(2, 12),
    d=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_interact_matches_ref(b, f, d, seed):
    r = rng(seed)
    e = r.standard_normal((b, f, d), dtype=np.float32)
    got = interact(jnp.asarray(e))
    want = ref.interact_ref(jnp.asarray(e))
    assert got.shape == (b, f * (f - 1) // 2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_interact_dlrm_shape():
    # the model's actual shape: 27 features (26 sparse + bottom), dim 16
    r = rng(4)
    e = r.standard_normal((8, 27, 16), dtype=np.float32)
    got = interact(jnp.asarray(e))
    assert got.shape == (8, 351)
    assert_allclose(np.asarray(got), np.asarray(ref.interact_ref(jnp.asarray(e))),
                    rtol=1e-4, atol=1e-4)


def test_interact_gradients_match_jnp():
    r = rng(5)
    e = jnp.asarray(r.standard_normal((4, 6, 8), dtype=np.float32))

    gp = jax.grad(lambda x: jnp.sum(interact(x) ** 2))(e)
    gr = jax.grad(lambda x: jnp.sum(ref.interact_ref(x) ** 2))(e)
    assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-4, atol=1e-4)


def test_interact_is_permutation_consistent():
    # swapping two feature rows permutes outputs but preserves the
    # multiset of pair products
    r = rng(6)
    e = r.standard_normal((1, 5, 7), dtype=np.float32)
    a = np.sort(np.asarray(interact(jnp.asarray(e)))[0])
    e2 = e[:, ::-1, :].copy()
    b = np.sort(np.asarray(interact(jnp.asarray(e2)))[0])
    assert_allclose(a, b, rtol=1e-4, atol=1e-4)
