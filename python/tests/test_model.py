"""L2 model tests: shapes, flatten/unflatten, training dynamics, AOT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.model import ModelConfig

jax.config.update("jax_platform_name", "cpu")


def tiny_cfg(**kw):
    base = dict(
        num_dense=4,
        num_sparse=5,
        vocab=50,
        embed_dim=8,
        bottom_mlp=(16, 8),
        top_mlp=(16, 1),
        batch=8,
        lr=0.1,
    )
    base.update(kw)
    return ModelConfig(**base)


def batch_for(cfg, seed=0):
    r = np.random.default_rng(seed)
    dense = jnp.asarray(r.standard_normal((cfg.batch, cfg.num_dense)), jnp.float32)
    sparse = jnp.asarray(
        r.integers(0, cfg.vocab, (cfg.batch, cfg.num_sparse)), jnp.int32
    )
    labels = jnp.asarray(r.integers(0, 2, cfg.batch), jnp.float32)
    return dense, sparse, labels


def test_param_count_matches_shapes():
    cfg = tiny_cfg()
    flat = model.init(cfg)
    assert flat.shape == (cfg.param_count(),)
    tensors = model.unflatten(cfg, flat)
    assert tensors[0].shape == (cfg.num_sparse, cfg.vocab, cfg.embed_dim)
    assert_allclose(np.asarray(model.flatten(tensors)), np.asarray(flat))


def test_init_is_deterministic():
    cfg = tiny_cfg()
    a, b = model.init(cfg), model.init(cfg)
    assert_allclose(np.asarray(a), np.asarray(b))


def test_forward_shapes_and_range():
    cfg = tiny_cfg()
    flat = model.init(cfg)
    dense, sparse, _ = batch_for(cfg)
    probs = model.forward_probs(cfg, flat, dense, sparse)
    assert probs.shape == (cfg.batch,)
    p = np.asarray(probs)
    assert np.all((p > 0) & (p < 1))


def test_loss_is_finite_and_near_ln2_at_init():
    cfg = tiny_cfg()
    flat = model.init(cfg)
    dense, sparse, labels = batch_for(cfg)
    loss = model.loss_fn(cfg, flat, dense, sparse, labels)
    assert np.isfinite(float(loss))
    # balanced random labels at small logits → loss ≈ ln 2
    assert 0.2 < float(loss) < 2.0


def test_train_step_reduces_loss_on_fixed_batch():
    cfg = tiny_cfg(lr=0.2)
    flat = model.init(cfg)
    dense, sparse, labels = batch_for(cfg, seed=1)
    first = None
    step = jax.jit(lambda f: model.train_step(cfg, f, dense, sparse, labels))
    for i in range(30):
        flat, loss = step(flat)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, f"loss {first} -> {float(loss)}"


def test_gradients_flow_to_all_parameter_groups():
    cfg = tiny_cfg()
    flat = model.init(cfg)
    dense, sparse, labels = batch_for(cfg, seed=2)
    grad = jax.grad(lambda f: model.loss_fn(cfg, f, dense, sparse, labels))(flat)
    tensors = model.unflatten(cfg, grad)
    # embeddings: only gathered rows get gradient, but some must
    assert float(jnp.abs(tensors[0]).sum()) > 0, "embedding grads are zero"
    for i, t in enumerate(tensors[1:], start=1):
        assert float(jnp.abs(t).sum()) > 0, f"param group {i} has zero grad"


def test_out_of_range_indices_are_clipped_not_crash():
    cfg = tiny_cfg()
    flat = model.init(cfg)
    dense, sparse, _ = batch_for(cfg)
    bad = sparse.at[0, 0].set(10**6)
    probs = model.forward_probs(cfg, flat, dense, bad)
    assert np.all(np.isfinite(np.asarray(probs)))


def test_shapes_assertion_on_bad_bottom_mlp():
    with pytest.raises(AssertionError):
        tiny_cfg(bottom_mlp=(16, 12)).shapes()  # must end at embed_dim


def test_default_config_is_criteo_shaped():
    cfg = ModelConfig()
    assert cfg.num_dense == 13 and cfg.num_sparse == 26
    assert cfg.interaction_dim() == 27 * 26 // 2
    # a real (if small) model: ~2.2M params at the default sizes
    assert cfg.param_count() > 2_000_000
