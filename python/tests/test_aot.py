"""AOT lowering tests: HLO text artifacts parse, contain no python-only
custom calls, and meta.txt matches the model."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.model import ModelConfig

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = ModelConfig(
        num_dense=4, num_sparse=5, vocab=50, embed_dim=8,
        bottom_mlp=(16, 8), top_mlp=(16, 1), batch=8,
    )
    aot.lower_all(cfg, out)
    return out, cfg


def test_all_artifacts_written(artifacts):
    out, _ = artifacts
    for name in ["init.hlo.txt", "train_step.hlo.txt", "forward.hlo.txt", "meta.txt"]:
        path = os.path.join(out, name)
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 0, name


def test_hlo_is_text_with_entry(artifacts):
    out, _ = artifacts
    for name in ["init.hlo.txt", "train_step.hlo.txt", "forward.hlo.txt"]:
        text = open(os.path.join(out, name)).read()
        assert "HloModule" in text, f"{name} is not HLO text"
        assert "ENTRY" in text
        # interpret-mode pallas must have lowered to plain HLO — a Mosaic
        # custom-call would be unloadable by the rust CPU client
        assert "mosaic" not in text.lower(), f"{name} contains a Mosaic call"


def test_meta_matches_model(artifacts):
    out, cfg = artifacts
    meta = {}
    for line in open(os.path.join(out, "meta.txt")):
        k, v = line.split("=")
        meta[k.strip()] = v.strip()
    assert int(meta["batch"]) == cfg.batch
    assert int(meta["param_count"]) == cfg.param_count()
    assert int(meta["vocab"]) == cfg.vocab


def test_lowered_train_step_matches_eager(artifacts):
    """The lowered computation must equal the eager one numerically."""
    out, cfg = artifacts
    import numpy as np

    flat = model.init(cfg)
    r = np.random.default_rng(0)
    dense = jnp.asarray(r.standard_normal((cfg.batch, cfg.num_dense)), jnp.float32)
    sparse = jnp.asarray(r.integers(0, cfg.vocab, (cfg.batch, cfg.num_sparse)), jnp.int32)
    labels = jnp.asarray(r.integers(0, 2, cfg.batch), jnp.float32)

    compiled = jax.jit(
        lambda f, d, s, l: model.train_step(cfg, f, d, s, l)
    ).lower(flat, dense, sparse, labels).compile()
    new_flat_c, loss_c = compiled(flat, dense, sparse, labels)
    new_flat_e, loss_e = model.train_step(cfg, flat, dense, sparse, labels)
    np.testing.assert_allclose(float(loss_c), float(loss_e), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(new_flat_c), np.asarray(new_flat_e), rtol=1e-4, atol=1e-5
    )
