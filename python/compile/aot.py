"""AOT entry point: lower the DLRM functions to HLO *text* artifacts.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):
  init.hlo.txt        ()                                   -> (flat_params,)
  train_step.hlo.txt  (flat, dense, sparse, labels)        -> (flat, loss)
  forward.hlo.txt     (flat, dense, sparse)                -> (probs,)
  meta.txt            key=value shapes for the rust driver
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(cfg: model.ModelConfig, out_dir: str, suffix: str = "") -> None:
    os.makedirs(out_dir, exist_ok=True)
    p = cfg.param_count()
    flat = jax.ShapeDtypeStruct((p,), jnp.float32)
    dense = jax.ShapeDtypeStruct((cfg.batch, cfg.num_dense), jnp.float32)
    sparse = jax.ShapeDtypeStruct((cfg.batch, cfg.num_sparse), jnp.int32)
    labels = jax.ShapeDtypeStruct((cfg.batch,), jnp.float32)

    jobs = {
        f"init{suffix}.hlo.txt": jax.jit(lambda: (model.init(cfg),)).lower(),
        f"train_step{suffix}.hlo.txt": jax.jit(
            lambda f, d, s, l: model.train_step(cfg, f, d, s, l)
        ).lower(flat, dense, sparse, labels),
        f"forward{suffix}.hlo.txt": jax.jit(
            lambda f, d, s: (model.forward_probs(cfg, f, d, s),)
        ).lower(flat, dense, sparse),
    }
    for name, lowered in jobs.items():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, name)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {len(text):>9} chars  {path}")

    meta = {
        "batch": cfg.batch,
        "num_dense": cfg.num_dense,
        "num_sparse": cfg.num_sparse,
        "embed_dim": cfg.embed_dim,
        "vocab": cfg.vocab,
        "param_count": p,
        "lr": cfg.lr,
    }
    with open(os.path.join(out_dir, f"meta{suffix}.txt"), "w") as fh:
        for k, v in meta.items():
            fh.write(f"{k} = {v}\n")
    print(f"model has {p} parameters; batch {cfg.batch}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=5000)
    ap.add_argument("--embed-dim", type=int, default=16)
    ap.add_argument(
        "--batch-variants",
        type=int,
        nargs="*",
        default=[],
        help="additionally lower train_step at these batch sizes "
        "(suffix _bN) for the Fig. 1 batch-size sweep",
    )
    args = ap.parse_args()
    cfg = model.ModelConfig(
        batch=args.batch, vocab=args.vocab, embed_dim=args.embed_dim
    )
    lower_all(cfg, args.out_dir)
    for b in args.batch_variants:
        vcfg = model.ModelConfig(
            batch=b, vocab=args.vocab, embed_dim=args.embed_dim
        )
        lower_all(vcfg, args.out_dir, suffix=f"_b{b}")


if __name__ == "__main__":
    main()
