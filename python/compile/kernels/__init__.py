"""Layer-1 Pallas kernels (interpret=True on CPU) + pure-jnp oracles."""

from . import interact, matmul, mlp, ref  # noqa: F401
