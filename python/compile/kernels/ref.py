"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: `pytest python/tests` asserts the
Pallas kernels (run with interpret=True on CPU) match these references to
float tolerance across shape/dtype sweeps.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain matrix multiply: (m, k) @ (k, n) -> (m, n), f32 accumulate."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def mlp_layer_ref(x, w, b):
    """Fused dense layer: relu(x @ w + b)."""
    return jnp.maximum(matmul_ref(x, w) + b[None, :], 0.0)


def interact_ref(emb):
    """DLRM pairwise dot-product feature interaction.

    emb: (batch, features, dim) stacked embedding vectors (bottom-MLP
    output is stacked in as one more "feature" by the caller).
    Returns (batch, features*(features-1)//2): the strictly-upper-triangle
    of the per-sample Gram matrix emb @ emb^T — the interaction layer of
    Naumov et al.'s DLRM (paper §2.2 reference [53]).
    """
    gram = jnp.einsum("bfd,bgd->bfg", emb, emb)
    f = emb.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    return gram[:, iu, ju]
