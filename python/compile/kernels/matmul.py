"""Pallas tiled matmul — the MXU-shaped compute primitive.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper's
accelerator is an FPGA dataflow for *preprocessing*; the ML *consumer*
(DLRM) is where the dense compute lives, so the Pallas layer implements
the consumer's hot-spot. The kernel tiles for TPU VMEM: block sizes are
multiples of the (8, 128) f32 tile and the MXU's 128×128 systolic shape,
with the K dimension innermost in the grid so partial products accumulate
in the revisited output block. On CPU we run interpret=True (real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run); the
BlockSpec structure is what DESIGN.md §Perf's VMEM/MXU estimate is
computed from.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Grid = (m_tiles, n_tiles, k_steps), K innermost.

    The output BlockSpec maps every k step to the same (i, j) block, so
    o_ref acts as the accumulator held in VMEM across the K loop — the
    standard MXU accumulation pattern without a scratch buffer.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim, target):
    """Largest divisor of `dim` that is <= target (keeps shapes static)."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm=128, bn=128, bk=128):
    """(m, k) @ (k, n) -> (m, n) via a VMEM-tiled Pallas kernel.

    Block sizes adapt to small dims so the kernel is total; for MXU-sized
    inputs they stay at the 128×128 systolic shape. VMEM footprint per
    grid step = (bm*bk + bk*bn + bm*bn) * 4 bytes — 192 KiB at the
    default blocks, comfortably under the ~16 MiB VMEM budget, leaving
    room for double-buffering.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))


def vmem_bytes(bm=128, bn=128, bk=128):
    """Modeled VMEM bytes per grid step (for DESIGN.md §Perf)."""
    return 4 * (bm * bk + bk * bn + bm * bn)
