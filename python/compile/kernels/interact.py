"""DLRM pairwise dot-product interaction as a Pallas kernel.

Computes, per sample, the Gram matrix of the stacked embedding vectors
and extracts its strict upper triangle — the feature-interaction layer
that dominates DLRM's dense compute after the embedding gathers.

Kernel shape: grid over the batch; each step loads one sample's
(features, dim) block into VMEM, does a single (F, D) @ (D, F) MXU
contraction, and writes the flattened triu. F and D are tiny (27, 16 in
the default model) so a whole sample fits in a fraction of VMEM; the
batch grid gives the pipeline its parallelism. A custom VJP implements
the bilinear backward dE = (G + Gᵀ) E with the same contraction shape.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _triu_pairs(f):
    iu, ju = np.triu_indices(f, k=1)
    return iu.astype(np.int32), ju.astype(np.int32)


def _gram_kernel(e_ref, o_ref):
    """One sample per grid step: (F, D) @ (D, F) on the MXU."""
    e = e_ref[0]  # (F, D)
    o_ref[0] = jnp.dot(e, e.T, preferred_element_type=jnp.float32)


@jax.custom_vjp
def interact(emb):
    """(B, F, D) -> (B, F*(F-1)//2) pairwise dot interactions."""
    return _interact_forward(emb)


def _interact_forward(emb):
    b, f, _d = emb.shape
    # The Pallas kernel computes the batched Gram matrix (the MXU
    # contraction — the actual compute); the strict-triu extraction is a
    # static gather that XLA fuses into the surrounding graph. Index
    # arrays cannot be captured inside a Pallas kernel body, which is why
    # the extraction lives outside.
    gram = pl.pallas_call(
        _gram_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, f, _d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, f, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, f), jnp.float32),
        interpret=True,
    )(emb.astype(jnp.float32))
    iu, ju = _triu_pairs(f)
    return gram[:, iu, ju]


def _interact_fwd(emb):
    return _interact_forward(emb), emb


def _interact_bwd(emb, g):
    b, f, d = emb.shape
    iu, ju = _triu_pairs(f)
    # scatter the flat grad back into a symmetric (F, F) matrix
    gram_grad = jnp.zeros((b, f, f), jnp.float32)
    gram_grad = gram_grad.at[:, iu, ju].set(g)
    sym = gram_grad + jnp.swapaxes(gram_grad, 1, 2)
    # d/dE of tr(Gᵀ E Eᵀ) pattern: dE = (G + Gᵀ) E
    d_emb = jnp.einsum("bfg,bgd->bfd", sym, emb)
    return (d_emb,)


interact.defvjp(_interact_fwd, _interact_bwd)
