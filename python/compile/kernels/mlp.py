"""Fused dense layer as a Pallas kernel: relu(x @ w + b).

The bias-add and ReLU fuse into the final K step of the tiled matmul so
the activation never round-trips to HBM — the standard epilogue-fusion
the MXU pipeline wants. A custom VJP routes the backward pass through the
same Pallas matmul kernel (Pallas calls have no automatic transpose
rule), so fwd AND bwd both exercise the L1 kernels when the train step is
lowered.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm


def _mlp_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps, relu):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...][None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


def _mlp_forward(x, w, b, relu, bm, bn, bk):
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = mm._pick_block(m, bm), mm._pick_block(n, bn), mm._pick_block(k, bk)
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_mlp_kernel, k_steps=k_steps, relu=relu),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def mlp_layer(x, w, b, relu=True):
    """relu(x @ w + b) (or linear when relu=False), Pallas-fused."""
    return _mlp_forward(x, w, b, relu, 128, 128, 128)


def _mlp_fwd(x, w, b, relu):
    y = _mlp_forward(x, w, b, relu, 128, 128, 128)
    return y, (x, w, y)


def _mlp_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0).astype(g.dtype)
    # backward matmuls through the same Pallas kernel
    dx = mm.matmul(g, w.T)
    dw = mm.matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


mlp_layer.defvjp(_mlp_fwd, _mlp_bwd)
