"""Layer-2: the DLRM training consumer, in JAX, calling the L1 kernels.

Architecture (Naumov et al., the model the paper's pipeline feeds):

  dense (B, ND) ──bottom MLP──▶ (B, D) ─┐
  sparse (B, NS) ──embedding gather──▶ (B, NS, D) ─┴─ stack (B, NS+1, D)
      ─▶ pairwise dot interaction (L1 kernel) ─▶ (B, P)
      ─▶ concat with bottom output ─▶ top MLP ─▶ logit (B,)
  loss = sigmoid BCE; optimizer = SGD.

Parameters cross the rust↔XLA boundary as ONE flat f32 vector; this
module owns the (static) unflatten schema. Everything here is build-time
only — `aot.py` lowers `init` / `train_step` / `forward` to HLO text and
the rust runtime executes them.
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.interact import interact
from .kernels.mlp import mlp_layer


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    num_dense: int = 13
    num_sparse: int = 26
    vocab: int = 5000
    embed_dim: int = 16
    bottom_mlp: Tuple[int, ...] = (64, 16)
    top_mlp: Tuple[int, ...] = (64, 1)
    batch: int = 256
    lr: float = 0.05
    seed: int = 0

    def interaction_dim(self) -> int:
        f = self.num_sparse + 1
        return f * (f - 1) // 2

    def shapes(self) -> List[Tuple[int, ...]]:
        """Static parameter shapes, in flat-vector order."""
        shapes: List[Tuple[int, ...]] = [(self.num_sparse, self.vocab, self.embed_dim)]
        d_in = self.num_dense
        for width in self.bottom_mlp:
            shapes.append((d_in, width))
            shapes.append((width,))
            d_in = width
        assert d_in == self.embed_dim, (
            "bottom MLP must end at embed_dim so the dense vector stacks "
            f"with the embeddings ({d_in} != {self.embed_dim})"
        )
        t_in = self.interaction_dim() + self.embed_dim
        for width in self.top_mlp:
            shapes.append((t_in, width))
            shapes.append((width,))
            t_in = width
        assert t_in == 1, "top MLP must end at a single logit"
        return shapes

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for s in self.shapes())


def unflatten(cfg: ModelConfig, flat):
    """Split the flat parameter vector into the model's tensors."""
    out, at = [], 0
    for s in cfg.shapes():
        n = 1
        for d in s:
            n *= d
        out.append(flat[at : at + n].reshape(s))
        at += n
    assert at == flat.shape[0], f"flat vector has {flat.shape[0]} != {at} params"
    return out


def flatten(params) -> jnp.ndarray:
    return jnp.concatenate([p.reshape(-1) for p in params])


def init(cfg: ModelConfig) -> jnp.ndarray:
    """Deterministic initialization, returned as the flat vector."""
    key = jax.random.PRNGKey(cfg.seed)
    parts = []
    for s in cfg.shapes():
        key, sub = jax.random.split(key)
        if len(s) == 1:
            parts.append(jnp.zeros(s, jnp.float32))  # biases
        else:
            fan_in = s[-2] if len(s) >= 2 else s[0]
            scale = (2.0 / fan_in) ** 0.5
            parts.append(scale * jax.random.normal(sub, s, jnp.float32))
    return flatten(parts)


def _mlp(x, tensors, start, widths, final_linear=False):
    """Run an MLP through the fused Pallas layer; returns (y, next_idx)."""
    i = start
    for li, _ in enumerate(widths):
        w, b = tensors[i], tensors[i + 1]
        relu = not (final_linear and li == len(widths) - 1)
        x = mlp_layer(x, w, b, relu)
        i += 2
    return x, i


def forward_logits(cfg: ModelConfig, flat, dense, sparse):
    """(B, ND) f32, (B, NS) i32 -> (B,) logits."""
    tensors = unflatten(cfg, flat)
    tables = tensors[0]  # (NS, V, D)
    # bottom MLP over the log-transformed dense features
    bot, at = _mlp(dense, tensors, 1, cfg.bottom_mlp)
    # embedding gathers: per-column table lookup (XLA gather — memory
    # bound, stays in jnp)
    idx = jnp.clip(sparse, 0, cfg.vocab - 1)
    emb = _gather(tables, idx)  # (B, NS, D)
    stacked = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, NS+1, D)
    inter = interact(stacked)  # L1 kernel
    top_in = jnp.concatenate([inter, bot], axis=1)
    logits, _ = _mlp(top_in, tensors, at, cfg.top_mlp, final_linear=True)
    return logits[:, 0]


def _gather(tables, idx):
    """tables (NS, V, D), idx (B, NS) -> (B, NS, D)."""
    def per_col(table, col_idx):
        return table[col_idx]  # (B, D)

    emb = jax.vmap(per_col, in_axes=(0, 1), out_axes=1)(tables, idx)
    return emb  # (B, NS, D)


def loss_fn(cfg: ModelConfig, flat, dense, sparse, labels):
    logits = forward_logits(cfg, flat, dense, sparse)
    # numerically-stable sigmoid BCE
    z = jnp.clip(logits, -30.0, 30.0)
    loss = jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return jnp.mean(loss)


def train_step(cfg: ModelConfig, flat, dense, sparse, labels):
    """One SGD step. Returns (new_flat, loss)."""
    loss, grad = jax.value_and_grad(lambda p: loss_fn(cfg, p, dense, sparse, labels))(flat)
    return flat - cfg.lr * grad, loss


def forward_probs(cfg: ModelConfig, flat, dense, sparse):
    return jax.nn.sigmoid(forward_logits(cfg, flat, dense, sparse))
