//! PJRT runtime: load AOT-compiled HLO text (produced once by
//! `python/compile/aot.py`) and execute it from rust. Python is never on
//! this path — the interchange format is HLO *text* (not serialized
//! protos: jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids).

use std::path::{Path, PathBuf};

use crate::Result;

/// A PJRT CPU client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create the CPU PJRT client. `artifacts_dir` is where `make
    /// artifacts` wrote the `*.hlo.txt` files.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact by file name (e.g.
    /// `"train_step.hlo.txt"`).
    pub fn load(&self, name: &str) -> Result<LoadedFn> {
        let path = self.artifacts_dir.join(name);
        anyhow::ensure!(
            path.exists(),
            "artifact {} not found — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(LoadedFn { exe, name: name.to_string() })
    }
}

/// One compiled executable (a jax function lowered with
/// `return_tuple=True`, so outputs always come back as a tuple).
pub struct LoadedFn {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl LoadedFn {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow::anyhow!("{} returned no buffers", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {} output: {e:?}", self.name))?;
        out.to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {} output: {e:?}", self.name))
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Helpers for building literals from rust slices.
pub mod lit {
    use crate::Result;

    /// f32 tensor of the given shape.
    pub fn f32_tensor(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    /// i32 tensor of the given shape.
    pub fn i32_tensor(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    /// Extract an f32 scalar.
    pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
        l.get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("scalar read: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are skipped
    /// (not failed) when artifacts are missing so `cargo test` stays
    /// green on a fresh checkout.
    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("train_step.hlo.txt").exists().then_some(dir)
    }

    #[test]
    fn runtime_creates_cpu_client() {
        let rt = Runtime::new(Path::new("/nonexistent")).unwrap();
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = Runtime::new(Path::new("/nonexistent")).unwrap();
        let err = match rt.load("nope.hlo.txt") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("loading a missing artifact must fail"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn lit_shape_checks() {
        assert!(lit::f32_tensor(&[1.0, 2.0], &[3]).is_err());
        let t = lit::f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(t.element_count(), 4);
    }

    #[test]
    fn loads_and_runs_train_step_artifact_if_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::new(&dir).unwrap();
        let f = rt.load("train_step.hlo.txt").unwrap();
        assert_eq!(f.name(), "train_step.hlo.txt");
    }
}
