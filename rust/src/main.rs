//! `piper` — the launcher CLI.
//!
//! Subcommands:
//!   gen-data    generate a synthetic Criteo-format dataset file
//!   preprocess  run one backend over a dataset and print stage timings
//!   compare     run the Fig. 9 style CPU/GPU/PIPER comparison
//!   serve       run a network-attached PIPER worker (TCP)
//!   submit      stream a dataset to a worker and collect results
//!   freeze      build a frozen vocabulary artifact from a dataset
//!   request     send a small batch to a serving worker (online mode)
//!   train       end-to-end: preprocess + train the DLRM via PJRT
//!
//! Every knob is a `key=value` override (see `--help`), optionally layered
//! on a `--config FILE`.

use std::path::Path;

use piper::accel::{InputFormat, Mode};
use piper::config::Config;
use piper::coordinator::{self, Backend, Experiment};
use piper::cpu_baseline::ConfigKind;
use piper::data::{binary, synth::SynthConfig, utf8, Schema, SynthDataset};
use piper::net::{self, protocol::Job, stream::WireFormat};
use piper::ops::{Modulus, PipelineSpec, VocabArtifact};
use piper::pipeline::{FileSource, MissPolicy, Source as _};
use piper::report::{fmt_duration, fmt_rows_per_sec, fmt_speedup, fmt_tagged, Table};
use piper::Result;

const HELP: &str = "\
piper — simulated PIPER accelerator for tabular ML preprocessing

USAGE: piper <COMMAND> [key=value]... [--config FILE]

COMMANDS:
  gen-data    rows=100000 format=utf8|binary out=PATH seed=N
  preprocess  input=PATH format=utf8|binary backend=cpu|gpu|piper-local|piper-host-decode|piper-net
              vocab=5000 threads=8 cpu_config=1|2|3 chunk_rows=65536 spec='modulus:5000|genvocab|...'
              strategy=fused|two-pass (default: fused when the backend supports it)
              decode_threads=N (default: one per core; 1 = sequential decode)
              pipeline_depth=N (fused in-flight chunk window, default 2; 1 = sequential)
              save_artifact=PATH (also freeze the vocabularies to an artifact)
              on_error=zero|skip|quarantine|fail (malformed-row policy, default zero)
              max_errors=N|P% (error budget: absolute count or percentage; default unlimited)
              quarantine=PATH (replayable side file; implies on_error=quarantine)
              error_details=N (defect offsets kept for the summary, default 64)
              replay=PATH (re-ingest a quarantine side file instead of input=)
              metrics=PATH (write a JSON run manifest: stage timings, rows, containment)
  compare     rows=20000 vocab=5000 format=utf8|binary
  serve       addr=127.0.0.1:7700 jobs=1 (jobs=0: accept connections forever)
  submit      input=PATH addr=127.0.0.1:7700 format=utf8|binary vocab=5000 spec='...'
              strategy=fused|two-pass timeout=30 deadline=0 retries=2 backoff_ms=50
              pipeline_depth=N (leader read-ahead window, default 1)
              on_error=... max_errors=... (containment counters come back per worker)
              metrics=PATH (write a JSON run manifest, incl. per-worker breakdown)
              window=N (cluster: splits in flight across the pool; 0 = one per worker)
              splits=N (cluster: scheduling granularity, default one per worker)
              (addr=A,B,... runs the job on the preprocessing service — splits
              scheduled over the pool, vocabularies shard-owned, fused single-pass)
  freeze      input=PATH format=utf8|binary out=vocab.artifact vocab=5000 spec='...'
              dense=13 sparse=26 chunk=1048576
  request     artifact=PATH input=PATH addr=127.0.0.1:7700 format=utf8|binary
              policy=sentinel|default:N|reject queue_depth=32
              timeout=30 retries=2 backoff_ms=50
  train       input=PATH format=utf8 vocab=5000 steps=100 artifacts=artifacts
  help        print this message

spec= accepts per-column operator programs — `;`-separated rules of the
form `sparse[*]: modulus:5000|genvocab|applyvocab`, with selectors
sparse[*], sparse[3], sparse[0..4] (same for dense) and the dense ops
neg2zero, log, clip:lo:hi, bucketize:b1:b2:... Later rules override
earlier ones; a flat op list (no selector) means every column.
vocab=N is sugar for the uniform DLRM preset at modulus N.

preprocess and submit stream the input file in bounded chunks — the
dataset is never resident in memory. Under the fused strategy (the
default) vocabulary generation and application run in ONE decode pass;
strategy=two-pass reproduces the classic two-loop baseline with its
rewind. pipeline_depth= sizes the fused stage pipeline's in-flight
chunk window: at depth >= 2 chunk N+1's decode and stateless column
work overlap chunk N's sequential vocabulary scan (output stays
bit-identical — the vocab stage runs strictly in chunk order), and the
report's stage split shows the reclaimed decode idle. For submit it is
the leader's source read-ahead window: disk reads overlap the network
send.

timeout= is the per-socket read/write deadline in seconds (0 disables
it), deadline= a wall-clock budget for the whole job in seconds (0 =
unbounded), retries= how often a failed split (submit) or overloaded
request (request) is re-dispatched, and backoff_ms= the base of the
capped exponential backoff between attempts. A cluster submit runs the
disaggregated preprocessing service: the input is cut into splits, each
vocabulary column is owned by one worker, and every split runs the
fused single-pass scan — no global merge barrier. Failed splits retry
on surviving workers and the retry/fault counts are reported.

metrics=PATH writes a machine-readable JSON manifest next to the human
table: spec/schema hashes, rows in/out, per-stage durations, the
containment counters, and (cluster submit) a per-worker breakdown of
splits won and decode/stateless/vocab time.

on_error= decides what happens to a malformed row (illegal bytes, wrong
field count, numeric overflow, oversized field): zero keeps the row
with defective fields zero-filled (the historical behavior), skip drops
it, quarantine drops it AND appends its raw bytes to the quarantine=
side file (re-ingestable later via replay=), fail aborts on the first
defect naming its byte offset. max_errors= bounds how many rows may be
contained before the run aborts with a typed budget error — an absolute
count (max_errors=100) or a rate (max_errors=0.1%). Over the wire
(submit) the counters come back per worker and are summed; quarantined
raw bytes never cross the wire.

freeze builds a versioned, checksummed vocabulary artifact from a
training dataset; request sends one small batch against a worker
serving that artifact (start it with `serve jobs=0`) and prints the
response plus the worker's p50/p99 latency report. policy= decides
what happens to vocabulary misses at serving time: sentinel keeps the
u32::MAX marker, default:N rewrites misses to index N, reject drops
the whole row.
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_args() -> Result<(String, Config)> {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = args.collect();
    let mut cfg = Config::new();
    let mut i = 0;
    while i < rest.len() {
        if rest[i] == "--config" {
            let path = rest
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
            let file = Config::from_file(Path::new(path))?;
            for k in file.keys().map(str::to_string).collect::<Vec<_>>() {
                if cfg.get(&k).is_none() {
                    if let Some(v) = file.get(&k) {
                        cfg.set(&k, v);
                    }
                }
            }
            i += 2;
        } else {
            cfg.apply_overrides([rest[i].as_str()])?;
            i += 1;
        }
    }
    Ok((cmd, cfg))
}

fn run() -> Result<()> {
    let (cmd, cfg) = parse_args()?;
    match cmd.as_str() {
        "gen-data" => cmd_gen_data(&cfg),
        "preprocess" => cmd_preprocess(&cfg),
        "compare" => cmd_compare(&cfg),
        "serve" => cmd_serve(&cfg),
        "submit" => cmd_submit(&cfg),
        "freeze" => cmd_freeze(&cfg),
        "request" => cmd_request(&cfg),
        "train" => cmd_train(&cfg),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

fn modulus_of(cfg: &Config) -> Result<Modulus> {
    Ok(Modulus::new(cfg.get_usize("vocab", 5000)? as u32))
}

/// Fault-tolerance knobs shared by `submit` and `request`: `timeout=`
/// (per-socket I/O deadline, seconds; 0 disables), `deadline=` (whole-
/// job wall-clock budget, seconds; 0 = unbounded), `retries=`,
/// `backoff_ms=` (base of the capped exponential backoff).
fn net_config_of(cfg: &Config) -> Result<net::NetConfig> {
    let defaults = net::NetConfig::default();
    let io = cfg.get_u64("timeout", 30)?;
    let deadline = cfg.get_u64("deadline", 0)?;
    Ok(net::NetConfig {
        io_timeout: (io > 0).then(|| std::time::Duration::from_secs(io)),
        job_deadline: (deadline > 0).then(|| std::time::Duration::from_secs(deadline)),
        retries: cfg.get_usize("retries", defaults.retries as usize)? as u32,
        backoff: std::time::Duration::from_millis(cfg.get_u64("backoff_ms", 50)?),
        backoff_cap: defaults.backoff_cap,
        leader_window: defaults.leader_window,
    })
}

fn format_of(cfg: &Config) -> Result<InputFormat> {
    match cfg.get_or("format", "utf8") {
        "utf8" => Ok(InputFormat::Utf8),
        "binary" => Ok(InputFormat::Binary),
        other => anyhow::bail!("unknown format `{other}`"),
    }
}

/// Whole-file read — only the pjrt `train` path still wants the buffer
/// resident (the trainer slices minibatches from it); everything else
/// streams via [`FileSource`].
#[cfg(feature = "pjrt")]
fn read_input(cfg: &Config) -> Result<Vec<u8>> {
    let path = cfg
        .get("input")
        .ok_or_else(|| anyhow::anyhow!("missing input=PATH"))?;
    Ok(std::fs::read(path)?)
}

fn cmd_gen_data(cfg: &Config) -> Result<()> {
    let rows = cfg.get_usize("rows", 100_000)?;
    let out = cfg.get_or("out", "dataset.txt");
    let mut scfg = SynthConfig::preset(cfg.get_or("dataset", "criteo"), rows)?;
    scfg.seed = cfg.get_u64("seed", scfg.seed)?;
    if cfg.get("dense").is_some() || cfg.get("sparse").is_some() {
        scfg.schema = Schema::new(
            cfg.get_usize("dense", scfg.schema.num_dense)?,
            cfg.get_usize("sparse", scfg.schema.num_sparse)?,
        );
    }
    let ds = SynthDataset::generate(scfg);
    match format_of(cfg)? {
        InputFormat::Utf8 => utf8::write_file(&ds, Path::new(out))?,
        InputFormat::Binary => binary::write_file(&ds, Path::new(out))?,
    }
    println!("wrote {} rows to {out}", ds.num_rows());
    Ok(())
}

fn backend_of(cfg: &Config) -> Result<Backend> {
    let threads = cfg.get_usize("threads", 8)?;
    let kind = match cfg.get_usize("cpu_config", 1)? {
        1 => ConfigKind::I,
        2 => ConfigKind::II,
        3 => ConfigKind::III,
        n => anyhow::bail!("cpu_config must be 1..3, got {n}"),
    };
    Ok(match cfg.get_or("backend", "piper-net") {
        "cpu" => Backend::Cpu { kind, threads },
        "gpu" => Backend::Gpu,
        "piper-local" => Backend::Piper { mode: Mode::LocalDecodeInKernel },
        "piper-host-decode" => Backend::Piper { mode: Mode::LocalDecodeInHost },
        "piper-net" => Backend::Piper { mode: Mode::Network },
        other => anyhow::bail!("unknown backend `{other}`"),
    })
}

fn cmd_preprocess(cfg: &Config) -> Result<()> {
    let replay = cfg.get("replay");
    let path = match (cfg.get("input"), replay) {
        (Some(p), _) => Some(p),
        (None, Some(_)) => None,
        (None, None) => anyhow::bail!("missing input=PATH (or replay=QUARANTINE)"),
    };
    let backend = backend_of(cfg)?;
    // A replayed quarantine file carries its own input format.
    let mut replay_source = match replay {
        Some(q) => Some(piper::pipeline::QuarantineSource::open(Path::new(q))?),
        None => None,
    };
    let format = match &replay_source {
        Some(src) => src.format(),
        None => format_of(cfg)?,
    };
    let modulus = modulus_of(cfg)?;

    // Plan once (spec + capability checks + strategy selection), then
    // stream the file through the engine in bounded chunks.
    let mut builder = piper::pipeline::PipelineBuilder::new()
        .input(format)
        .chunk_rows(cfg.get_usize("chunk_rows", 64 * 1024)?)
        .executor(backend.executor());
    builder = match cfg.get("spec") {
        Some(spec) => builder.spec_str(spec)?,
        None => builder.spec(piper::ops::PipelineSpec::dlrm(modulus.range)),
    };
    if let Some(s) = cfg.get("strategy") {
        builder = builder.strategy(piper::pipeline::ExecStrategy::parse(s)?);
    }
    if cfg.get("decode_threads").is_some() {
        builder = builder.decode_threads(cfg.get_usize("decode_threads", 1)?);
    }
    if cfg.get("pipeline_depth").is_some() {
        builder = builder.pipeline_depth(cfg.get_usize("pipeline_depth", 2)?);
    }
    if let Some(p) = cfg.get("on_error") {
        builder = builder.on_error(piper::decode::ErrorPolicy::parse(p)?);
    }
    if let Some(b) = cfg.get("max_errors") {
        builder = builder.error_budget(piper::decode::ErrorBudget::parse(b)?);
    }
    if cfg.get("error_details").is_some() {
        builder = builder.error_details(cfg.get_usize("error_details", 64)?);
    }
    if let Some(q) = cfg.get("quarantine") {
        builder = builder.quarantine(q);
    }
    let pipeline = builder.build()?;
    let mut sink = piper::pipeline::CountSink::new();
    let report = match replay_source.as_mut() {
        Some(source) => pipeline.run(source, &mut sink)?,
        None => {
            let mut source =
                FileSource::open(Path::new(path.expect("input= checked above")), format)?;
            pipeline.run(&mut source, &mut sink)?
        }
    };

    let mut t = Table::new(
        "preprocess",
        &["backend", "strategy", "passes", "rows", "chunks", "vocab entries", "e2e", "rows/s"],
    );
    t.row(&[
        report.executor.clone(),
        report.strategy.name().to_string(),
        report.decode_passes.to_string(),
        report.rows.to_string(),
        report.chunks.to_string(),
        report.vocab_entries.to_string(),
        fmt_tagged(report.e2e, report.tag),
        fmt_rows_per_sec(report.e2e_rows_per_sec()),
    ]);
    t.note("streamed with bounded memory; one pipeline serves many submissions");
    t.note(&format!(
        "executor time split: observe {} / process {} [meas]",
        piper::report::fmt_duration(report.observe_time),
        piper::report::fmt_duration(report.process_time),
    ));
    t.note(&format!(
        "decode: {} across {} decode thread(s) [meas]",
        piper::report::fmt_duration(report.decode_time),
        report.decode_threads,
    ));
    if report.pipeline_depth > 1 {
        t.note(&format!(
            "stage pipeline: depth {} — stateless busy {}, vocab busy {}, \
             vocab wait {} [meas]",
            report.pipeline_depth,
            piper::report::fmt_duration(report.stage_stateless_time),
            piper::report::fmt_duration(report.observe_time),
            piper::report::fmt_duration(report.vocab_wait_time),
        ));
    } else {
        t.note("stage pipeline: depth 1 (sequential chunk-at-a-time driving)");
    }
    if report.illegal_bytes > 0 {
        t.note(&format!(
            "WARNING: {} illegal input byte(s) in the stream",
            report.illegal_bytes,
        ));
    }
    if report.row_errors.total > 0 {
        t.note(&format!(
            "WARNING: {} malformed row(s) contained — {} skipped, {} quarantined, \
             rest zero-filled",
            report.row_errors.total,
            report.rows_skipped,
            report.rows_quarantined,
        ));
        let first: Vec<String> = report
            .row_errors
            .recorded
            .iter()
            .take(8)
            .map(|e| format!("row {} ({}) at byte {}", e.row, e.kind.name(), e.offset))
            .collect();
        t.note(&format!("first defect(s): {}", first.join("; ")));
    }
    if let Some(qpath) = &report.quarantine.path {
        t.note(&format!(
            "{} quarantined row(s) written to {} — re-ingest with replay={}",
            report.quarantine.rows,
            qpath.display(),
            qpath.display(),
        ));
    }
    t.print();

    if let Some(out) = cfg.get("metrics") {
        write_preprocess_metrics(Path::new(out), &report, &spec_of(cfg)?)?;
        println!("metrics manifest written to {out}");
    }

    // Optionally freeze the run's vocabularies for online serving. The
    // artifact pass re-streams the file through GenVocab only — same
    // spec, same schema, so the keys match what this run built.
    if let Some(out) = cfg.get("save_artifact") {
        let path =
            path.ok_or_else(|| anyhow::anyhow!("save_artifact= needs input=PATH, not replay="))?;
        let spec = spec_of(cfg)?;
        let artifact =
            build_artifact(Path::new(path), format, &spec, Schema::CRITEO, 1 << 20)?;
        artifact.save(Path::new(out))?;
        println!(
            "froze {} vocabulary entries to {out} (spec {:#018x}, schema {:#018x})",
            artifact.total_entries(),
            artifact.spec_hash(),
            artifact.schema_hash(),
        );
    }
    Ok(())
}

/// The spec every command shares: an explicit `spec=` program, or the
/// uniform DLRM preset at `vocab=` range.
fn spec_of(cfg: &Config) -> Result<PipelineSpec> {
    Ok(match cfg.get("spec") {
        Some(s) => PipelineSpec::parse(s)?,
        None => PipelineSpec::dlrm(modulus_of(cfg)?.range),
    })
}

/// Stream `path` through a GenVocab-only pass and freeze the resulting
/// vocabularies into a checksummed [`VocabArtifact`].
fn build_artifact(
    path: &Path,
    input: InputFormat,
    spec: &PipelineSpec,
    schema: Schema,
    chunk: usize,
) -> Result<VocabArtifact> {
    let wire = match input {
        InputFormat::Utf8 => WireFormat::Utf8,
        InputFormat::Binary => WireFormat::Binary,
    };
    let decode = piper::pipeline::DecodeOptions {
        threads: piper::decode::shard::default_threads(),
        swar: true,
        errors: Default::default(),
    };
    let mut sp = net::StreamingPreprocessor::with_decode_options(spec, schema, wire, decode)?;
    let mut source = FileSource::open(path, input)?;
    let mut buf = Vec::new();
    while source.next_chunk(chunk.max(1), &mut buf)? {
        sp.pass1_chunk(&buf)?;
    }
    sp.pass1_end()?;
    VocabArtifact::new(spec.clone(), schema, sp.export_vocabs())
}

fn cmd_freeze(cfg: &Config) -> Result<()> {
    let path = cfg
        .get("input")
        .ok_or_else(|| anyhow::anyhow!("missing input=PATH"))?;
    let out = cfg.get_or("out", "vocab.artifact");
    let input = format_of(cfg)?;
    let schema = Schema::new(
        cfg.get_usize("dense", Schema::CRITEO.num_dense)?,
        cfg.get_usize("sparse", Schema::CRITEO.num_sparse)?,
    );
    let spec = spec_of(cfg)?;
    // Fail on selector/schema mismatch before touching the dataset.
    spec.compile(schema)?;
    let chunk = cfg.get_usize("chunk", 1 << 20)?;
    let artifact = build_artifact(Path::new(path), input, &spec, schema, chunk)?;
    artifact.save(Path::new(out))?;
    println!(
        "froze {} vocabulary entries across {} column(s) to {out}",
        artifact.total_entries(),
        artifact.vocabs().len(),
    );
    println!(
        "artifact hashes: spec {:#018x} schema {:#018x}",
        artifact.spec_hash(),
        artifact.schema_hash(),
    );
    Ok(())
}

fn cmd_request(cfg: &Config) -> Result<()> {
    let artifact_path = cfg
        .get("artifact")
        .ok_or_else(|| anyhow::anyhow!("missing artifact=PATH"))?;
    let input_path = cfg
        .get("input")
        .ok_or_else(|| anyhow::anyhow!("missing input=PATH"))?;
    let addr = cfg.get_or("addr", "127.0.0.1:7700");
    let policy = MissPolicy::parse(cfg.get_or("policy", "sentinel"))?;
    let format = match format_of(cfg)? {
        InputFormat::Utf8 => WireFormat::Utf8,
        InputFormat::Binary => WireFormat::Binary,
    };
    let artifact = VocabArtifact::load(Path::new(artifact_path))?;
    let schema = artifact.schema();
    let job = net::ServeJob {
        policy,
        format,
        queue_depth: cfg.get_usize("queue_depth", 32)? as u32,
        artifact,
    };
    let raw = std::fs::read(input_path)?;
    let netcfg = net_config_of(cfg)?;
    let mut client = net::ServeClient::connect_retry(addr, &job, &netcfg)?;
    let resp = client.request_retry(&raw, &netcfg)?;
    let (report, _late) = client.finish()?;
    match resp.status {
        net::ServeStatus::BadRequest => println!(
            "request rejected: {}",
            String::from_utf8_lossy(&resp.payload)
        ),
        status => println!(
            "status {status:?}: {} row(s) back, {} miss(es), {} rejected row(s)",
            resp.rows(schema),
            resp.misses,
            resp.rejected_rows,
        ),
    }
    println!(
        "server report: {} request(s), latency p50 {} / p99 {}",
        report.requests,
        fmt_duration(report.p50()),
        fmt_duration(report.p99()),
    );
    Ok(())
}

fn cmd_compare(cfg: &Config) -> Result<()> {
    let rows = cfg.get_usize("rows", 20_000)?;
    let input = format_of(cfg)?;
    let m = modulus_of(cfg)?;
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = match input {
        InputFormat::Utf8 => utf8::encode_dataset(&ds),
        InputFormat::Binary => binary::encode_dataset(&ds),
    };
    let threads = cfg.get_usize("threads", 8)?;
    let cpu_kind = match input {
        InputFormat::Utf8 => ConfigKind::II,
        InputFormat::Binary => ConfigKind::III,
    };
    let backends = vec![
        Backend::Cpu { kind: cpu_kind, threads },
        Backend::Gpu,
        Backend::Piper { mode: Mode::LocalDecodeInKernel },
        Backend::Piper { mode: Mode::Network },
    ];
    let exp = Experiment::new(m, input);
    let rows_out = coordinator::compare(&backends, &exp, &raw)?;
    let mut t = Table::new(
        &format!("compare ({:?}, vocab {})", input, m.range),
        &["backend", "strategy", "e2e", "rows/s", "speedup vs best CPU"],
    );
    for r in &rows_out {
        t.row(&[
            r.backend.clone(),
            r.strategy.name().to_string(),
            fmt_tagged(r.e2e, r.tag),
            fmt_rows_per_sec(r.rows_per_sec),
            fmt_speedup(r.speedup_vs_ref),
        ]);
    }
    t.note("sim-tagged rows model paper hardware; meas rows ran on this machine");
    t.note("CPU rows are pinned two-pass (the paper's staged baseline)");
    t.print();
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    let addr = cfg.get_or("addr", "127.0.0.1:7700");
    let jobs = cfg.get_usize("jobs", 1)?;
    let listener = std::net::TcpListener::bind(addr)?;
    if jobs == 0 {
        println!("piper worker listening on {addr} (forever; ^C to stop)");
        net::serve_forever(&listener);
    }
    println!("piper worker listening on {addr} for {jobs} job(s)");
    for i in 0..jobs {
        let stats = net::serve_one(&listener)?;
        println!("job {}: {} rows, {} vocab entries", i + 1, stats.rows, stats.vocab_entries);
    }
    Ok(())
}

fn cmd_submit(cfg: &Config) -> Result<()> {
    let path = cfg
        .get("input")
        .ok_or_else(|| anyhow::anyhow!("missing input=PATH"))?;
    let addr = cfg.get_or("addr", "127.0.0.1:7700");
    let input = format_of(cfg)?;
    let format = match input {
        InputFormat::Utf8 => WireFormat::Utf8,
        InputFormat::Binary => WireFormat::Binary,
    };
    // The wire handshake carries the full per-column spec; vocab= is
    // sugar for the uniform DLRM preset.
    let spec = match cfg.get("spec") {
        Some(s) => piper::ops::PipelineSpec::parse(s)?,
        None => piper::ops::PipelineSpec::dlrm(modulus_of(cfg)?.range),
    };
    // Resolve the spec against the job schema *before* connecting: a
    // selector/schema mismatch should be this planning error, not a
    // broken pipe after the worker rejects the handshake.
    spec.compile(Schema::CRITEO)?;
    let mut errors = piper::decode::ErrorConfig::default();
    if let Some(p) = cfg.get("on_error") {
        errors.policy = piper::decode::ErrorPolicy::parse(p)?;
    }
    if let Some(b) = cfg.get("max_errors") {
        errors.budget = piper::decode::ErrorBudget::parse(b)?;
    }
    let job = Job { schema: Schema::CRITEO, spec, format, errors };
    let chunk = cfg.get_usize("chunk", 1 << 20)?;
    let strategy = match cfg.get("strategy") {
        Some(s) => piper::pipeline::ExecStrategy::parse(s)?,
        None => piper::pipeline::ExecStrategy::Fused, // single-node default
    };
    let mut netcfg = net_config_of(cfg)?;
    // The worker protocol is strictly chunk-at-a-time, so pipelining a
    // submit happens on the leader: a read-ahead window of source
    // chunks overlaps disk reads with the network send.
    netcfg.leader_window = cfg.get_usize("pipeline_depth", 1)?.max(1);
    if addr.contains(',') {
        // Cluster mode: run the job on the disaggregated preprocessing
        // service — the dispatcher schedules splits over the pool and
        // every vocabulary column is owned by one worker, so the whole
        // cluster runs the fused single-pass scan with no merge barrier.
        let addrs: Vec<String> = addr
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        let raw = std::fs::read(Path::new(path))
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let binary = matches!(format, WireFormat::Binary);
        let nsplits = cfg.get_usize("splits", addrs.len())?.max(1);
        let splits = net::cluster::shard_rows(&raw, job.schema, binary, nsplits);
        let scfg = piper::service::ServiceConfig {
            net: netcfg,
            window: cfg.get_usize("window", 0)?,
            decode_threads: 0,
            chunk_bytes: chunk.max(1),
        };
        let run = piper::service::run_service_cfg(&addrs, &job, &raw, &splits, &scfg)?;
        println!(
            "preprocessed {} rows ({} vocab entries) across {} workers in {} \
             (service, fused single-pass; {} split retries, {} faults, \
             max {} split(s) in flight)",
            run.stats.rows,
            run.stats.vocab_entries,
            run.workers,
            fmt_duration(run.wallclock),
            run.retries,
            run.faults,
            run.max_inflight,
        );
        for w in &run.per_worker {
            println!(
                "  worker {}: {} split(s) won, {} rows — decode {} / \
                 stateless {} / vocab {}",
                w.addr,
                w.splits,
                w.stats.rows,
                fmt_duration(std::time::Duration::from_nanos(w.stats.decode_ns)),
                fmt_duration(std::time::Duration::from_nanos(w.stats.stateless_ns)),
                fmt_duration(std::time::Duration::from_nanos(w.stats.vocab_ns)),
            );
        }
        print_submit_containment(&run.stats);
        if let Some(out) = cfg.get("metrics") {
            write_submit_metrics(
                Path::new(out),
                &job.spec,
                &run.stats,
                run.workers,
                run.wallclock,
                run.retries,
                run.faults,
                &run.per_worker,
            )?;
            println!("metrics manifest written to {out}");
        }
        return Ok(());
    }
    // Stream the file to the worker chunk by chunk — the leader never
    // holds the dataset either. Fused sends it once; two-pass twice.
    let mut source = FileSource::open(Path::new(path), input)?;
    let run = net::run_leader_source_cfg(addr, &job, &mut source, chunk, strategy, &netcfg)?;
    println!(
        "preprocessed {} rows ({} vocab entries) in {} over TCP ({})",
        run.stats.rows,
        run.stats.vocab_entries,
        fmt_duration(run.wallclock),
        strategy.name(),
    );
    print_submit_containment(&run.stats);
    if let Some(out) = cfg.get("metrics") {
        write_submit_metrics(Path::new(out), &job.spec, &run.stats, 1, run.wallclock, 0, 0, &[])?;
        println!("metrics manifest written to {out}");
    }
    Ok(())
}

/// Escape a string for the hand-rolled JSON manifests (the tree
/// carries no serde; same idiom as the bench JSON emitters).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_containment(
    indent: &str,
    illegal_bytes: u64,
    row_errors: u64,
    rows_skipped: u64,
    rows_quarantined: u64,
) -> String {
    format!(
        "{indent}\"containment\": {{\"illegal_bytes\": {illegal_bytes}, \
         \"row_errors\": {row_errors}, \"rows_skipped\": {rows_skipped}, \
         \"rows_quarantined\": {rows_quarantined}}}"
    )
}

/// `metrics=PATH` for `preprocess`: one JSON object per run — spec and
/// schema hashes, rows in/out, per-stage durations (seconds), and the
/// containment counters.
fn write_preprocess_metrics(
    path: &Path,
    report: &piper::pipeline::RunReport,
    spec: &PipelineSpec,
) -> Result<()> {
    let rows_in = report.rows as u64 + report.rows_skipped + report.rows_quarantined;
    let mut j = String::from("{\n  \"command\": \"preprocess\",\n");
    j.push_str(&format!("  \"executor\": {},\n", json_str(&report.executor)));
    j.push_str(&format!("  \"strategy\": {},\n", json_str(report.strategy.name())));
    j.push_str(&format!(
        "  \"spec_hash\": \"{:#018x}\",\n  \"schema_hash\": \"{:#018x}\",\n",
        piper::ops::artifact::spec_hash(spec),
        piper::ops::artifact::schema_hash(Schema::CRITEO),
    ));
    j.push_str(&format!(
        "  \"rows_in\": {rows_in},\n  \"rows_out\": {},\n  \"chunks\": {},\n",
        report.rows, report.chunks,
    ));
    j.push_str(&format!(
        "  \"decode_passes\": {},\n  \"vocab_entries\": {},\n",
        report.decode_passes, report.vocab_entries,
    ));
    j.push_str(&format!(
        "  \"decode_threads\": {},\n  \"pipeline_depth\": {},\n",
        report.decode_threads, report.pipeline_depth,
    ));
    j.push_str(&format!("  \"time_tag\": {},\n", json_str(report.tag.suffix())));
    j.push_str(&format!(
        "  \"stages_s\": {{\"e2e\": {:.6}, \"wall\": {:.6}, \"decode\": {:.6}, \
         \"stateless\": {:.6}, \"vocab\": {:.6}, \"process\": {:.6}, \
         \"vocab_wait\": {:.6}}},\n",
        report.e2e.as_secs_f64(),
        report.wall.as_secs_f64(),
        report.decode_time.as_secs_f64(),
        report.stage_stateless_time.as_secs_f64(),
        report.observe_time.as_secs_f64(),
        report.process_time.as_secs_f64(),
        report.vocab_wait_time.as_secs_f64(),
    ));
    j.push_str(&json_containment(
        "  ",
        report.illegal_bytes,
        report.row_errors.total,
        report.rows_skipped,
        report.rows_quarantined,
    ));
    j.push_str("\n}\n");
    std::fs::write(path, j).map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

/// `metrics=PATH` for `submit`: the merged wire-side [`net::RunStats`]
/// plus — on the service path — the per-worker splits/stage breakdown.
#[allow(clippy::too_many_arguments)]
fn write_submit_metrics(
    path: &Path,
    spec: &PipelineSpec,
    stats: &net::RunStats,
    workers: usize,
    wallclock: std::time::Duration,
    retries: u64,
    faults: u64,
    per_worker: &[piper::service::WorkerStats],
) -> Result<()> {
    let rows_in = stats.rows + stats.rows_skipped + stats.rows_quarantined;
    let mut j = String::from("{\n  \"command\": \"submit\",\n");
    j.push_str(&format!(
        "  \"spec_hash\": \"{:#018x}\",\n  \"schema_hash\": \"{:#018x}\",\n",
        piper::ops::artifact::spec_hash(spec),
        piper::ops::artifact::schema_hash(Schema::CRITEO),
    ));
    j.push_str(&format!(
        "  \"workers\": {workers},\n  \"wall_s\": {:.6},\n  \"retries\": {retries},\n  \
         \"faults\": {faults},\n",
        wallclock.as_secs_f64(),
    ));
    j.push_str(&format!(
        "  \"rows_in\": {rows_in},\n  \"rows_out\": {},\n  \"vocab_entries\": {},\n",
        stats.rows, stats.vocab_entries,
    ));
    j.push_str(&format!(
        "  \"stages_s\": {{\"decode\": {:.6}, \"stateless\": {:.6}, \"vocab\": {:.6}}},\n",
        stats.decode_ns as f64 / 1e9,
        stats.stateless_ns as f64 / 1e9,
        stats.vocab_ns as f64 / 1e9,
    ));
    j.push_str(&json_containment(
        "  ",
        stats.illegal_bytes,
        0,
        stats.rows_skipped,
        stats.rows_quarantined,
    ));
    j.push_str(",\n  \"per_worker\": [\n");
    for (i, w) in per_worker.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"addr\": {}, \"splits\": {}, \"rows\": {}, \"decode_s\": {:.6}, \
             \"stateless_s\": {:.6}, \"vocab_s\": {:.6}}}{}\n",
            json_str(&w.addr),
            w.splits,
            w.stats.rows,
            w.stats.decode_ns as f64 / 1e9,
            w.stats.stateless_ns as f64 / 1e9,
            w.stats.vocab_ns as f64 / 1e9,
            if i + 1 < per_worker.len() { "," } else { "" },
        ));
    }
    j.push_str("  ]\n}\n");
    std::fs::write(path, j).map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

fn print_submit_containment(stats: &net::RunStats) {
    if stats.rows_skipped + stats.rows_quarantined + stats.illegal_bytes == 0 {
        return;
    }
    println!(
        "containment: {} row(s) skipped, {} row(s) quarantined worker-side, \
         {} illegal byte(s) (merged across workers)",
        stats.rows_skipped, stats.rows_quarantined, stats.illegal_bytes,
    );
}

#[cfg(feature = "pjrt")]
fn cmd_train(cfg: &Config) -> Result<()> {
    let raw = read_input(cfg)?;
    let exp = Experiment::new(modulus_of(cfg)?, format_of(cfg)?);
    let backend = backend_of(cfg)?;
    let summary = coordinator::run_backend(&backend, &exp, &raw)?;
    println!(
        "preprocessed {} rows via {} in {}",
        summary.rows,
        summary.backend,
        fmt_tagged(summary.e2e, summary.tag)
    );

    let artifacts = Path::new(cfg.get_or("artifacts", "artifacts"));
    let rt = piper::runtime::Runtime::new(artifacts)?;
    let mut trainer = piper::train::Trainer::new(&rt, artifacts)?;
    let steps = cfg.get_usize("steps", 100)?;
    let losses = piper::train::train_loop(&mut trainer, &summary.processed, steps)?;
    for (i, chunk) in losses.chunks(10).enumerate() {
        let avg = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("steps {:>4}-{:<4} mean loss {avg:.4}", i * 10, i * 10 + chunk.len() - 1);
    }
    let first = losses.first().copied().unwrap_or(f32::NAN);
    let last = losses.last().copied().unwrap_or(f32::NAN);
    println!("final loss {last:.4} (first {first:.4})");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_cfg: &Config) -> Result<()> {
    anyhow::bail!(
        "this build has no PJRT runtime — rebuild with `--features pjrt` \
         (needs the xla_extension shared library) to enable `train`"
    )
}
