//! Bounded FIFO channel model between PEs (paper §3.1: "different PEs are
//! interconnected via FIFO channels").
//!
//! The functional pipeline doesn't need explicit FIFOs (rust vectors carry
//! the data), but the *timing* question the ablation bench asks — how
//! deep must inter-PE FIFOs be before producer/consumer rate mismatch
//! stalls the chain — needs an occupancy model. This is a discrete
//! simulation over per-cycle token flow between two stages with given
//! IIs and burstiness.

/// Result of simulating a producer→FIFO→consumer segment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FifoStats {
    /// Cycles the producer stalled on a full FIFO.
    pub producer_stalls: u64,
    /// Cycles the consumer starved on an empty FIFO.
    pub consumer_starves: u64,
    /// Peak occupancy reached.
    pub peak_occupancy: usize,
    /// Total cycles to move all tokens.
    pub total_cycles: u64,
}

/// Simulate `tokens` items flowing producer(II=`prod_ii`) → FIFO(depth) →
/// consumer(II=`cons_ii`). `burst` models a producer that emits up to
/// `burst` tokens in one launch (the parallel decoder emits 0–4 values
/// per cycle — paper Script 1).
pub fn simulate(tokens: u64, depth: usize, prod_ii: u64, cons_ii: u64, burst: u64) -> FifoStats {
    assert!(depth >= 1 && prod_ii >= 1 && cons_ii >= 1 && burst >= 1);
    let mut occupancy: usize = 0;
    let mut produced: u64 = 0;
    let mut consumed: u64 = 0;
    let mut stats = FifoStats {
        producer_stalls: 0,
        consumer_starves: 0,
        peak_occupancy: 0,
        total_cycles: 0,
    };
    let mut cycle: u64 = 0;
    let mut next_prod = 0u64;
    let mut next_cons = 0u64;

    while consumed < tokens {
        // consumer first (frees space within the cycle, like ap_fifo).
        if cycle >= next_cons && consumed < tokens {
            if occupancy > 0 {
                occupancy -= 1;
                consumed += 1;
                next_cons = cycle + cons_ii;
            } else if produced < tokens {
                stats.consumer_starves += 1;
            }
        }
        if cycle >= next_prod && produced < tokens {
            let want = burst.min(tokens - produced) as usize;
            let space = depth - occupancy;
            if space == 0 {
                stats.producer_stalls += 1;
            } else {
                let emit = want.min(space);
                occupancy += emit;
                produced += emit as u64;
                next_prod = cycle + prod_ii;
            }
        }
        stats.peak_occupancy = stats.peak_occupancy.max(occupancy);
        cycle += 1;
        // Safety valve: no livelock possible, but cap anyway.
        if cycle > tokens.saturating_mul(prod_ii.max(cons_ii) + 2) + 1000 {
            break;
        }
    }
    stats.total_cycles = cycle;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matched_rates_never_stall() {
        let s = simulate(1000, 8, 1, 1, 1);
        assert_eq!(s.producer_stalls, 0);
        // consumer may starve a cycle at startup only
        assert!(s.consumer_starves <= 2, "{s:?}");
        assert!(s.total_cycles <= 1010);
    }

    #[test]
    fn slow_consumer_backpressures_producer() {
        // consumer II=2, producer II=1 → producer must stall ~half the time.
        let s = simulate(1000, 4, 1, 2, 1);
        assert!(s.producer_stalls > 400, "{s:?}");
        assert!(s.total_cycles >= 2000);
    }

    #[test]
    fn deeper_fifo_absorbs_bursts() {
        // bursty producer (4 tokens per launch, like the width-4 decoder)
        // into a consumer of II=1.
        let shallow = simulate(4000, 2, 4, 1, 4);
        let deep = simulate(4000, 16, 4, 1, 4);
        assert!(deep.producer_stalls <= shallow.producer_stalls);
        assert!(deep.total_cycles <= shallow.total_cycles);
    }

    #[test]
    fn throughput_set_by_slowest_side() {
        let s = simulate(10_000, 64, 3, 1, 1);
        // producer II=3 ⇒ ~3 cycles/token
        let cpt = s.total_cycles as f64 / 10_000.0;
        assert!((cpt - 3.0).abs() < 0.2, "cycles/token {cpt}");
    }

    #[test]
    fn peak_occupancy_bounded_by_depth() {
        let s = simulate(5000, 8, 1, 5, 4);
        assert!(s.peak_occupancy <= 8);
    }
}
