//! Memory models: DDR/HBM lanes feeding the dataflow, and on-chip SRAM
//! vs off-chip HBM placement for the vocabulary tables.
//!
//! Calibration sources (all from the paper):
//! * §3.3 — "The theoretical throughput of one DDR channel is 19 GB/s
//!   (512-bit wide memory lane, 300 MHz)";
//! * §4.1 — U250: 4 DDR channels / 77 GB/s, 54 MB SRAM;
//!   U55c: 32 HBM channels / 460 GB/s, 43 MB SRAM;
//! * §3.2 — ApplyVocab II ≈ 15 cycles for random HBM access;
//! * §4.4.6 — round-robin across independent HBM channels brings the
//!   effective II back to 1 when the revisit interval exceeds latency.

use crate::Result;

/// A 512-bit memory lane at 300 MHz (one DDR/HBM pseudo-channel group).
#[derive(Debug, Clone, Copy)]
pub struct MemLane {
    pub bits: u32,
    pub clock_hz: f64,
}

impl Default for MemLane {
    fn default() -> Self {
        MemLane { bits: 512, clock_hz: 300.0e6 }
    }
}

impl MemLane {
    /// Bytes delivered per *kernel* cycle at kernel clock `f` — the lane
    /// runs at its own 300 MHz; a slower kernel sees proportionally more
    /// bytes available per cycle (it is never lane-starved).
    pub fn bytes_per_kernel_cycle(&self, kernel_hz: f64) -> f64 {
        (self.bits as f64 / 8.0) * (self.clock_hz / kernel_hz)
    }

    /// Sequential bandwidth in bytes/second (≈19.2 GB/s for the default).
    pub fn bandwidth_bps(&self) -> f64 {
        self.bits as f64 / 8.0 * self.clock_hz
    }
}

/// Where the vocabulary tables live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VocabPlacement {
    /// On-chip BRAM/URAM — II = 2, capacity-limited.
    Sram,
    /// Off-chip HBM — random access `latency` cycles, hidden by
    /// round-robin across `channels`; `sharers` feature columns share
    /// the channel pool.
    Hbm { latency: u32, channels: u32, sharers: u32 },
}

impl VocabPlacement {
    /// U55c HBM with all 26 sparse columns sharing 32 channels.
    pub fn hbm_u55c() -> Self {
        VocabPlacement::Hbm { latency: 15, channels: 32, sharers: 26 }
    }

    /// Effective II of a vocabulary access PE (ApplyVocab-1/2).
    ///
    /// SRAM: II = 2 (paper §3.2). HBM: a single stream sees the full
    /// random-access latency (~15), but interleaving accesses round-robin
    /// over independent channels hides it — "the time span for accessing
    /// the same HBM channel is longer than the allowed II" (§4.4.6). With
    /// `sharers` columns sharing `channels` channels, each column
    /// effectively owns `channels/sharers` channels, so
    /// `II_eff = max(1, latency × sharers / channels)`.
    pub fn vocab_ii(&self) -> f64 {
        match *self {
            VocabPlacement::Sram => 2.0,
            VocabPlacement::Hbm { latency, channels, sharers } => {
                (latency as f64 * sharers as f64 / channels as f64).max(1.0)
            }
        }
    }

    /// On-chip capacity check: the U55c/U250 SRAM budget is ~43–54 MB;
    /// we enforce the smaller one.
    pub fn validate(&self, needed_bits: u64) -> Result<()> {
        const SRAM_BITS: u64 = 43 * 8 * 1024 * 1024 * 8 / 8; // 43 MB in bits
        if matches!(self, VocabPlacement::Sram) && needed_bits > SRAM_BITS {
            anyhow::bail!(
                "vocabulary needs {needed_bits} bits but on-chip SRAM holds {SRAM_BITS}; \
                 use HBM placement (the paper's 1M-vocab build)"
            );
        }
        Ok(())
    }
}

/// The off-chip memory system feeding LoadData.
#[derive(Debug, Clone)]
pub struct MemSystem {
    pub lanes: Vec<MemLane>,
}

impl MemSystem {
    /// n identical default lanes.
    pub fn with_lanes(n: usize) -> Self {
        MemSystem { lanes: vec![MemLane::default(); n] }
    }

    pub fn total_bandwidth_bps(&self) -> f64 {
        self.lanes.iter().map(|l| l.bandwidth_bps()).sum()
    }

    /// Bytes per kernel cycle across all lanes.
    pub fn bytes_per_kernel_cycle(&self, kernel_hz: f64) -> f64 {
        self.lanes.iter().map(|l| l.bytes_per_kernel_cycle(kernel_hz)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_bandwidth_matches_paper() {
        let lane = MemLane::default();
        let gbps = lane.bandwidth_bps() / 1e9;
        assert!((gbps - 19.2).abs() < 0.1, "paper says 19 GB/s, got {gbps}");
    }

    #[test]
    fn u250_aggregate_bandwidth() {
        let mem = MemSystem::with_lanes(4);
        let gbps = mem.total_bandwidth_bps() / 1e9;
        assert!((gbps - 76.8).abs() < 1.0, "paper says 77 GB/s, got {gbps}");
    }

    #[test]
    fn slower_kernel_sees_more_bytes_per_cycle() {
        let lane = MemLane::default();
        assert!(lane.bytes_per_kernel_cycle(135.0e6) > lane.bytes_per_kernel_cycle(250.0e6));
        // at 300 MHz kernel == lane clock: exactly 64 B/cycle
        assert!((lane.bytes_per_kernel_cycle(300.0e6) - 64.0).abs() < 1e-9);
    }

    #[test]
    fn hbm_ii_regimes() {
        // dedicated channel pool larger than latency → fully hidden
        let fast = VocabPlacement::Hbm { latency: 15, channels: 32, sharers: 1 };
        assert_eq!(fast.vocab_ii(), 1.0);
        // 26 sharers on 32 channels → latency mostly exposed
        let shared = VocabPlacement::hbm_u55c();
        let ii = shared.vocab_ii();
        assert!(ii > 10.0 && ii < 15.0, "expected ~12.2, got {ii}");
        // single channel → full latency
        let one = VocabPlacement::Hbm { latency: 15, channels: 1, sharers: 1 };
        assert_eq!(one.vocab_ii(), 15.0);
    }

    #[test]
    fn sram_capacity_check() {
        let sram = VocabPlacement::Sram;
        assert!(sram.validate(1_000_000).is_ok());
        assert!(sram.validate(u64::MAX / 2).is_err());
        // HBM never fails the check
        assert!(VocabPlacement::hbm_u55c().validate(u64::MAX / 2).is_ok());
    }
}
