//! The PIPER accelerator — functional + cycle-level simulator.
//!
//! The paper prototypes PIPER on Xilinx Alveo U250 (local, 64 GB DDR) and
//! U55c (network-attached, 16 GB HBM). Neither FPGA is available here, so
//! the accelerator is reproduced as a simulator with two faces:
//!
//! * **functional** — [`dataflow`] really executes the column-wise
//!   two-loop pipeline (decode → modulus → gen-vocab → apply-vocab →
//!   neg2zero → log → store) and produces bit-identical output to the CPU
//!   baseline (asserted by tests);
//! * **timing** — every PE carries the paper's initiation interval
//!   (§3.2), memory models carry the paper's lane widths/latencies
//!   (§3.3, §4.4.6), and a run reports modeled cycles → seconds at the
//!   build's kernel clock (Table 4 caption: 250 MHz for the 5K/SRAM
//!   build, 135 MHz for the 1M/HBM build). All such times are tagged
//!   `sim` in reports — never mixed with wallclock.
//!
//! Submodules:
//! * [`pe`] — PE catalogue with IIs;
//! * [`memory`] — DDR/HBM lanes, SRAM/HBM vocabulary placement;
//! * [`fifo`] — inter-PE FIFO occupancy model (backpressure ablation);
//! * [`dataflow`] — the two-loop column pipeline (functional + cycles);
//! * [`host`] — local-mode host-side stages (Fig. 10);
//! * [`network`] — network-attached streaming overlap model (Fig. 7d).

pub mod dataflow;
pub mod fifo;
pub mod host;
pub mod memory;
pub mod network;
pub mod pe;

use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::ops::{DirectVocab, Modulus};
use std::time::Duration;

pub use dataflow::{KernelRun, KernelTiming};
pub use host::HostModel;
pub use memory::VocabPlacement;

/// Where the raw dataset enters the accelerator (paper Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fig. 7b — PCIe-attached; decode runs in the FPGA kernel.
    LocalDecodeInKernel,
    /// Fig. 7c — PCIe-attached; host CPU decodes, kernel does the rest.
    LocalDecodeInHost,
    /// Fig. 7d — network-attached, fully pipelined streaming.
    Network,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::LocalDecodeInKernel => "local/decode-in-kernel",
            Mode::LocalDecodeInHost => "local/decode-in-host",
            Mode::Network => "network",
        }
    }
}

/// Input format (paper Table 2 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputFormat {
    Utf8,
    Binary,
}

/// Full accelerator configuration.
#[derive(Debug, Clone)]
pub struct PiperConfig {
    pub schema: Schema,
    pub modulus: Modulus,
    pub mode: Mode,
    pub input: InputFormat,
    /// Parallel-decode width in bytes/cycle (paper Script 1: 4).
    pub decode_width: usize,
    /// Number of parallel sparse-column dataflows instantiated
    /// (paper §3.1: "the performance of each processing stage can be
    /// controlled via instantiating multiple PEs"). The U250 local build
    /// fits 8; the U55c network build fits 13 (DESIGN.md §5).
    pub sparse_dataflows: usize,
    /// Parallel dense-column dataflows.
    pub dense_dataflows: usize,
    /// Vocabulary storage decided by size (paper §3.1: "the size of
    /// vocabulary determines whether it is stored in on-chip SRAM or
    /// off-chip HBM").
    pub vocab_placement: VocabPlacement,
    /// Kernel clock (Hz).
    pub clock_hz: f64,
    /// Memory lanes feeding LoadData in binary mode (paper §3.4.1: one
    /// 512-bit lane for label+dense, two for sparse).
    pub load_lanes: usize,
    /// FIFO depth between PEs (ablation knob; paper uses HLS defaults).
    pub fifo_depth: usize,
}

impl PiperConfig {
    /// The paper's configuration for a given mode / input / vocab size.
    pub fn paper(mode: Mode, input: InputFormat, vocab: Modulus) -> Self {
        let large_vocab = vocab.range > 100_000;
        let network = mode == Mode::Network;
        PiperConfig {
            schema: Schema::CRITEO,
            modulus: vocab,
            mode,
            input,
            decode_width: 4,
            // U55c (network) fits more parallel dataflows than U250.
            sparse_dataflows: if network { 13 } else { 8 },
            dense_dataflows: 4,
            vocab_placement: if large_vocab {
                VocabPlacement::hbm_u55c()
            } else {
                VocabPlacement::Sram
            },
            // Table 4 caption: 250 MHz (5K build) / 135 MHz (1M build).
            // The network build closes timing ~17% lower (Table 3: local
            // 1.87e6 vs network 1.56e6 rows/s on the same dataflow —
            // "the difference ... lies in the kernel clock frequency").
            clock_hz: {
                let base = if large_vocab { 135.0e6 } else { 250.0e6 };
                if network {
                    base * 0.83
                } else {
                    base
                }
            },
            load_lanes: 3,
            fifo_depth: 64,
        }
    }

    /// Modeled VMEM/SRAM bits needed by the vocabulary structures —
    /// drives the SRAM-capacity check in [`VocabPlacement::validate`].
    pub fn vocab_storage_bits(&self) -> u64 {
        let per_col = DirectVocab::new(self.modulus.range).storage_bits();
        per_col * self.schema.num_sparse as u64
    }
}

/// Result of a full PIPER run: functional output + the timing report.
#[derive(Debug)]
pub struct PiperRun {
    pub processed: ProcessedColumns,
    pub vocabs: Vec<DirectVocab>,
    pub rows: usize,
    /// Kernel (dataflow) timing.
    pub kernel: KernelTiming,
    /// Host-side stage times (zero for network mode).
    pub host: host::HostBreakdown,
    /// End-to-end modeled time.
    pub e2e: Duration,
}

impl PiperRun {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        crate::report::rows_per_sec(self.rows, self.e2e)
    }

    pub fn kernel_rows_per_sec(&self) -> f64 {
        crate::report::rows_per_sec(self.rows, self.kernel.seconds())
    }
}

/// Run PIPER end-to-end over a raw buffer (UTF-8 or binary per config).
pub fn run(cfg: &PiperConfig, raw: &[u8]) -> crate::Result<PiperRun> {
    cfg.vocab_placement.validate(cfg.vocab_storage_bits())?;
    let kernel_run = dataflow::run_kernel(cfg, raw)?;
    let rows = kernel_run.processed.num_rows();

    let (host, e2e) = match cfg.mode {
        Mode::LocalDecodeInKernel | Mode::LocalDecodeInHost => {
            let hm = HostModel::default();
            let hb = hm.local_breakdown(cfg, raw.len(), rows, kernel_run.timing.seconds());
            let total = hb.total();
            (hb, total)
        }
        Mode::Network => {
            let nb = network::stream_time(cfg, raw.len(), kernel_run.timing.seconds());
            (host::HostBreakdown::none(), nb)
        }
    };

    Ok(PiperRun {
        processed: kernel_run.processed,
        vocabs: kernel_run.vocabs,
        rows,
        kernel: kernel_run.timing,
        host,
        e2e,
    })
}

// ---------------------------------------------------------------------
// Streaming executor
// ---------------------------------------------------------------------

use crate::data::RowBlock;
use crate::pipeline::{
    ChunkState, Executor, ExecutorReport, ExecutorRun, Plan, StreamStats,
};
use crate::report::TimeTag;

/// PIPER as a streaming [`Executor`], covering all three modes of
/// Fig. 7. The fused single-pass strategy *is* the hardware design:
/// GenVocab-1's bitmap and ApplyVocab-1's counter live in the same
/// dataflow, so a value's appearance index is assigned the cycle its
/// novelty is decided — one scan, no rewind. The functional pipeline
/// runs chunk by chunk (fused or two-loop, bit-identical either way);
/// the cycle model ([`dataflow::model_timing`]) plus the mode's host or
/// network model are evaluated once at the end over the stream totals —
/// the same quantities [`run`] derives from a one-shot buffer, so the
/// modeled times are identical. All times are tagged sim.
///
/// Per-column programs map naturally onto the modular-PE design: each
/// sparse dataflow instantiates the column's own Modulus → GenVocab →
/// ApplyVocab chain with its own vocabulary capacity, each dense
/// dataflow the column's kernel chain — §5's "dynamically configured"
/// PEs. The build-level knobs (kernel clock, SRAM-vs-HBM placement) key
/// on the plan's largest vocabulary, and the SRAM capacity check sums
/// the **per-column** capacities, so a heterogeneous plan only pays for
/// what its programs declare.
///
/// The vocabulary-placement capacity check ([`VocabPlacement::validate`])
/// runs at **planning** time: an over-capacity SRAM build fails in
/// [`crate::pipeline::PipelineBuilder::build`], not inside a serving
/// worker.
#[derive(Debug, Clone)]
pub struct PiperExecutor {
    pub mode: Mode,
    /// Overrides applied on top of [`PiperConfig::paper`] (dataflow
    /// counts, clock, placement); `None` = the paper configuration.
    pub config: Option<PiperConfig>,
}

impl PiperExecutor {
    pub fn new(mode: Mode) -> Self {
        PiperExecutor { mode, config: None }
    }

    pub fn with_config(config: PiperConfig) -> Self {
        PiperExecutor { mode: config.mode, config: Some(config) }
    }

    /// The concrete accelerator configuration for a plan. The build's
    /// clock and vocabulary placement key on the plan's largest
    /// **vocabulary-building** modulus (the biggest vocabulary decides
    /// SRAM vs HBM and how the build closes timing — a modulus-only
    /// passthrough column stores nothing, however large its range); the
    /// SRAM capacity check itself sums each column's own capacity
    /// ([`Plan::programs`]).
    fn config_for(&self, plan: &Plan) -> PiperConfig {
        let modulus = plan.programs.max_vocab_modulus();
        let mut cfg = self.config.clone().unwrap_or_else(|| {
            PiperConfig::paper(
                self.mode,
                plan.input,
                modulus.unwrap_or(crate::ops::Modulus::VOCAB_5K),
            )
        });
        cfg.input = plan.input;
        cfg.schema = plan.schema();
        if let Some(m) = modulus {
            cfg.modulus = m;
        }
        cfg
    }
}

impl Executor for PiperExecutor {
    fn name(&self) -> String {
        format!("PIPER {}", self.mode.name())
    }

    fn accepts(&self, _input: InputFormat) -> bool {
        true // decode-in-kernel handles UTF-8; LoadData handles binary
    }

    /// The fused single pass is PIPER's native dataflow (GenVocab-1
    /// bitmap + ApplyVocab-1 counter in one pipeline) — always
    /// supported.
    fn supports_fused(&self, _plan: &Plan) -> bool {
        true
    }

    fn plan_check(&self, plan: &Plan) -> crate::Result<()> {
        let cfg = self.config_for(plan);
        if plan.has_gen_vocab() {
            // Sum each column's own vocabulary capacity — a
            // heterogeneous plan (a few big columns, many small ones)
            // prices exactly what its programs ask for, not
            // columns × max.
            cfg.vocab_placement.validate(plan.programs.vocab_storage_bits())?;
        }
        Ok(())
    }

    fn begin(&self, plan: &Plan) -> crate::Result<Box<dyn ExecutorRun>> {
        Ok(Box::new(PiperExecRun {
            cfg: self.config_for(plan),
            state: ChunkState::new(plan),
            observe_time: Duration::ZERO,
            process_time: Duration::ZERO,
        }))
    }
}

struct PiperExecRun {
    cfg: PiperConfig,
    state: ChunkState,
    observe_time: Duration,
    process_time: Duration,
}

impl ExecutorRun for PiperExecRun {
    fn process_observing(
        &mut self,
        block: &RowBlock,
        sink: &mut dyn crate::pipeline::Sink,
    ) -> crate::Result<()> {
        let t0 = std::time::Instant::now();
        let out = self.state.process_fused(block);
        self.process_time += t0.elapsed();
        sink.push(&out)
    }

    /// Stage-split for the pipelined fused scheduler — the exact
    /// decomposition of [`ChunkState::process_fused`], mirroring the
    /// hardware's concurrently-active dataflow stages on the host: the
    /// engine overlaps chunk N+1's decode+stateless work with chunk N's
    /// ordered vocab scan. Output stays bit-identical.
    fn stages(&mut self) -> Option<crate::pipeline::FusedStages<'_>> {
        let (programs, vocabs) = self.state.stage_split();
        Some(crate::pipeline::FusedStages {
            stateless: Box::new(move |block: &RowBlock| {
                crate::pipeline::executor::stateless_range(programs, block, 0..block.num_rows())
            }),
            vocab: Box::new(move |block: &RowBlock, out: &mut ProcessedColumns| {
                crate::pipeline::executor::fuse_sparse_into(programs, vocabs, block, out);
            }),
        })
    }

    fn observe(&mut self, block: &RowBlock) -> crate::Result<()> {
        let t0 = std::time::Instant::now();
        self.state.observe(block);
        self.observe_time += t0.elapsed();
        Ok(())
    }

    fn process(&mut self, block: &RowBlock) -> crate::Result<ProcessedColumns> {
        let t0 = std::time::Instant::now();
        let out = self.state.process(block);
        self.process_time += t0.elapsed();
        Ok(out)
    }

    fn finish(&mut self, stats: &StreamStats) -> crate::Result<ExecutorReport> {
        // Engine-measured stage times under pipelined driving; zero when
        // this run timed its own phases in `process_observing`.
        self.process_time += stats.stateless_time;
        self.observe_time += stats.vocab_time;
        let kernel = dataflow::model_timing(
            &self.cfg,
            stats.raw_bytes as usize,
            stats.rows as usize,
            self.state.vocab_entries(),
        );
        let e2e = match self.cfg.mode {
            Mode::LocalDecodeInKernel | Mode::LocalDecodeInHost => HostModel::default()
                .local_breakdown(
                    &self.cfg,
                    stats.raw_bytes as usize,
                    stats.rows as usize,
                    kernel.seconds(),
                )
                .total(),
            Mode::Network => {
                network::stream_time(&self.cfg, stats.raw_bytes as usize, kernel.seconds())
            }
        };
        Ok(ExecutorReport {
            tag: TimeTag::Sim,
            modeled_e2e: Some(e2e),
            compute: Some(kernel.seconds()),
            observe_time: self.observe_time,
            process_time: self.process_time,
            vocab_entries: self.state.vocab_entries(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};

    #[test]
    fn paper_configs_have_expected_clocks() {
        let small = PiperConfig::paper(Mode::LocalDecodeInKernel, InputFormat::Utf8, Modulus::VOCAB_5K);
        let large = PiperConfig::paper(Mode::LocalDecodeInKernel, InputFormat::Utf8, Modulus::VOCAB_1M);
        assert_eq!(small.clock_hz, 250.0e6);
        assert_eq!(large.clock_hz, 135.0e6);
        let net = PiperConfig::paper(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K);
        assert!(net.clock_hz < small.clock_hz);
        let large = PiperConfig::paper(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_1M);
        assert_eq!(small.vocab_placement, VocabPlacement::Sram);
        assert!(matches!(large.vocab_placement, VocabPlacement::Hbm { .. }));
    }

    #[test]
    fn end_to_end_matches_cpu_baseline_output() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let raw = utf8::encode_dataset(&ds);
        let m = Modulus::new(997);

        let mut cfg = PiperConfig::paper(Mode::Network, InputFormat::Utf8, m);
        cfg.schema = ds.schema();
        let piper = run(&cfg, &raw).unwrap();

        let bl_cfg = crate::cpu_baseline::BaselineConfig::new(
            crate::cpu_baseline::ConfigKind::I,
            4,
            m,
        );
        let baseline = crate::cpu_baseline::run(&bl_cfg, &raw);
        assert_eq!(piper.processed, baseline.processed,
            "PIPER functional output must equal the CPU baseline");
    }

    #[test]
    fn binary_and_utf8_inputs_agree() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let m = Modulus::new(1009);
        let mut cfg_u = PiperConfig::paper(Mode::Network, InputFormat::Utf8, m);
        cfg_u.schema = ds.schema();
        let mut cfg_b = PiperConfig::paper(Mode::Network, InputFormat::Binary, m);
        cfg_b.schema = ds.schema();
        let u = run(&cfg_u, &utf8::encode_dataset(&ds)).unwrap();
        let b = run(&cfg_b, &binary::encode_dataset(&ds)).unwrap();
        assert_eq!(u.processed, b.processed);
    }

    #[test]
    fn binary_kernel_is_much_faster_than_utf8() {
        let ds = SynthDataset::generate(SynthConfig::small(500));
        let m = Modulus::VOCAB_5K;
        let u = run(&PiperConfig::paper(Mode::Network, InputFormat::Utf8, m),
                    &utf8::encode_dataset(&ds)).unwrap();
        let b = run(&PiperConfig::paper(Mode::Network, InputFormat::Binary, m),
                    &binary::encode_dataset(&ds)).unwrap();
        let speedup = u.kernel.seconds().as_secs_f64() / b.kernel.seconds().as_secs_f64();
        // paper: decode caps UTF-8 mode; binary lifts throughput ~10×.
        assert!(speedup > 4.0, "binary speedup over UTF-8 only {speedup:.2}×");
    }

    #[test]
    fn network_mode_beats_local_mode_at_scale() {
        // Timing-model property at paper scale (11 GB / 46M rows): the
        // network mode deletes the host-side buffer costs, so it must
        // win end-to-end. (At toy scale the fixed 1 ms connection setup
        // dominates and local can win — scale matters, which is itself a
        // property the paper discusses.)
        let m = Modulus::VOCAB_5K;
        let raw_bytes = 11_000_000_000usize;
        let rows = 46_000_000usize;
        let unique = 26 * 5_000;

        let net_cfg = PiperConfig::paper(Mode::Network, InputFormat::Utf8, m);
        let net_kernel = dataflow::model_timing(&net_cfg, raw_bytes, rows, unique);
        let net_e2e = network::stream_time(&net_cfg, raw_bytes, net_kernel.seconds());

        let loc_cfg = PiperConfig::paper(Mode::LocalDecodeInKernel, InputFormat::Utf8, m);
        let loc_kernel = dataflow::model_timing(&loc_cfg, raw_bytes, rows, unique);
        let hb = HostModel::default().local_breakdown(
            &loc_cfg, raw_bytes, rows, loc_kernel.seconds(),
        );
        assert!(
            net_e2e < hb.total(),
            "network {net_e2e:?} must beat local {:?}",
            hb.total()
        );
    }

    #[test]
    fn large_vocab_slows_kernel() {
        let ds = SynthDataset::generate(SynthConfig::small(500));
        let raw = binary::encode_dataset(&ds);
        let small = run(&PiperConfig::paper(Mode::Network, InputFormat::Binary, Modulus::VOCAB_5K),
                        &raw).unwrap();
        let large = run(&PiperConfig::paper(Mode::Network, InputFormat::Binary, Modulus::VOCAB_1M),
                        &raw).unwrap();
        assert!(large.kernel.seconds() > small.kernel.seconds(),
            "1M vocab (HBM, 135 MHz) must be slower than 5K (SRAM, 250 MHz)");
    }

    #[test]
    fn streaming_executor_matches_one_shot_run() {
        let ds = SynthDataset::generate(SynthConfig::small(250));
        let m = crate::ops::Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let mut cfg = PiperConfig::paper(Mode::Network, InputFormat::Utf8, m);
        cfg.schema = ds.schema();
        let one_shot = run(&cfg, &raw).unwrap();

        let pipeline = crate::pipeline::PipelineBuilder::new()
            .spec(crate::ops::PipelineSpec::dlrm(m.range))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(100)
            .executor(Box::new(PiperExecutor::new(Mode::Network)))
            .build()
            .unwrap();
        let mut src = crate::pipeline::MemorySource::new(&raw, InputFormat::Utf8);
        let (cols, report) = pipeline.run_collect(&mut src).unwrap();
        assert_eq!(cols, one_shot.processed);
        assert_eq!(report.tag, TimeTag::Sim);
        let d = report.e2e.as_secs_f64() - one_shot.e2e.as_secs_f64();
        assert!(d.abs() < 1e-9, "modeled e2e drifted by {d}");
        let dk = report.compute.unwrap().as_secs_f64() - one_shot.kernel.seconds().as_secs_f64();
        assert!(dk.abs() < 1e-9, "kernel time drifted by {dk}");
    }

    #[test]
    fn sram_over_capacity_is_a_planning_error() {
        let mut cfg =
            PiperConfig::paper(Mode::Network, InputFormat::Binary, crate::ops::Modulus::VOCAB_1M);
        cfg.vocab_placement = VocabPlacement::Sram;
        let err = crate::pipeline::PipelineBuilder::new()
            .spec(crate::ops::PipelineSpec::dlrm(1_000_000))
            .input(InputFormat::Binary)
            .executor(Box::new(PiperExecutor::with_config(cfg)))
            .build();
        assert!(err.is_err(), "1M×26 vocab must not plan into SRAM");
    }

    #[test]
    fn sram_capacity_is_enforced() {
        // 1M vocab × 26 columns does not fit SRAM — forcing it must fail.
        let mut cfg = PiperConfig::paper(Mode::Network, InputFormat::Binary, Modulus::VOCAB_1M);
        cfg.vocab_placement = VocabPlacement::Sram;
        let ds = SynthDataset::generate(SynthConfig::small(10));
        let raw = binary::encode_dataset(&ds);
        assert!(run(&cfg, &raw).is_err());
    }
}
