//! Host-side cost model for PIPER as a *local* (PCIe-attached)
//! accelerator — the four stages the paper profiles in Fig. 10:
//! Get Row Number, Initialize Buffer, Assign Values, Kernel Execution.
//!
//! These costs are exactly what the network-attached design deletes
//! (§3.4.2: "avoids the host-side processing, which involves expensive
//! operations including allocating a large buffer and data movements").
//! All times here are modeled (tagged `sim`); bandwidth constants are
//! calibrated in DESIGN.md §5.

use std::time::Duration;

use super::{InputFormat, Mode, PiperConfig};

/// Host machine parameters (the paper's attached Xeon/EPYC hosts).
#[derive(Debug, Clone, Copy)]
pub struct HostModel {
    /// Sequential scan bandwidth for counting rows (bytes/s).
    pub scan_bps: f64,
    /// First-touch buffer allocation bandwidth (bytes/s) — the dominant
    /// Fig. 10 cost ("the initialization overhead of creating large
    /// buffers dominates, and it can reach tens of seconds", §4.4.4).
    pub buffer_init_bps: f64,
    /// Plain memcpy into a pinned buffer (bytes/s).
    pub memcpy_bps: f64,
    /// Host-side UTF-8 decode throughput (bytes/s) — "the program can
    /// only read the file per byte, and it is time-consuming" (§4.4.4).
    pub host_decode_bps: f64,
    /// Effective PCIe gen3 ×16 bandwidth (bytes/s).
    pub pcie_bps: f64,
}

impl Default for HostModel {
    fn default() -> Self {
        HostModel {
            scan_bps: 1.5e9,
            buffer_init_bps: 1.2e9,
            memcpy_bps: 5.0e9,
            host_decode_bps: 0.33e9,
            pcie_bps: 12.0e9,
        }
    }
}

/// Fig. 10's per-stage breakdown (all sim-tagged).
#[derive(Debug, Clone, Copy, Default)]
pub struct HostBreakdown {
    pub get_row_number: Duration,
    pub initialize_buffer: Duration,
    pub assign_values: Duration,
    /// H2D transfer + kernel + D2H transfer.
    pub kernel_execution: Duration,
}

impl HostBreakdown {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn total(&self) -> Duration {
        self.get_row_number + self.initialize_buffer + self.assign_values
            + self.kernel_execution
    }

    /// Stage shares of the total (for the Fig. 10 stacked bars).
    pub fn shares(&self) -> [(&'static str, f64); 4] {
        let t = self.total().as_secs_f64().max(1e-12);
        [
            ("Get Row Number", self.get_row_number.as_secs_f64() / t),
            ("Initialize Buffer", self.initialize_buffer.as_secs_f64() / t),
            ("Assign Values", self.assign_values.as_secs_f64() / t),
            ("Kernel Execution", self.kernel_execution.as_secs_f64() / t),
        ]
    }
}

impl HostModel {
    /// Build the Fig. 10 breakdown for a local-mode run.
    ///
    /// The stages run strictly in sequence (paper §3.4.1: "all these
    /// stages must execute in sequence, and there is no overlap among
    /// them").
    pub fn local_breakdown(
        &self,
        cfg: &PiperConfig,
        raw_bytes: usize,
        rows: usize,
        kernel: Duration,
    ) -> HostBreakdown {
        let out_bytes = rows * cfg.schema.binary_row_bytes();
        let decoded_bytes = rows * cfg.schema.binary_row_bytes();

        // 1. Get Row Number — UTF-8 scans the file; binary divides sizes.
        let get_row_number = match cfg.input {
            InputFormat::Utf8 => Duration::from_secs_f64(raw_bytes as f64 / self.scan_bps),
            InputFormat::Binary => Duration::from_micros(5),
        };

        // 2. Initialize Buffer — first-touch of input + output buffers.
        let init_bytes = raw_bytes + out_bytes;
        let initialize_buffer =
            Duration::from_secs_f64(init_bytes as f64 / self.buffer_init_bps);

        // 3. Assign Values — fill the input buffer. If the host decodes
        //    (Fig. 7c), this is where the per-byte decode cost lands.
        let assign_values = match (cfg.mode, cfg.input) {
            (Mode::LocalDecodeInHost, InputFormat::Utf8) => {
                Duration::from_secs_f64(raw_bytes as f64 / self.host_decode_bps)
            }
            _ => Duration::from_secs_f64(raw_bytes as f64 / self.memcpy_bps),
        };

        // 4. Kernel Execution — H2D + kernel + D2H.
        let h2d_bytes = match (cfg.mode, cfg.input) {
            (Mode::LocalDecodeInHost, InputFormat::Utf8) => decoded_bytes,
            _ => raw_bytes,
        };
        let transfer = Duration::from_secs_f64(
            (h2d_bytes as f64 + out_bytes as f64) / self.pcie_bps,
        );
        let kernel_execution = transfer + kernel;

        HostBreakdown { get_row_number, initialize_buffer, assign_values, kernel_execution }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Modulus;

    fn mk(mode: Mode, input: InputFormat) -> PiperConfig {
        PiperConfig::paper(mode, input, Modulus::VOCAB_5K)
    }

    #[test]
    fn buffer_init_dominates_for_large_inputs() {
        // Paper Fig. 10: Initialize Buffer is a large share in both modes.
        let hm = HostModel::default();
        let cfg = mk(Mode::LocalDecodeInKernel, InputFormat::Binary);
        let raw = 8_200_000_000usize; // 8.2 GB binary
        let rows = 46_000_000;
        let hb = hm.local_breakdown(&cfg, raw, rows, Duration::from_secs_f64(2.6));
        let init_share = hb.initialize_buffer.as_secs_f64() / hb.total().as_secs_f64();
        assert!(init_share > 0.4, "init share {init_share}");
    }

    #[test]
    fn decode_in_host_assign_values_explodes() {
        let hm = HostModel::default();
        let k = mk(Mode::LocalDecodeInKernel, InputFormat::Utf8);
        let h = mk(Mode::LocalDecodeInHost, InputFormat::Utf8);
        let raw = 1_000_000_000usize;
        let rows = 4_200_000;
        let bk = hm.local_breakdown(&k, raw, rows, Duration::from_secs(2));
        let bh = hm.local_breakdown(&h, raw, rows, Duration::from_secs(1));
        assert!(bh.assign_values > 10 * bk.assign_values);
    }

    #[test]
    fn binary_row_count_is_free() {
        let hm = HostModel::default();
        let cfg = mk(Mode::LocalDecodeInKernel, InputFormat::Binary);
        let hb = hm.local_breakdown(&cfg, 1_000_000_000, 6_250_000, Duration::from_secs(1));
        assert!(hb.get_row_number < Duration::from_millis(1));
    }

    #[test]
    fn shares_sum_to_one() {
        let hm = HostModel::default();
        let cfg = mk(Mode::LocalDecodeInKernel, InputFormat::Utf8);
        let hb = hm.local_breakdown(&cfg, 100_000_000, 420_000, Duration::from_secs(1));
        let s: f64 = hb.shares().iter().map(|(_, v)| v).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
