//! The two-loop column-wise dataflow (paper Fig. 5) — functional
//! execution + cycle accounting.
//!
//! Loop ① reads the dataset and builds the per-column vocabularies
//! (Modulus → GenVocab-1 → ApplyVocab-1); loop ② re-reads it and maps
//! every sparse feature through the vocabulary (Modulus → GenVocab-2 →
//! ApplyVocab-2 → StoreData) while the dense chains apply
//! Neg2Zero → Logarithm. All chains run concurrently and the loop's
//! throughput is set by the slowest stage — in UTF-8 mode that is the
//! decode PE ("the operator with the largest II determines the
//! performance of the entire dataflow", §3.3).

use std::time::Duration;

use crate::data::row::ProcessedColumns;
use crate::data::{binary, DecodedRow};
use crate::decode::shard;
use crate::ops::{log1p, DirectVocab, Vocab};
use crate::Result;

use super::memory::MemSystem;
use super::pe::PeChain;
use super::{InputFormat, Mode, PiperConfig};

/// Modeled kernel timing of one PIPER run.
#[derive(Debug, Clone)]
pub struct KernelTiming {
    pub clock_hz: f64,
    pub loop1_cycles: f64,
    pub loop2_cycles: f64,
    /// cycles/row of the bottleneck stage, per loop.
    pub loop1_cpr: f64,
    pub loop2_cpr: f64,
    /// Human-readable bottleneck of each loop.
    pub loop1_bottleneck: &'static str,
    pub loop2_bottleneck: &'static str,
}

impl KernelTiming {
    pub fn total_cycles(&self) -> f64 {
        self.loop1_cycles + self.loop2_cycles
    }

    /// Modeled kernel time (tagged `sim` in all reports).
    pub fn seconds(&self) -> Duration {
        Duration::from_secs_f64(self.total_cycles() / self.clock_hz)
    }
}

/// Functional output + timing of the kernel.
#[derive(Debug)]
pub struct KernelRun {
    pub processed: ProcessedColumns,
    pub vocabs: Vec<DirectVocab>,
    pub timing: KernelTiming,
}

/// Execute the kernel over a raw buffer.
pub fn run_kernel(cfg: &PiperConfig, raw: &[u8]) -> Result<KernelRun> {
    // ---- functional: obtain decoded rows -----------------------------
    // Row-sharded SWAR decode — bit-identical to
    // `ParallelDecoder::with_width(cfg.schema, cfg.decode_width)` at
    // every width (width changes modeled cycles, never rows), so the
    // kernel's functional front end runs at software speed while the
    // cycle model below stays pinned to `cfg.decode_width`.
    let rows: Vec<DecodedRow> = match cfg.input {
        InputFormat::Utf8 => shard::decode_rows(cfg.schema, raw, shard::default_threads()),
        InputFormat::Binary => binary::decode_bytes(raw, cfg.schema)?,
    };
    let n_rows = rows.len();

    // ---- loop 1: build vocabularies (column-wise) ---------------------
    let mut vocabs: Vec<DirectVocab> =
        (0..cfg.schema.num_sparse).map(|_| DirectVocab::new(cfg.modulus.range)).collect();
    for row in &rows {
        for (c, &s) in row.sparse.iter().enumerate() {
            vocabs[c].observe(cfg.modulus.apply(s));
        }
    }
    let unique_total: usize = vocabs.iter().map(|v| v.len()).sum();

    // ---- loop 2: apply vocabularies + finish dense --------------------
    let mut processed = ProcessedColumns::with_schema(cfg.schema);
    processed.labels.reserve(n_rows);
    for c in processed.dense.iter_mut() {
        c.reserve(n_rows);
    }
    for c in processed.sparse.iter_mut() {
        c.reserve(n_rows);
    }
    for row in &rows {
        processed.labels.push(row.label);
        for (c, &d) in row.dense.iter().enumerate() {
            processed.dense[c].push(log1p(d));
        }
        for (c, &s) in row.sparse.iter().enumerate() {
            let idx = vocabs[c]
                .apply(cfg.modulus.apply(s))
                .expect("loop 2 value must have been observed in loop 1");
            processed.sparse[c].push(idx);
        }
    }

    // ---- timing --------------------------------------------------------
    let timing = model_timing(cfg, raw.len(), n_rows, unique_total);

    Ok(KernelRun { processed, vocabs, timing })
}

/// Cycle model of the two loops (DESIGN.md §5).
pub fn model_timing(
    cfg: &PiperConfig,
    raw_bytes: usize,
    n_rows: usize,
    unique_total: usize,
) -> KernelTiming {
    let schema = cfg.schema;
    let placement = cfg.vocab_placement;
    let rows = n_rows.max(1) as f64;

    // Input-side cycles per row.
    let decode_in_kernel =
        cfg.input == InputFormat::Utf8 && cfg.mode != Mode::LocalDecodeInHost;
    let input_cpr = if decode_in_kernel {
        // Decode PE: `decode_width` bytes per cycle over the raw text.
        (raw_bytes as f64 / rows) / cfg.decode_width as f64
    } else {
        // Binary words over the memory lanes; LoadData II = 1 floor.
        let mem = MemSystem::with_lanes(cfg.load_lanes);
        let bytes_per_cycle = mem.bytes_per_kernel_cycle(cfg.clock_hz);
        (schema.binary_row_bytes() as f64 / bytes_per_cycle).max(1.0)
    };

    // Column-side cycles per row: each dataflow serves
    // ceil(columns / dataflows) columns at the chain's bottleneck II.
    let sparse_per_flow =
        (schema.num_sparse as f64 / cfg.sparse_dataflows as f64).ceil();
    let dense_per_flow = (schema.num_dense as f64 / cfg.dense_dataflows as f64).ceil();

    // Loop 1: Modulus → GenVocab-1 → ApplyVocab-1. ApplyVocab-1 touches
    // the vocabulary only for *unique* values (it writes the counter), so
    // its effective II amortizes by the unique fraction.
    let unique_frac = unique_total as f64 / (rows * schema.num_sparse.max(1) as f64);
    let chain1 = PeChain::sparse(1);
    let gen_ii = 2.0f64; // GenVocab-1 (paper §3.2)
    let av1_eff = placement.vocab_ii() * unique_frac;
    let chain1_ii = gen_ii.max(av1_eff).max(1.0);
    let loop1_sparse_cpr = sparse_per_flow * chain1_ii;
    let (loop1_cpr, loop1_bottleneck) = if input_cpr >= loop1_sparse_cpr {
        (input_cpr, if decode_in_kernel { "Decode" } else { "LoadData" })
    } else {
        (loop1_sparse_cpr, "GenVocab/ApplyVocab-1")
    };

    // Loop 2: sparse chain reads the vocabulary for *every* value; dense
    // chain is II=1.
    let chain2 = PeChain::sparse(2);
    let loop2_sparse_cpr = sparse_per_flow * chain2.bottleneck_ii(placement);
    let loop2_dense_cpr = dense_per_flow * PeChain::dense().bottleneck_ii(placement);
    let column_cpr = loop2_sparse_cpr.max(loop2_dense_cpr);
    let (loop2_cpr, loop2_bottleneck) = if input_cpr >= column_cpr {
        (input_cpr, if decode_in_kernel { "Decode" } else { "LoadData" })
    } else if loop2_sparse_cpr >= loop2_dense_cpr {
        (loop2_sparse_cpr, "ApplyVocab-2")
    } else {
        (loop2_dense_cpr, "Dense chain")
    };

    let fill = (chain1.fill_latency() + chain2.fill_latency()) as f64;
    KernelTiming {
        clock_hz: cfg.clock_hz,
        loop1_cycles: rows * loop1_cpr + fill,
        loop2_cycles: rows * loop2_cpr + fill,
        loop1_cpr,
        loop2_cpr,
        loop1_bottleneck,
        loop2_bottleneck,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, utf8, Schema, SynthDataset};
    use crate::ops::Modulus;

    fn cfg(mode: Mode, input: InputFormat, m: Modulus) -> PiperConfig {
        PiperConfig::paper(mode, input, m)
    }

    #[test]
    fn utf8_mode_is_decode_bound() {
        let c = cfg(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K);
        let t = model_timing(&c, 240 * 1000, 1000, 26 * 100);
        assert_eq!(t.loop1_bottleneck, "Decode");
        assert_eq!(t.loop2_bottleneck, "Decode");
        // 240 B/row at 4 B/cycle = 60 cycles/row per loop
        assert!((t.loop1_cpr - 60.0).abs() < 1e-9);
    }

    #[test]
    fn binary_mode_is_vocab_bound() {
        let c = cfg(Mode::Network, InputFormat::Binary, Modulus::VOCAB_5K);
        let t = model_timing(&c, 160 * 1000, 1000, 26 * 100);
        assert_eq!(t.loop2_bottleneck, "ApplyVocab-2");
        // ceil(26/13)=2 columns per flow × II 2 = 4 cycles/row
        assert!((t.loop2_cpr - 4.0).abs() < 1e-9, "{}", t.loop2_cpr);
    }

    #[test]
    fn hbm_vocab_raises_loop2_cost() {
        let small = cfg(Mode::Network, InputFormat::Binary, Modulus::VOCAB_5K);
        let large = cfg(Mode::Network, InputFormat::Binary, Modulus::VOCAB_1M);
        let ts = model_timing(&small, 160_000, 1000, 26 * 100);
        let tl = model_timing(&large, 160_000, 1000, 26 * 100);
        assert!(tl.loop2_cpr > 4.0 * ts.loop2_cpr, "HBM sharing should dominate loop 2");
    }

    #[test]
    fn decode_in_host_removes_decode_bottleneck() {
        let mut c = cfg(Mode::LocalDecodeInHost, InputFormat::Utf8, Modulus::VOCAB_5K);
        c.mode = Mode::LocalDecodeInHost;
        let t = model_timing(&c, 240_000, 1000, 26 * 100);
        assert_ne!(t.loop1_bottleneck, "Decode");
        assert!(t.loop1_cpr < 60.0);
    }

    #[test]
    fn functional_loop2_never_misses_vocab() {
        // Every loop-2 lookup hits (observed in loop 1) — run end to end.
        let mut c = cfg(Mode::Network, InputFormat::Utf8, Modulus::new(101));
        c.schema = Schema::new(2, 3);
        let mut scfg = SynthConfig::small(150);
        scfg.schema = c.schema;
        let ds = SynthDataset::generate(scfg);
        let raw = utf8::encode_dataset(&ds);
        let run = run_kernel(&c, &raw).unwrap();
        assert_eq!(run.processed.num_rows(), 150);
        // indices are dense in 0..vocab_len per column
        for (c_idx, v) in run.vocabs.iter().enumerate() {
            let max = run.processed.sparse[c_idx].iter().copied().max().unwrap();
            assert!((max as usize) < v.len());
        }
    }

    #[test]
    fn wider_decode_scales_cpr() {
        let mut c = cfg(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K);
        c.decode_width = 8;
        let t8 = model_timing(&c, 240_000, 1000, 2600);
        c.decode_width = 1;
        let t1 = model_timing(&c, 240_000, 1000, 2600);
        assert!((t1.loop1_cpr / t8.loop1_cpr - 8.0).abs() < 1e-9);
    }
}
