//! Processing-element catalogue with the paper's initiation intervals
//! (§3.2). A PE's *initiation interval* (II) is the minimum number of
//! clock cycles between successive input launches in the pipelined
//! design; for a streaming PE processing `n` items, modeled cycles are
//! `fill_latency + II × n`, and a chain of PEs overlaps so the chain's
//! throughput is set by its slowest member.

use super::memory::VocabPlacement;

/// PE kinds of paper Fig. 5 / §3.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeKind {
    /// Load from DDR/HBM/network. II = 1.
    LoadData,
    /// UTF-8 decode; consumes `width` bytes per cycle (Script 1).
    Decode,
    /// Dense `x<0 ? 0 : x`. II = 1.
    Neg2Zero,
    /// Dense `log(x+1)`. II = 1.
    Logarithm,
    /// Sparse positive modulus. II = 1.
    Modulus,
    /// Loop-1 unique filter (bitmap). II = 2.
    GenVocab1,
    /// Loop-2 pass-through (rate-matched to GenVocab-1). II = 2.
    GenVocab2,
    /// Loop-1 vocabulary write (counter). II depends on placement.
    ApplyVocab1,
    /// Loop-2 vocabulary read. II depends on placement.
    ApplyVocab2,
    /// Combine dataflows and write out. II = 1.
    StoreData,
}

impl PeKind {
    /// The paper's II for this PE given the vocabulary placement
    /// (§3.2: GenVocab II=2; ApplyVocab II=2 on-chip, ~15 off-chip
    /// random, →1 with round-robin HBM channels, §4.4.6).
    pub fn ii(&self, vocab: VocabPlacement) -> f64 {
        match self {
            PeKind::LoadData
            | PeKind::Neg2Zero
            | PeKind::Logarithm
            | PeKind::Modulus
            | PeKind::StoreData
            | PeKind::Decode => 1.0,
            PeKind::GenVocab1 | PeKind::GenVocab2 => 2.0,
            PeKind::ApplyVocab1 | PeKind::ApplyVocab2 => vocab.vocab_ii(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PeKind::LoadData => "LoadData",
            PeKind::Decode => "Decode",
            PeKind::Neg2Zero => "Neg2Zero",
            PeKind::Logarithm => "Logarithm",
            PeKind::Modulus => "Modulus",
            PeKind::GenVocab1 => "GenVocab-1",
            PeKind::GenVocab2 => "GenVocab-2",
            PeKind::ApplyVocab1 => "ApplyVocab-1",
            PeKind::ApplyVocab2 => "ApplyVocab-2",
            PeKind::StoreData => "StoreData",
        }
    }

    /// Pipeline fill latency (cycles before the first output) — small
    /// constants; they matter only for tiny inputs.
    pub fn fill_latency(&self) -> u64 {
        match self {
            PeKind::Decode => 8,
            PeKind::ApplyVocab1 | PeKind::ApplyVocab2 => 4,
            _ => 2,
        }
    }

    /// Cycles for this PE to stream `items` inputs.
    pub fn stream_cycles(&self, items: u64, vocab: VocabPlacement) -> f64 {
        self.fill_latency() as f64 + self.ii(vocab) * items as f64
    }
}

/// A chain of PEs processing the same item stream (one feature column's
/// dataflow). Pipelined: throughput = slowest II; latency adds fills.
#[derive(Debug, Clone)]
pub struct PeChain {
    pub pes: Vec<PeKind>,
}

impl PeChain {
    /// The sparse-column chain for loop `1` or `2` (paper Fig. 5).
    pub fn sparse(loop_idx: u8) -> Self {
        let pes = match loop_idx {
            1 => vec![PeKind::Modulus, PeKind::GenVocab1, PeKind::ApplyVocab1],
            2 => vec![PeKind::Modulus, PeKind::GenVocab2, PeKind::ApplyVocab2, PeKind::StoreData],
            _ => panic!("loop index must be 1 or 2"),
        };
        PeChain { pes }
    }

    /// The dense-column chain (only active in loop 2 — loop 1 just
    /// streams past dense features).
    pub fn dense() -> Self {
        PeChain { pes: vec![PeKind::Neg2Zero, PeKind::Logarithm, PeKind::StoreData] }
    }

    /// Slowest II in the chain — the chain's cycles-per-item.
    pub fn bottleneck_ii(&self, vocab: VocabPlacement) -> f64 {
        self.pes.iter().map(|p| p.ii(vocab)).fold(0.0, f64::max)
    }

    /// Total fill latency.
    pub fn fill_latency(&self) -> u64 {
        self.pes.iter().map(|p| p.fill_latency()).sum()
    }

    /// Cycles to stream `items` through the pipelined chain.
    pub fn stream_cycles(&self, items: u64, vocab: VocabPlacement) -> f64 {
        self.fill_latency() as f64 + self.bottleneck_ii(vocab) * items as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_iis() {
        let sram = VocabPlacement::Sram;
        assert_eq!(PeKind::LoadData.ii(sram), 1.0);
        assert_eq!(PeKind::GenVocab1.ii(sram), 2.0);
        assert_eq!(PeKind::ApplyVocab2.ii(sram), 2.0);
        // HBM single-stream random access ≈ 15 cycles (paper §3.2)
        let hbm1 = VocabPlacement::Hbm { latency: 15, channels: 1, sharers: 1 };
        assert_eq!(PeKind::ApplyVocab2.ii(hbm1), 15.0);
        // Round-robin over ≥latency channels hides it (paper §4.4.6)
        let hbm32 = VocabPlacement::Hbm { latency: 15, channels: 32, sharers: 1 };
        assert_eq!(PeKind::ApplyVocab2.ii(hbm32), 1.0);
    }

    #[test]
    fn chain_bottleneck() {
        let c = PeChain::sparse(1);
        assert_eq!(c.bottleneck_ii(VocabPlacement::Sram), 2.0);
        let d = PeChain::dense();
        assert_eq!(d.bottleneck_ii(VocabPlacement::Sram), 1.0);
    }

    #[test]
    fn stream_cycles_scale_linearly() {
        let c = PeChain::sparse(2);
        let v = VocabPlacement::Sram;
        let a = c.stream_cycles(1000, v);
        let b = c.stream_cycles(2000, v);
        assert!((b - a - 2.0 * 1000.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn bad_loop_index_panics() {
        PeChain::sparse(3);
    }
}
