//! Network-attached streaming model (paper Fig. 7d / §3.4.2).
//!
//! With the FPGA TCP/IP stack the dataset streams directly into the
//! dataflow: data movement fully overlaps kernel execution, so the
//! end-to-end time is the *maximum* of line-rate streaming and kernel
//! time, not their sum — and there is no host buffer to initialize. The
//! same model backs the real-TCP implementation in [`crate::net`], which
//! measures the functional path on loopback and reports the modeled
//! 100 Gbps figure alongside (tagged `sim`).

use std::time::Duration;

use super::PiperConfig;

/// Network parameters of the paper's deployment (100 Gbps NIC-class
/// link, hardware TCP stack).
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Line rate in bytes/second (100 Gbps = 12.5 GB/s).
    pub line_rate_bps: f64,
    /// Connection setup / teardown.
    pub setup: Duration,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel { line_rate_bps: 12.5e9, setup: Duration::from_millis(1) }
    }
}

impl NetworkModel {
    /// End-to-end streaming time: input streaming, kernel execution and
    /// output streaming all overlap in the fully-pipelined design.
    pub fn e2e(&self, in_bytes: usize, out_bytes: usize, kernel: Duration) -> Duration {
        let stream_in = in_bytes as f64 / self.line_rate_bps;
        let stream_out = out_bytes as f64 / self.line_rate_bps;
        let wire = stream_in.max(stream_out);
        self.setup + Duration::from_secs_f64(wire.max(kernel.as_secs_f64()))
    }
}

/// Modeled network-mode end-to-end time for a PIPER run. The dataset is
/// re-streamed for each of the two loops when decoding in-kernel from
/// UTF-8 (the FPGA cannot hold larger-than-memory datasets — that is the
/// point of streaming), which the kernel time already accounts for since
/// streaming overlaps compute.
pub fn stream_time(cfg: &PiperConfig, raw_bytes: usize, kernel: Duration) -> Duration {
    let model = NetworkModel::default();
    // Two loops ⇒ the input crosses the wire twice.
    let out_bytes = raw_bytes; // upper bound; output ≤ input size
    model.e2e(raw_bytes * 2, out_bytes, kernel)
        + Duration::from_secs_f64(0.0 * cfg.clock_hz.recip()) // keep cfg in signature
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{InputFormat, Mode};
    use crate::ops::Modulus;

    #[test]
    fn kernel_bound_when_kernel_slow() {
        let m = NetworkModel::default();
        let t = m.e2e(1_000_000, 1_000_000, Duration::from_secs(10));
        assert!((t.as_secs_f64() - 10.001).abs() < 1e-3);
    }

    #[test]
    fn wire_bound_when_kernel_fast() {
        let m = NetworkModel::default();
        let t = m.e2e(12_500_000_000, 100, Duration::from_millis(1));
        assert!((t.as_secs_f64() - 1.001).abs() < 1e-2);
    }

    #[test]
    fn stream_time_counts_two_loops() {
        let cfg = PiperConfig::paper(Mode::Network, InputFormat::Binary, Modulus::VOCAB_5K);
        // kernel negligible ⇒ wire-bound at 2× input bytes
        let t = stream_time(&cfg, 12_500_000_000, Duration::from_millis(1));
        assert!((t.as_secs_f64() - 2.001).abs() < 0.01, "{t:?}");
    }
}
