//! Tabular schema: label + dense + sparse columns.

/// Column counts for a Criteo-style tabular dataset.
///
/// The paper's dataset has 1 label, 13 dense (signed decimal integers,
/// e.g. click counts) and 26 sparse (8-hex-digit hashed categoricals)
/// columns. Other tabular datasets (MovieLens, Yelp, ... — paper §5) map
/// onto the same shape with different counts, so both are parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schema {
    /// Number of dense (numerical) feature columns.
    pub num_dense: usize,
    /// Number of sparse (categorical, hex-hashed) feature columns.
    pub num_sparse: usize,
}

impl Schema {
    /// The Criteo Kaggle shape used throughout the paper: 13 dense + 26
    /// sparse.
    pub const CRITEO: Schema = Schema { num_dense: 13, num_sparse: 26 };

    pub fn new(num_dense: usize, num_sparse: usize) -> Self {
        Schema { num_dense, num_sparse }
    }

    /// Total feature columns excluding the label.
    pub fn num_features(&self) -> usize {
        self.num_dense + self.num_sparse
    }

    /// Total columns including the label.
    pub fn num_columns(&self) -> usize {
        1 + self.num_features()
    }

    /// Bytes per row in the decoded binary format: every value is a
    /// 32-bit little-endian word (label, dense..., sparse...).
    pub fn binary_row_bytes(&self) -> usize {
        4 * self.num_columns()
    }
}

impl Default for Schema {
    fn default() -> Self {
        Schema::CRITEO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn criteo_shape() {
        let s = Schema::CRITEO;
        assert_eq!(s.num_features(), 39);
        assert_eq!(s.num_columns(), 40);
        assert_eq!(s.binary_row_bytes(), 160);
    }

    #[test]
    fn custom_shape() {
        let s = Schema::new(2, 3);
        assert_eq!(s.num_columns(), 6);
        assert_eq!(s.binary_row_bytes(), 24);
    }
}
