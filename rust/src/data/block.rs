//! Column-major decoded-chunk storage — the engine's chunk currency.
//!
//! A [`RowBlock`] holds one decoded chunk as three contiguous buffers:
//! `labels`, a flat column-major `dense` plane and a flat column-major
//! `sparse` plane (stride = allocated row capacity). This replaces the
//! per-row `Vec<DecodedRow>` representation on every hot path: a Criteo
//! chunk of 64K rows costs **three** live allocations instead of ~128K,
//! and GenVocab/ApplyVocab scan real column slices instead of pointer-
//! chasing row objects (the cache-hostile row materialization the DPP
//! literature blames for CPU preprocessing stalls).
//!
//! Blocks are reusable: [`RowBlock::clear`] keeps the allocation, so the
//! engine decodes every chunk of a pass into the same scratch block.
//! [`DecodedRow`] remains as a test/convenience *view*
//! ([`RowBlock::row`], [`RowBlock::to_rows`], [`RowBlock::from_rows`]).

use super::row::DecodedRow;
use super::schema::Schema;

/// A sink that accepts assembled rows as field slices — implemented by
/// [`RowBlock`] (the engine's column-major currency), [`RowWindow`] (a
/// disjoint row range of a block, the parallel decoder's target) and
/// `Vec<DecodedRow>` (the one-shot decoders' row-wise view). The
/// decoder's hot loop is generic over this, so every sink monomorphizes
/// to the same zero-alloc inner loop.
pub trait PushRow {
    fn push_row(&mut self, label: i32, dense: &[i32], sparse: &[u32]);
}

impl PushRow for RowBlock {
    #[inline]
    fn push_row(&mut self, label: i32, dense: &[i32], sparse: &[u32]) {
        RowBlock::push_row(self, label, dense, sparse);
    }
}

impl PushRow for Vec<DecodedRow> {
    #[inline]
    fn push_row(&mut self, label: i32, dense: &[i32], sparse: &[u32]) {
        self.push(DecodedRow { label, dense: dense.to_vec(), sparse: sparse.to_vec() });
    }
}

/// One decoded chunk in column-major layout.
///
/// Invariants: `dense.len() == num_dense * cap`,
/// `sparse.len() == num_sparse * cap`, `labels.len() == len <= cap`;
/// column `c` of the dense plane lives at `dense[c*cap .. c*cap+len]`.
#[derive(Debug, Clone)]
pub struct RowBlock {
    schema: Schema,
    /// Allocated row capacity — the stride between consecutive columns.
    cap: usize,
    /// Rows currently stored.
    len: usize,
    labels: Vec<i32>,
    dense: Vec<i32>,
    sparse: Vec<u32>,
}

impl RowBlock {
    /// An empty block (no allocation until the first push).
    pub fn new(schema: Schema) -> Self {
        RowBlock { schema, cap: 0, len: 0, labels: Vec::new(), dense: Vec::new(), sparse: Vec::new() }
    }

    /// An empty block with room for `rows` rows per column.
    pub fn with_capacity(schema: Schema, rows: usize) -> Self {
        let mut b = Self::new(schema);
        if rows > 0 {
            b.grow(rows);
        }
        b
    }

    pub fn schema(&self) -> Schema {
        self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated row capacity (the column stride).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Forget the rows, keep the allocation — the reuse hook the engine
    /// calls before decoding each chunk into the same scratch block.
    pub fn clear(&mut self) {
        self.len = 0;
        self.labels.clear();
    }

    pub fn labels(&self) -> &[i32] {
        &self.labels
    }

    /// Dense column `c` as a contiguous slice over the stored rows.
    #[inline]
    pub fn dense_col(&self, c: usize) -> &[i32] {
        debug_assert!(c < self.schema.num_dense);
        &self.dense[c * self.cap..c * self.cap + self.len]
    }

    /// Sparse column `c` as a contiguous slice over the stored rows.
    #[inline]
    pub fn sparse_col(&self, c: usize) -> &[u32] {
        debug_assert!(c < self.schema.num_sparse);
        &self.sparse[c * self.cap..c * self.cap + self.len]
    }

    /// Re-stride the planes to a larger capacity, preserving contents.
    fn grow(&mut self, min_cap: usize) {
        let new_cap = min_cap.max(self.cap * 2).max(16);
        let (nd, ns) = (self.schema.num_dense, self.schema.num_sparse);
        let mut dense = vec![0i32; nd * new_cap];
        for c in 0..nd {
            dense[c * new_cap..c * new_cap + self.len]
                .copy_from_slice(&self.dense[c * self.cap..c * self.cap + self.len]);
        }
        let mut sparse = vec![0u32; ns * new_cap];
        for c in 0..ns {
            sparse[c * new_cap..c * new_cap + self.len]
                .copy_from_slice(&self.sparse[c * self.cap..c * self.cap + self.len]);
        }
        self.dense = dense;
        self.sparse = sparse;
        self.cap = new_cap;
        self.labels.reserve(new_cap.saturating_sub(self.labels.len()));
    }

    /// Append one row from field slices (the UTF-8 assembler's scratch
    /// row). The transpose cost — one strided write per column — is paid
    /// here, once, instead of on every later pass over the data.
    #[inline]
    pub fn push_row(&mut self, label: i32, dense: &[i32], sparse: &[u32]) {
        debug_assert_eq!(dense.len(), self.schema.num_dense);
        debug_assert_eq!(sparse.len(), self.schema.num_sparse);
        if self.len == self.cap {
            self.grow(self.cap + 1);
        }
        let (cap, r) = (self.cap, self.len);
        self.labels.push(label);
        for (c, &v) in dense.iter().enumerate() {
            self.dense[c * cap + r] = v;
        }
        for (c, &v) in sparse.iter().enumerate() {
            self.sparse[c * cap + r] = v;
        }
        self.len += 1;
    }

    /// Bulk-append rows from a row-aligned binary buffer (the decoded
    /// binary format: one little-endian 32-bit word per field, `label,
    /// dense..., sparse...`). One sequential pass over `raw`; each word
    /// goes straight to its column plane — no per-row allocation.
    pub fn append_binary(&mut self, raw: &[u8]) {
        let rb = self.schema.binary_row_bytes();
        debug_assert_eq!(raw.len() % rb, 0, "binary append must be row-aligned");
        let n = raw.len() / rb;
        if self.len + n > self.cap {
            self.grow(self.len + n);
        }
        let cap = self.cap;
        let (nd, ns) = (self.schema.num_dense, self.schema.num_sparse);
        self.labels.reserve(n);
        for (r, row) in raw.chunks_exact(rb).enumerate() {
            let dst = self.len + r;
            let word = |i: usize| {
                u32::from_le_bytes([row[4 * i], row[4 * i + 1], row[4 * i + 2], row[4 * i + 3]])
            };
            self.labels.push(word(0) as i32);
            for c in 0..nd {
                self.dense[c * cap + dst] = word(1 + c) as i32;
            }
            for c in 0..ns {
                self.sparse[c * cap + dst] = word(1 + nd + c);
            }
        }
        self.len += n;
    }

    /// Split the block's *next* rows into disjoint, independently
    /// writable windows of the given sizes — the safe seam the
    /// row-sharded parallel decoder writes through. The block grows (if
    /// needed) and commits `sum(counts)` rows up front; each returned
    /// [`RowWindow`] owns `&mut` column slices over its row range only,
    /// so shard threads fill their ranges concurrently with no
    /// post-merge memmove and the column-major stride-=-capacity
    /// invariant holds throughout. Callers are expected to fill every
    /// window completely; a window dropped short zero-fills its
    /// remaining rows (FillMissing semantics) at drop time, so the
    /// fully-filled fast path never pays a redundant plane memset.
    pub fn disjoint_row_windows(&mut self, counts: &[usize]) -> Vec<RowWindow<'_>> {
        let total: usize = counts.iter().sum();
        let start = self.len;
        if start + total > self.cap {
            self.grow(start + total);
        }
        self.labels.resize(start + total, 0);
        self.len = start + total;
        let cap = self.cap;
        let (nd, ns) = (self.schema.num_dense, self.schema.num_sparse);

        let mut windows: Vec<RowWindow<'_>> = counts
            .iter()
            .map(|&c| RowWindow {
                rows: c,
                filled: 0,
                labels: &mut [],
                dense: Vec::with_capacity(nd),
                sparse: Vec::with_capacity(ns),
            })
            .collect();

        let mut rest: &mut [i32] = &mut self.labels[start..start + total];
        for (w, &c) in windows.iter_mut().zip(counts) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(c);
            w.labels = head;
            rest = tail;
        }
        // Window rows must be zero-initialized (FillMissing semantics for
        // anything a shard leaves untouched) — the planes may hold stale
        // values from a previous chunk decoded into the same scratch.
        let mut plane: &mut [i32] = &mut self.dense;
        for _ in 0..nd {
            let (col, tail) = std::mem::take(&mut plane).split_at_mut(cap);
            plane = tail;
            let mut rest: &mut [i32] = &mut col[start..start + total];
            for (w, &c) in windows.iter_mut().zip(counts) {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(c);
                w.dense.push(head);
                rest = tail;
            }
        }
        let mut plane: &mut [u32] = &mut self.sparse;
        for _ in 0..ns {
            let (col, tail) = std::mem::take(&mut plane).split_at_mut(cap);
            plane = tail;
            let mut rest: &mut [u32] = &mut col[start..start + total];
            for (w, &c) in windows.iter_mut().zip(counts) {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(c);
                w.sparse.push(head);
                rest = tail;
            }
        }
        windows
    }

    /// Close the gaps left by partially-filled windows from a prior
    /// [`Self::disjoint_row_windows`] call: window `i` committed
    /// `committed[i]` rows starting at `start` but only filled
    /// `filled[i]` of them (a dropping error policy skipped the rest).
    /// Each window's filled prefix slides down to be contiguous and the
    /// block's length shrinks to the rows actually present. Costs one
    /// `copy_within` per column per displaced window; a fully-filled
    /// decode never calls this.
    pub fn compact_rows(&mut self, start: usize, committed: &[usize], filled: &[usize]) {
        assert_eq!(committed.len(), filled.len());
        let total: usize = committed.iter().sum();
        assert!(start + total == self.len, "compact_rows must cover the latest windows");
        let cap = self.cap;
        let (mut src, mut dst) = (start, start);
        for (&c, &f) in committed.iter().zip(filled) {
            assert!(f <= c, "window filled {f} of {c} rows");
            if f > 0 && dst != src {
                self.labels.copy_within(src..src + f, dst);
                for col in 0..self.schema.num_dense {
                    self.dense.copy_within(col * cap + src..col * cap + src + f, col * cap + dst);
                }
                for col in 0..self.schema.num_sparse {
                    self.sparse.copy_within(col * cap + src..col * cap + src + f, col * cap + dst);
                }
            }
            src += c;
            dst += f;
        }
        self.labels.truncate(dst);
        self.len = dst;
    }

    /// Row `r` as an owned [`DecodedRow`] — test/convenience view.
    pub fn row(&self, r: usize) -> DecodedRow {
        assert!(r < self.len, "row {r} out of {} rows", self.len);
        DecodedRow {
            label: self.labels[r],
            dense: (0..self.schema.num_dense).map(|c| self.dense_col(c)[r]).collect(),
            sparse: (0..self.schema.num_sparse).map(|c| self.sparse_col(c)[r]).collect(),
        }
    }

    /// Materialize all rows — test/convenience view.
    pub fn to_rows(&self) -> Vec<DecodedRow> {
        (0..self.len).map(|r| self.row(r)).collect()
    }

    /// Build a block from rows — test/convenience constructor.
    pub fn from_rows(rows: &[DecodedRow], schema: Schema) -> Self {
        let mut b = Self::with_capacity(schema, rows.len());
        for row in rows {
            b.push_row(row.label, &row.dense, &row.sparse);
        }
        b
    }
}

/// One disjoint row range of a [`RowBlock`], independently writable —
/// what [`RowBlock::disjoint_row_windows`] hands each decode shard.
/// Holds `&mut` slices of the parent's column planes covering exactly
/// this window's rows, so concurrent shard writes are safe Rust, not a
/// synchronization argument.
#[derive(Debug)]
pub struct RowWindow<'a> {
    /// Rows this window must receive.
    rows: usize,
    /// Rows received so far.
    filled: usize,
    labels: &'a mut [i32],
    /// Per dense column: this window's row range of the column plane.
    dense: Vec<&'a mut [i32]>,
    /// Per sparse column: this window's row range of the column plane.
    sparse: Vec<&'a mut [u32]>,
}

impl RowWindow<'_> {
    /// Rows this window was sized for.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Rows pushed so far.
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Has the window received every row it was sized for?
    pub fn is_full(&self) -> bool {
        self.filled == self.rows
    }
}

impl Drop for RowWindow<'_> {
    /// Unfilled rows must read as FillMissing zeros even though the
    /// parent's planes may hold stale values from a previous chunk
    /// decoded into the same scratch block. Zeroing only the shortfall
    /// here keeps the common fully-filled case free of any extra plane
    /// pass (every pushed row already wrote all its cells).
    fn drop(&mut self) {
        if self.filled == self.rows {
            return;
        }
        let short = self.filled..self.rows;
        self.labels[short.clone()].fill(0);
        for col in &mut self.dense {
            col[short.clone()].fill(0);
        }
        for col in &mut self.sparse {
            col[short.clone()].fill(0);
        }
    }
}

impl PushRow for RowWindow<'_> {
    #[inline]
    fn push_row(&mut self, label: i32, dense: &[i32], sparse: &[u32]) {
        let r = self.filled;
        assert!(r < self.rows, "row window overflow: {} rows committed", self.rows);
        self.labels[r] = label;
        for (col, &v) in self.dense.iter_mut().zip(dense) {
            col[r] = v;
        }
        for (col, &v) in self.sparse.iter_mut().zip(sparse) {
            col[r] = v;
        }
        self.filled += 1;
    }
}

/// Logical equality: same schema, same rows — capacity/stride excluded.
impl PartialEq for RowBlock {
    fn eq(&self, other: &Self) -> bool {
        self.schema == other.schema
            && self.len == other.len
            && self.labels == other.labels
            && (0..self.schema.num_dense).all(|c| self.dense_col(c) == other.dense_col(c))
            && (0..self.schema.num_sparse).all(|c| self.sparse_col(c) == other.sparse_col(c))
    }
}

impl Eq for RowBlock {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, SynthConfig, SynthDataset};

    #[test]
    fn push_row_round_trips() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            DecodedRow { label: 1, dense: vec![-3, 4], sparse: vec![7, 8, 9] },
            DecodedRow { label: 0, dense: vec![5, 6], sparse: vec![1, 2, 3] },
        ];
        let b = RowBlock::from_rows(&rows, schema);
        assert_eq!(b.num_rows(), 2);
        assert_eq!(b.labels(), &[1, 0]);
        assert_eq!(b.dense_col(0), &[-3, 5]);
        assert_eq!(b.sparse_col(2), &[9, 3]);
        assert_eq!(b.to_rows(), rows);
    }

    #[test]
    fn append_binary_matches_row_decode() {
        let ds = SynthDataset::generate(SynthConfig::small(97));
        let raw = binary::encode_dataset(&ds);
        let mut b = RowBlock::new(ds.schema());
        // Append in two unequal halves, cut at a row boundary.
        let rb = ds.schema().binary_row_bytes();
        let cut = 31 * rb;
        b.append_binary(&raw[..cut]);
        b.append_binary(&raw[cut..]);
        assert_eq!(b.to_rows(), ds.rows);
    }

    #[test]
    fn growth_preserves_columns() {
        let schema = Schema::new(1, 1);
        let mut b = RowBlock::with_capacity(schema, 2);
        for i in 0..100i32 {
            b.push_row(i, &[i * 2], &[i as u32 * 3]);
        }
        assert_eq!(b.num_rows(), 100);
        assert!(b.capacity() >= 100);
        assert_eq!(b.dense_col(0)[99], 198);
        assert_eq!(b.sparse_col(0)[0], 0);
        assert_eq!(b.labels()[50], 50);
    }

    #[test]
    fn clear_keeps_allocation() {
        let schema = Schema::CRITEO;
        let ds = SynthDataset::generate(SynthConfig::small(40));
        let mut b = RowBlock::from_rows(&ds.rows, schema);
        let cap = b.capacity();
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap, "clear must not free the planes");
        b.append_binary(&binary::encode_dataset(&ds));
        assert_eq!(b.to_rows(), ds.rows);
    }

    #[test]
    fn disjoint_windows_fill_disjoint_ranges() {
        let schema = Schema::new(2, 2);
        let ds = SynthDataset::generate(SynthConfig { schema, ..SynthConfig::small(30) });
        let mut whole = RowBlock::from_rows(&ds.rows, schema);

        let mut sharded = RowBlock::new(schema);
        let counts = [11usize, 0, 7, 12];
        let mut windows = sharded.disjoint_row_windows(&counts);
        assert_eq!(windows.len(), 4);
        // Fill out of order — disjointness means order cannot matter.
        let mut start_of = [0usize; 4];
        let mut acc = 0;
        for (i, &c) in counts.iter().enumerate() {
            start_of[i] = acc;
            acc += c;
        }
        for w_idx in [2usize, 0, 3, 1] {
            let w = &mut windows[w_idx];
            for r in 0..counts[w_idx] {
                let row = &ds.rows[start_of[w_idx] + r];
                w.push_row(row.label, &row.dense, &row.sparse);
            }
            assert!(w.is_full());
        }
        drop(windows);
        assert_eq!(sharded.num_rows(), 30);
        assert_eq!(sharded, whole);

        // Appending after a window pass continues normally.
        sharded.push_row(7, &[1, 2], &[3, 4]);
        whole.push_row(7, &[1, 2], &[3, 4]);
        assert_eq!(sharded, whole);
    }

    #[test]
    fn disjoint_windows_zero_stale_plane_values() {
        let schema = Schema::new(1, 1);
        let mut b = RowBlock::with_capacity(schema, 8);
        for i in 0..8i32 {
            b.push_row(i, &[i + 100], &[i as u32 + 200]);
        }
        b.clear();
        // Leave the second window untouched: its rows must read as
        // FillMissing zeros, not the stale values above.
        let mut windows = b.disjoint_row_windows(&[2, 3]);
        windows[0].push_row(1, &[2], &[3]);
        windows[0].push_row(4, &[5], &[6]);
        drop(windows);
        assert_eq!(b.num_rows(), 5);
        assert_eq!(b.labels(), &[1, 4, 0, 0, 0]);
        assert_eq!(b.dense_col(0), &[2, 5, 0, 0, 0]);
        assert_eq!(b.sparse_col(0), &[3, 6, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "row window overflow")]
    fn overfilled_window_panics() {
        let schema = Schema::new(1, 1);
        let mut b = RowBlock::new(schema);
        let mut windows = b.disjoint_row_windows(&[1]);
        windows[0].push_row(1, &[1], &[1]);
        windows[0].push_row(2, &[2], &[2]);
    }

    #[test]
    fn equality_ignores_capacity() {
        let schema = Schema::new(1, 1);
        let rows = vec![DecodedRow { label: 1, dense: vec![2], sparse: vec![3] }];
        let a = RowBlock::from_rows(&rows, schema);
        let mut b = RowBlock::with_capacity(schema, 1000);
        b.push_row(1, &[2], &[3]);
        assert_eq!(a, b);
    }
}
