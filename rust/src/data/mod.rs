//! Dataset substrate: Criteo-format schema, synthetic generator, and the
//! two on-disk encodings the paper evaluates (raw UTF-8 and decoded
//! binary).
//!
//! The paper's dataset (Criteo Kaggle, 11 GB raw / 8.2 GB binary) is
//! license- and size-gated, so [`synth`] generates byte-compatible rows:
//! one label, `num_dense` signed decimal integers, `num_sparse` 8-hex-digit
//! hashes, tab-separated, `\n`-terminated, empty string for missing values
//! (paper Fig. 4).

pub mod binary;
pub mod block;
pub mod row;
pub mod schema;
pub mod synth;
pub mod utf8;

pub use block::{PushRow, RowBlock, RowWindow};
pub use row::{DecodedRow, ProcessedRow};
pub use schema::Schema;
pub use synth::{RowGen, SynthConfig, SynthDataset};
