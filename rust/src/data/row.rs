//! Row representations at the two pipeline boundaries.

use super::schema::Schema;

/// A row after `Decode` + `FillMissing` (paper Table 1): every field is a
/// 32-bit word. Dense features are signed (minus sign in the raw text),
/// sparse features are the 32-bit values of the 8-hex-digit hashes.
/// Missing fields have already been filled with 0.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodedRow {
    pub label: i32,
    pub dense: Vec<i32>,
    pub sparse: Vec<u32>,
}

impl DecodedRow {
    pub fn zeroed(schema: Schema) -> Self {
        DecodedRow {
            label: 0,
            dense: vec![0; schema.num_dense],
            sparse: vec![0; schema.num_sparse],
        }
    }

    /// Flatten to the 32-bit word order of the binary format:
    /// `label, dense..., sparse...`.
    pub fn to_words(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(1 + self.dense.len() + self.sparse.len());
        out.push(self.label as u32);
        out.extend(self.dense.iter().map(|&d| d as u32));
        out.extend(self.sparse.iter().copied());
        out
    }
}

/// A fully preprocessed row, ready for training: dense features are
/// `log(1+max(x,0))` floats, sparse features are vocabulary indices.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessedRow {
    pub label: i32,
    pub dense: Vec<f32>,
    pub sparse: Vec<u32>,
}

/// Column-major storage for a fully preprocessed dataset — what the
/// training consumer (`crate::train`, pjrt feature) slices minibatches
/// from, and what
/// `Concatenate` (paper Table 1) assembles back into rows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProcessedColumns {
    pub labels: Vec<i32>,
    /// `dense[c][r]` — one Vec per dense column.
    pub dense: Vec<Vec<f32>>,
    /// `sparse[c][r]` — one Vec per sparse column (vocabulary indices).
    pub sparse: Vec<Vec<u32>>,
}

impl ProcessedColumns {
    pub fn with_schema(schema: Schema) -> Self {
        ProcessedColumns {
            labels: Vec::new(),
            dense: vec![Vec::new(); schema.num_dense],
            sparse: vec![Vec::new(); schema.num_sparse],
        }
    }

    pub fn num_rows(&self) -> usize {
        self.labels.len()
    }

    /// Assemble row `r` (the row-wise output ML training needs — paper
    /// §2.4 "most ML models require row-wise input").
    pub fn row(&self, r: usize) -> ProcessedRow {
        ProcessedRow {
            label: self.labels[r],
            dense: self.dense.iter().map(|c| c[r]).collect(),
            sparse: self.sparse.iter().map(|c| c[r]).collect(),
        }
    }

    /// Append a row (used by row-wise producers like the CPU baseline).
    pub fn push_row(&mut self, row: &ProcessedRow) {
        self.labels.push(row.label);
        for (c, v) in self.dense.iter_mut().zip(&row.dense) {
            c.push(*v);
        }
        for (c, v) in self.sparse.iter_mut().zip(&row.sparse) {
            c.push(*v);
        }
    }

    /// Concatenate another column block after this one (the CFR stage).
    pub fn extend_from(&mut self, other: &ProcessedColumns) {
        self.labels.extend_from_slice(&other.labels);
        for (c, o) in self.dense.iter_mut().zip(&other.dense) {
            c.extend_from_slice(o);
        }
        for (c, o) in self.sparse.iter_mut().zip(&other.sparse) {
            c.extend_from_slice(o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_roundtrip_order() {
        let r = DecodedRow { label: 1, dense: vec![-3, 4], sparse: vec![0xdead, 7] };
        assert_eq!(r.to_words(), vec![1, (-3i32) as u32, 4, 0xdead, 7]);
    }

    #[test]
    fn columns_row_roundtrip() {
        let schema = Schema::new(2, 1);
        let mut cols = ProcessedColumns::with_schema(schema);
        let r0 = ProcessedRow { label: 1, dense: vec![0.5, 1.5], sparse: vec![3] };
        let r1 = ProcessedRow { label: 0, dense: vec![2.5, 3.5], sparse: vec![9] };
        cols.push_row(&r0);
        cols.push_row(&r1);
        assert_eq!(cols.num_rows(), 2);
        assert_eq!(cols.row(0), r0);
        assert_eq!(cols.row(1), r1);
    }

    #[test]
    fn extend_concatenates_in_order() {
        let schema = Schema::new(1, 1);
        let mut a = ProcessedColumns::with_schema(schema);
        let mut b = ProcessedColumns::with_schema(schema);
        a.push_row(&ProcessedRow { label: 1, dense: vec![1.0], sparse: vec![1] });
        b.push_row(&ProcessedRow { label: 0, dense: vec![2.0], sparse: vec![2] });
        a.extend_from(&b);
        assert_eq!(a.num_rows(), 2);
        assert_eq!(a.row(1).sparse, vec![2]);
    }
}
