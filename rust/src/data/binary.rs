//! The decoded binary on-disk format (paper "binary dataset").
//!
//! Every field of every row is one 32-bit little-endian word in
//! `label, dense..., sparse...` order. Missing values are already 0
//! (FillMissing applied at decode time). The Criteo dataset is 11 GB raw
//! vs 8.2 GB binary — with this 160 B/row layout on 40 columns our
//! encoded/decoded size ratio matches (~1.3×).

use crate::Result;
use std::io::Write as _;
use std::path::Path;

use super::row::DecodedRow;
use super::schema::Schema;
use super::synth::SynthDataset;

/// Pack decoded rows to binary bytes.
pub fn encode_rows(rows: &[DecodedRow], schema: Schema) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * schema.binary_row_bytes());
    for row in rows {
        debug_assert_eq!(row.dense.len(), schema.num_dense);
        debug_assert_eq!(row.sparse.len(), schema.num_sparse);
        out.extend_from_slice(&row.label.to_le_bytes());
        for &d in &row.dense {
            out.extend_from_slice(&d.to_le_bytes());
        }
        for &s in &row.sparse {
            out.extend_from_slice(&s.to_le_bytes());
        }
    }
    out
}

/// Pack a synthetic dataset to binary bytes.
pub fn encode_dataset(ds: &SynthDataset) -> Vec<u8> {
    encode_rows(&ds.rows, ds.schema())
}

/// Unpack binary bytes into decoded rows (the CPU-side "Binary Unpack"
/// operator of paper Table 4 — on the FPGA this is a no-op since the PEs
/// consume 32-bit words directly).
pub fn decode_bytes(raw: &[u8], schema: Schema) -> Result<Vec<DecodedRow>> {
    let rb = schema.binary_row_bytes();
    anyhow::ensure!(
        raw.len() % rb == 0,
        "binary buffer length {} is not a multiple of row size {rb}",
        raw.len()
    );
    let mut rows = Vec::with_capacity(raw.len() / rb);
    for chunk in raw.chunks_exact(rb) {
        let mut words = chunk
            .chunks_exact(4)
            .map(|w| u32::from_le_bytes([w[0], w[1], w[2], w[3]]));
        // rb = 4 × num_columns, so each chunk holds exactly the label,
        // dense and sparse words — the length ensure above covers it.
        let label = words.next().expect("row chunk holds >= 1 word") as i32;
        let dense: Vec<i32> =
            (&mut words).take(schema.num_dense).map(|w| w as i32).collect();
        let sparse: Vec<u32> = words.collect();
        rows.push(DecodedRow { label, dense, sparse });
    }
    Ok(rows)
}

/// Number of rows in a binary buffer — `file size / row size`, the cheap
/// row counting the paper's Config III exploits (§4.2.1: "we simply
/// obtain the file size and calculate it").
pub fn count_rows(raw: &[u8], schema: Schema) -> usize {
    raw.len() / schema.binary_row_bytes()
}

/// Write the binary dataset to a file.
pub fn write_file(ds: &SynthDataset, path: &Path) -> Result<()> {
    let bytes = encode_dataset(ds);
    let mut f = std::fs::File::create(path)?;
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthConfig;

    #[test]
    fn roundtrip() {
        let ds = SynthDataset::generate(SynthConfig::small(77));
        let raw = encode_dataset(&ds);
        let rows = decode_bytes(&raw, ds.schema()).unwrap();
        assert_eq!(rows, ds.rows);
    }

    #[test]
    fn count_rows_from_size() {
        let ds = SynthDataset::generate(SynthConfig::small(41));
        let raw = encode_dataset(&ds);
        assert_eq!(count_rows(&raw, ds.schema()), 41);
    }

    #[test]
    fn rejects_misaligned_buffer() {
        let schema = Schema::CRITEO;
        assert!(decode_bytes(&[0u8; 7], schema).is_err());
    }

    #[test]
    fn negative_dense_survive() {
        let row = DecodedRow { label: 1, dense: vec![-123], sparse: vec![5] };
        let schema = Schema::new(1, 1);
        let raw = encode_rows(std::slice::from_ref(&row), schema);
        let back = decode_bytes(&raw, schema).unwrap();
        assert_eq!(back[0], row);
    }

    #[test]
    fn binary_smaller_than_utf8_for_criteo_shape() {
        let ds = SynthDataset::generate(SynthConfig::small(500));
        let bin = encode_dataset(&ds).len();
        let utf = super::super::utf8::encode_dataset(&ds).len();
        // paper: 11 GB UTF-8 vs 8.2 GB binary ⇒ utf8 is larger.
        assert!(utf > bin, "utf8 {utf} should exceed binary {bin}");
    }
}
