//! Deterministic synthetic Criteo-format dataset generator.
//!
//! Substitution for the gated Criteo Kaggle dataset (DESIGN.md §6). The
//! generator reproduces the statistical properties that matter to the
//! pipeline under study:
//!
//! * **sparse columns** are Zipf-skewed hashes with per-column cardinality
//!   (Criteo columns range from tens to millions of distinct values), so
//!   `GenVocab`'s unique-filtering and the 5K-vs-1M vocabulary regimes
//!   behave like the real data;
//! * **dense columns** are integer counts with negative values and a
//!   realistic missing-rate, so `Neg2Zero`/`Logarithm`/`FillMissing` all
//!   exercise their interesting branches;
//! * the raw encoding is byte-compatible with the paper's Fig. 4 (UTF-8,
//!   tab-separated, 8-hex-digit sparse values, empty string = missing).

use crate::util::{XorShift64, Zipf};

use super::row::DecodedRow;
use super::schema::Schema;

/// Knobs for the synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub schema: Schema,
    pub rows: usize,
    pub seed: u64,
    /// Zipf exponent for sparse columns (1.05–1.3 matches web-scale logs).
    pub zipf_exponent: f64,
    /// Distinct raw hash values per sparse column before Modulus.
    /// Per-column cardinality cycles through `base`, `base*4`, `base*16`…
    /// capped at `max_cardinality`, mimicking Criteo's wide spread.
    pub base_cardinality: u64,
    pub max_cardinality: u64,
    /// Probability a feature (dense or sparse) is missing (empty field).
    pub missing_rate: f64,
    /// Probability a dense value is negative.
    pub negative_rate: f64,
    /// Scale of dense count values.
    pub dense_scale: f64,
}

impl SynthConfig {
    /// Named presets for the other tabular datasets the paper's §5 says
    /// PIPER's modular dataflows adapt to — differing column counts and
    /// cardinality spreads, same row grammar.
    pub fn preset(name: &str, rows: usize) -> crate::Result<Self> {
        let mut cfg = Self::small(rows);
        match name {
            // Criteo Kaggle: the paper's default (13 dense / 26 sparse).
            "criteo" => {}
            // MovieLens-style: few columns, small vocabularies
            // (user, movie, tags...), dense = ratings/timestamps.
            "movielens" => {
                cfg.schema = Schema::new(3, 4);
                cfg.base_cardinality = 1_000;
                cfg.max_cardinality = 200_000;
                cfg.zipf_exponent = 1.05;
                cfg.missing_rate = 0.01;
            }
            // Yelp-style reviews: moderate sparse set, skewed businesses.
            "yelp" => {
                cfg.schema = Schema::new(6, 12);
                cfg.base_cardinality = 500;
                cfg.max_cardinality = 2_000_000;
                cfg.zipf_exponent = 1.25;
                cfg.missing_rate = 0.08;
            }
            // Amazon-reviews-style: wide sparse set, huge product space.
            "amazon" => {
                cfg.schema = Schema::new(4, 20);
                cfg.base_cardinality = 4_096;
                cfg.max_cardinality = 10_000_000;
                cfg.zipf_exponent = 1.3;
                cfg.missing_rate = 0.15;
            }
            other => anyhow::bail!(
                "unknown dataset preset `{other}` (criteo|movielens|yelp|amazon)"
            ),
        }
        Ok(cfg)
    }

    pub fn small(rows: usize) -> Self {
        SynthConfig {
            schema: Schema::CRITEO,
            rows,
            seed: 0xC217E0,
            zipf_exponent: 1.15,
            base_cardinality: 64,
            max_cardinality: 2_000_000,
            missing_rate: 0.12,
            negative_rate: 0.04,
            dense_scale: 300.0,
        }
    }
}

/// A generated dataset held as decoded rows plus a per-field missing mask
/// (needed to emit empty UTF-8 fields faithfully).
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub config: SynthConfig,
    pub rows: Vec<DecodedRow>,
    /// `missing[r]` is a bitmask over feature positions
    /// (0..num_dense are dense, then sparse), bit set = field was missing.
    pub missing: Vec<u64>,
}

/// Streaming row generator — the same deterministic row stream
/// [`SynthDataset::generate`] materializes, yielded one row at a time so
/// a [`crate::pipeline::Source`] can produce arbitrarily large datasets
/// with bounded memory. Re-creating the generator replays the identical
/// stream (deterministic in `config.seed`).
#[derive(Debug, Clone)]
pub struct RowGen {
    config: SynthConfig,
    sparse_cols: Vec<(Zipf, u64)>,
    rng: XorShift64,
    emitted: usize,
}

impl RowGen {
    pub fn new(config: SynthConfig) -> Self {
        assert!(
            config.schema.num_features() <= 64,
            "missing mask packs into u64; widen if you need >64 features"
        );
        let mut root = XorShift64::new(config.seed);
        let schema = config.schema;

        // Per-column samplers. Each sparse column owns a cardinality and a
        // salt so its hash space doesn't collide with other columns'.
        let mut card = config.base_cardinality;
        let sparse_cols: Vec<(Zipf, u64)> = (0..schema.num_sparse)
            .map(|c| {
                let z = Zipf::new(card.max(1), config.zipf_exponent);
                let salt = 0x9E3779B9u64.wrapping_mul(c as u64 + 1);
                card = (card * 4).min(config.max_cardinality);
                if card == config.max_cardinality {
                    card = config.base_cardinality; // cycle the spread
                }
                (z, salt)
            })
            .collect();

        let rng = root.fork(1);
        RowGen { config, sparse_cols, rng, emitted: 0 }
    }

    pub fn schema(&self) -> Schema {
        self.config.schema
    }

    /// Rows remaining in the stream.
    pub fn remaining(&self) -> usize {
        self.config.rows - self.emitted
    }

    /// Next row plus its per-field missing mask; `None` after
    /// `config.rows` rows. Allocates the row's field `Vec`s — use
    /// [`Self::next_row_into`] on hot paths.
    pub fn next_row(&mut self) -> Option<(DecodedRow, u64)> {
        let mut row = DecodedRow { label: 0, dense: Vec::new(), sparse: Vec::new() };
        self.next_row_into(&mut row).map(|mask| (row, mask))
    }

    /// Generate the next row into a caller-owned scratch row (cleared
    /// and refilled; its buffers are reused across calls), returning
    /// the per-field missing mask. The alloc-free form of
    /// [`Self::next_row`] — a [`crate::pipeline::SynthSource`] keeps
    /// one persistent scratch row so synthetic-input benches measure
    /// decode, not generator allocation.
    pub fn next_row_into(&mut self, row: &mut DecodedRow) -> Option<u64> {
        if self.emitted >= self.config.rows {
            return None;
        }
        self.emitted += 1;
        let schema = self.config.schema;
        let rng = &mut self.rng;
        let mut mask = 0u64;
        row.label = i32::from(rng.chance(0.25));

        row.dense.clear();
        row.dense.reserve(schema.num_dense);
        for d in 0..schema.num_dense {
            if rng.chance(self.config.missing_rate) {
                mask |= 1 << d;
                row.dense.push(0); // FillMissing default (paper: 0)
                continue;
            }
            // log-normal-ish counts: exp of a half-gaussian, scaled.
            let mag = (rng.gaussian().abs() * self.config.dense_scale) as i64;
            let v = if rng.chance(self.config.negative_rate) { -mag - 1 } else { mag };
            row.dense.push(v as i32);
        }

        row.sparse.clear();
        row.sparse.reserve(schema.num_sparse);
        for (s, (zipf, salt)) in self.sparse_cols.iter().enumerate() {
            if rng.chance(self.config.missing_rate) {
                mask |= 1 << (schema.num_dense + s);
                row.sparse.push(0);
                continue;
            }
            let rank = zipf.sample(rng);
            // Hash the rank into a 32-bit value — what Criteo's
            // anonymization does ("hashed string values", paper §4.1).
            let h = splitmix(rank ^ salt);
            row.sparse.push((h >> 32) as u32);
        }

        Some(mask)
    }
}

impl SynthDataset {
    /// Generate the dataset. Deterministic in `config.seed`.
    pub fn generate(config: SynthConfig) -> Self {
        let mut gen = RowGen::new(config.clone());
        let mut rows = Vec::with_capacity(config.rows);
        let mut missing = Vec::with_capacity(config.rows);
        while let Some((row, mask)) = gen.next_row() {
            rows.push(row);
            missing.push(mask);
        }
        SynthDataset { config, rows, missing }
    }

    pub fn schema(&self) -> Schema {
        self.config.schema
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Was feature `f` (dense-then-sparse index) of row `r` missing?
    pub fn is_missing(&self, r: usize, f: usize) -> bool {
        self.missing[r] & (1 << f) != 0
    }
}

/// splitmix64 finalizer — a good standalone integer hash.
#[inline]
pub fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_gen_streams_the_same_rows() {
        let cfg = SynthConfig::small(150);
        let ds = SynthDataset::generate(cfg.clone());
        let mut gen = RowGen::new(cfg);
        assert_eq!(gen.remaining(), 150);
        for r in 0..150 {
            let (row, mask) = gen.next_row().unwrap();
            assert_eq!(row, ds.rows[r], "row {r}");
            assert_eq!(mask, ds.missing[r], "mask {r}");
        }
        assert!(gen.next_row().is_none());
        assert_eq!(gen.remaining(), 0);
    }

    #[test]
    fn next_row_into_reuses_scratch_and_matches() {
        let cfg = SynthConfig::small(120);
        let ds = SynthDataset::generate(cfg.clone());
        let mut gen = RowGen::new(cfg);
        let mut scratch = DecodedRow { label: 0, dense: Vec::new(), sparse: Vec::new() };
        for r in 0..120 {
            let mask = gen.next_row_into(&mut scratch).unwrap();
            assert_eq!(scratch, ds.rows[r], "row {r}");
            assert_eq!(mask, ds.missing[r], "mask {r}");
        }
        assert!(gen.next_row_into(&mut scratch).is_none());
    }

    #[test]
    fn deterministic_generation() {
        let a = SynthDataset::generate(SynthConfig::small(200));
        let b = SynthDataset::generate(SynthConfig::small(200));
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.missing, b.missing);
    }

    #[test]
    fn shapes_match_schema() {
        let ds = SynthDataset::generate(SynthConfig::small(50));
        assert_eq!(ds.num_rows(), 50);
        for r in &ds.rows {
            assert_eq!(r.dense.len(), 13);
            assert_eq!(r.sparse.len(), 26);
        }
    }

    #[test]
    fn missing_fields_are_zero() {
        let ds = SynthDataset::generate(SynthConfig::small(500));
        let nd = ds.schema().num_dense;
        for (r, row) in ds.rows.iter().enumerate() {
            for d in 0..nd {
                if ds.is_missing(r, d) {
                    assert_eq!(row.dense[d], 0);
                }
            }
            for s in 0..ds.schema().num_sparse {
                if ds.is_missing(r, nd + s) {
                    assert_eq!(row.sparse[s], 0);
                }
            }
        }
    }

    #[test]
    fn missing_rate_roughly_honored() {
        let ds = SynthDataset::generate(SynthConfig::small(2000));
        let total = 2000 * ds.schema().num_features();
        let miss: u32 = ds.missing.iter().map(|m| m.count_ones()).sum();
        let rate = miss as f64 / total as f64;
        assert!((rate - 0.12).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn has_negative_dense_values() {
        let ds = SynthDataset::generate(SynthConfig::small(2000));
        let negs = ds.rows.iter().flat_map(|r| &r.dense).filter(|&&d| d < 0).count();
        assert!(negs > 0, "negative_rate should produce some negatives");
    }

    #[test]
    fn presets_produce_valid_datasets() {
        for name in ["criteo", "movielens", "yelp", "amazon"] {
            let cfg = SynthConfig::preset(name, 80).unwrap();
            let ds = SynthDataset::generate(cfg);
            assert_eq!(ds.num_rows(), 80, "{name}");
            // every preset must survive the full pipeline
            let raw = crate::data::utf8::encode_dataset(&ds);
            let out = crate::decode::ParallelDecoder::new(ds.schema()).decode(&raw);
            assert_eq!(out.rows, ds.rows, "{name} roundtrip");
        }
        assert!(SynthConfig::preset("nope", 10).is_err());
    }

    #[test]
    fn sparse_columns_are_skewed() {
        let ds = SynthDataset::generate(SynthConfig::small(3000));
        // column 0 has base cardinality 64 and zipf skew: top value should
        // cover a large share of the rows.
        let mut counts = std::collections::HashMap::new();
        for (r, row) in ds.rows.iter().enumerate() {
            if !ds.is_missing(r, ds.schema().num_dense) {
                *counts.entry(row.sparse[0]).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().copied().max().unwrap();
        let total: usize = counts.values().sum();
        assert!(max as f64 / total as f64 > 0.10, "head share {max}/{total}");
        assert!(counts.len() <= 64);
    }
}
