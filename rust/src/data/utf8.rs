//! The raw UTF-8 on-disk format (paper Fig. 4).
//!
//! One row = `label \t dense... \t sparse... \n`, where dense values are
//! signed decimal integers, sparse values are 8-hex-digit lowercase
//! hashes, and a missing value is an empty field (two adjacent tabs).
//! Only the byte values `\t`, `\n`, `-`, `0-9`, `a-f` appear (paper §3.2,
//! Decode PE).

use crate::Result;
use std::io::Write as _;
use std::path::Path;

use super::row::DecodedRow;
use super::synth::SynthDataset;

/// Encode one decoded row back to the raw UTF-8 line format.
/// `missing_mask` bit `f` set ⇒ feature `f` (dense-then-sparse order)
/// is emitted as an empty field.
pub fn encode_row(row: &DecodedRow, missing_mask: u64, out: &mut Vec<u8>) {
    // Label is a bare decimal (never missing in Criteo).
    push_decimal(out, row.label as i64);
    for (d, &v) in row.dense.iter().enumerate() {
        out.push(b'\t');
        if missing_mask & (1 << d) == 0 {
            push_decimal(out, v as i64);
        }
    }
    let nd = row.dense.len();
    for (s, &v) in row.sparse.iter().enumerate() {
        out.push(b'\t');
        if missing_mask & (1 << (nd + s)) == 0 {
            push_hex8(out, v);
        }
    }
    out.push(b'\n');
}

/// Encode a whole synthetic dataset to raw UTF-8 bytes.
pub fn encode_dataset(ds: &SynthDataset) -> Vec<u8> {
    // Rough pre-size: ~6 bytes/dense, 9/sparse, 2/label.
    let per_row = 2 + 7 * ds.schema().num_dense + 9 * ds.schema().num_sparse;
    let mut out = Vec::with_capacity(per_row * ds.num_rows());
    for (r, row) in ds.rows.iter().enumerate() {
        encode_row(row, ds.missing[r], &mut out);
    }
    out
}

/// Write the UTF-8 dataset to a file.
pub fn write_file(ds: &SynthDataset, path: &Path) -> Result<()> {
    let bytes = encode_dataset(ds);
    let mut f = std::fs::File::create(path)?;
    f.write_all(&bytes)?;
    Ok(())
}

fn push_decimal(out: &mut Vec<u8>, v: i64) {
    let mut buf = [0u8; 20];
    let mut n = v;
    if n < 0 {
        out.push(b'-');
        n = -n;
    }
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (n % 10) as u8;
        n /= 10;
        if n == 0 {
            break;
        }
    }
    out.extend_from_slice(&buf[i..]);
}

fn push_hex8(out: &mut Vec<u8>, v: u32) {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    for shift in (0..8).rev() {
        out.push(HEX[((v >> (shift * 4)) & 0xf) as usize]);
    }
}

/// Count rows in a raw buffer (the "Get Row Number" host step, Fig. 10)
/// — a SWAR newline popcount, 8 bytes per compare.
pub fn count_rows(raw: &[u8]) -> usize {
    crate::decode::swar::count_newlines(raw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Schema, SynthConfig};

    #[test]
    fn encode_simple_row() {
        let row = DecodedRow { label: 1, dense: vec![-5, 0], sparse: vec![0xdeadbeef] };
        let mut out = Vec::new();
        encode_row(&row, 0, &mut out);
        assert_eq!(out, b"1\t-5\t0\tdeadbeef\n");
    }

    #[test]
    fn encode_missing_fields_are_empty() {
        let row = DecodedRow { label: 0, dense: vec![7, 0], sparse: vec![0, 0x1] };
        // dense[1] missing (bit 1), sparse[0] missing (bit 2)
        let mut out = Vec::new();
        encode_row(&row, 0b110, &mut out);
        assert_eq!(out, b"0\t7\t\t\t00000001\n");
    }

    #[test]
    fn only_legal_bytes_appear() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let raw = encode_dataset(&ds);
        for &b in &raw {
            assert!(
                b == b'\t' || b == b'\n' || b == b'-'
                    || b.is_ascii_digit()
                    || (b'a'..=b'f').contains(&b),
                "illegal byte {b:#x}"
            );
        }
    }

    #[test]
    fn row_count_matches() {
        let ds = SynthDataset::generate(SynthConfig::small(123));
        let raw = encode_dataset(&ds);
        assert_eq!(count_rows(&raw), 123);
    }

    #[test]
    fn field_count_per_row() {
        let mut cfg = SynthConfig::small(10);
        cfg.schema = Schema::new(3, 4);
        let ds = SynthDataset::generate(cfg);
        let raw = encode_dataset(&ds);
        for line in raw.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let tabs = line.iter().filter(|&&b| b == b'\t').count();
            assert_eq!(tabs, 7); // num_features columns ⇒ num_features tabs
        }
    }
}
