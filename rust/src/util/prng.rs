//! Deterministic xorshift64* PRNG.
//!
//! Every randomized piece of the repo (synthetic data, property tests,
//! workload jitter) goes through this generator so runs are exactly
//! reproducible from a seed — `rand` is deliberately not a dependency.

/// xorshift64* generator (Vigna 2016). Passes BigCrush for our purposes
/// (data synthesis), tiny, and copy-free.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        if s == 0 {
            s = 0x9E37_79B9_7F4A_7C15;
        }
        // Scramble the raw seed through two rounds so consecutive seeds
        // (0, 1, 2, ...) do not produce correlated first outputs.
        let mut g = XorShift64 { state: s };
        g.next_u64();
        g.next_u64();
        g
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Approximate standard normal via sum of 12 uniforms (Irwin–Hall).
    /// Good enough for synthetic dense features; avoids libm dependence.
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        let mut acc = 0.0;
        for _ in 0..12 {
            acc += self.f64();
        }
        acc - 6.0
    }

    /// Fork a statistically independent child stream (for per-thread or
    /// per-column generators) without sharing state.
    pub fn fork(&mut self, stream: u64) -> XorShift64 {
        XorShift64::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut g = XorShift64::new(0);
        assert_ne!(g.next_u64(), 0);
    }

    #[test]
    fn below_is_in_range() {
        let mut g = XorShift64::new(7);
        for _ in 0..10_000 {
            let v = g.below(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut g = XorShift64::new(9);
        for _ in 0..10_000 {
            let v = g.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut g = XorShift64::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = XorShift64::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
