//! Zipf-distributed sampler for synthetic sparse features.
//!
//! Criteo's categorical columns are heavily skewed; the paper's 5K vs 1M
//! vocabulary experiments hinge on how many *distinct* values appear and
//! how they are spread. A Zipf(s) sampler over `n` ranks reproduces that
//! skew deterministically.

use super::prng::XorShift64;

/// Zipf sampler using the rejection-inversion method of Hörmann (1996 —
/// the same algorithm used by `rand_distr`). O(1) per sample, supports
/// very large `n` (e.g. 1M ranks) without a precomputed table.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
}

impl Zipf {
    /// Zipf over ranks `1..=n` with exponent `s > 0`, `s != 1` handled too.
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "zipf needs at least one rank");
        assert!(s > 0.0, "zipf exponent must be positive");
        let nf = n as f64;
        let h_integral_x1 = Self::h_integral(1.5, s) - 1.0;
        let h_integral_n = Self::h_integral(nf + 0.5, s);
        Zipf { n: nf, s, h_integral_x1, h_integral_n }
    }

    /// `H(x) = ((x^(1-s)) - 1) / (1-s)`, continuated at s=1 to ln(x).
    fn h_integral(x: f64, s: f64) -> f64 {
        let log_x = x.ln();
        if (1.0 - s).abs() < 1e-9 {
            log_x
        } else {
            ((1.0 - s) * log_x).exp_m1() / (1.0 - s)
        }
    }

    /// `h(x) = x^(-s)`.
    fn h(x: f64, s: f64) -> f64 {
        (-s * x.ln()).exp()
    }

    /// Inverse of `h_integral`.
    fn h_integral_inv(x: f64, s: f64) -> f64 {
        let mut t = x * (1.0 - s);
        if t < -1.0 {
            t = -1.0;
        }
        ((1.0 / (1.0 - s)) * t.ln_1p()).exp()
    }

    /// Draw a rank in `1..=n` (rank 1 is the most frequent).
    pub fn sample(&self, rng: &mut XorShift64) -> u64 {
        loop {
            let u = self.h_integral_n
                + rng.f64() * (self.h_integral_x1 - self.h_integral_n);
            // u is in (h_integral_x1, h_integral_n)
            let x = if (1.0 - self.s).abs() < 1e-9 {
                u.exp()
            } else {
                Self::h_integral_inv(u, self.s)
            };
            let mut k = (x + 0.5).floor();
            if k < 1.0 {
                k = 1.0;
            } else if k > self.n {
                k = self.n;
            }
            // Acceptance test (Hörmann).
            if k - x <= 0.5
                || u >= Self::h_integral(k + 0.5, self.s) - Self::h(k, self.s)
            {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_in_range() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = XorShift64::new(3);
        for _ in 0..20_000 {
            let r = z.sample(&mut rng);
            assert!((1..=1000).contains(&r));
        }
    }

    #[test]
    fn rank_one_dominates() {
        let z = Zipf::new(10_000, 1.2);
        let mut rng = XorShift64::new(4);
        let mut counts = [0u64; 11];
        let n = 100_000;
        for _ in 0..n {
            let r = z.sample(&mut rng);
            if r <= 10 {
                counts[r as usize] += 1;
            }
        }
        // rank-1 should be clearly more frequent than rank-2, which beats rank-4.
        assert!(counts[1] > counts[2]);
        assert!(counts[2] > counts[4]);
        // head should be a meaningful share of the mass for s=1.2
        assert!(counts[1] as f64 / n as f64 > 0.1, "head share {}", counts[1]);
    }

    #[test]
    fn exponent_one_supported() {
        let z = Zipf::new(100, 1.0);
        let mut rng = XorShift64::new(5);
        for _ in 0..5000 {
            let r = z.sample(&mut rng);
            assert!((1..=100).contains(&r));
        }
    }

    #[test]
    fn single_rank_degenerate() {
        let z = Zipf::new(1, 1.5);
        let mut rng = XorShift64::new(6);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 1);
        }
    }
}
