//! Wallclock stopwatch with named laps — used by the measured (CPU
//! baseline) paths and the bench harness.

use std::time::{Duration, Instant};

/// A stopwatch accumulating named laps. Laps with the same name add up,
/// so per-stage times can be collected across repeated calls.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now(), laps: Vec::new() }
    }

    /// Restart the lap timer (does not clear recorded laps).
    pub fn reset(&mut self) {
        self.start = Instant::now();
    }

    /// Record the time since the last `reset`/`lap` under `name` and
    /// restart the lap timer.
    pub fn lap(&mut self, name: &str) -> Duration {
        let d = self.start.elapsed();
        if let Some(entry) = self.laps.iter_mut().find(|(n, _)| n == name) {
            entry.1 += d;
        } else {
            self.laps.push((name.to_string(), d));
        }
        self.start = Instant::now();
        d
    }

    /// Total accumulated time across all laps.
    pub fn total(&self) -> Duration {
        self.laps.iter().map(|(_, d)| *d).sum()
    }

    /// Accumulated time for one lap name (zero if never recorded).
    pub fn get(&self, name: &str) -> Duration {
        self.laps
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    /// All laps in recording order.
    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_by_name() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        sw.lap("a");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.get("a") >= sw.laps()[0].1 - sw.get("a")); // sanity: non-negative
        assert_eq!(sw.total(), sw.get("a") + sw.get("b"));
    }

    #[test]
    fn get_missing_is_zero() {
        let sw = Stopwatch::new();
        assert_eq!(sw.get("nope"), Duration::ZERO);
    }
}
