//! Small shared utilities: deterministic PRNG, Zipf sampler, timing.

pub mod prng;
pub mod zipf;
pub mod timer;

pub use prng::XorShift64;
pub use timer::Stopwatch;
pub use zipf::Zipf;
