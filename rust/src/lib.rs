//! # PIPER — simulated accelerator for tabular ML data preprocessing
//!
//! Reproduction of *"Efficient Tabular Data Preprocessing of ML Pipelines"*
//! (Zhu, Jiang, Alonso — 2024). The paper builds an FPGA dataflow
//! accelerator (PIPER) for the stateful DLRM preprocessing pipeline
//! (decode → hex2int → modulus → gen-vocab → apply-vocab → neg2zero →
//! logarithm → concatenate) and compares it against a 128-core CPU server
//! and a V100 GPU.
//!
//! This crate reproduces the whole system on commodity hardware:
//!
//! * [`data`] — the dataset substrate: Criteo-format schema, a
//!   deterministic synthetic generator, and the UTF-8 / binary on-disk
//!   formats of the paper's Figure 4.
//! * [`decode`] — the byte-at-a-time UTF-8 decoder (paper Fig. 6) and the
//!   4-byte-per-cycle *parallel* decoder (paper Script 1), bit-exact to
//!   each other.
//! * [`ops`] — the operator library of Table 1, the insertion-ordered
//!   vocabulary with mergeable per-thread sub-dictionaries, and the typed
//!   per-column program layer ([`ops::ColumnProgram`] /
//!   [`ops::PipelineSpec`]): different transforms on different columns,
//!   compiled at planning time into per-column fixed-function slots.
//! * [`cpu_baseline`] — Meta's row-partitioned multithreaded pipeline
//!   (Split-Input-File → Generate-Vocab → Apply-Vocab → Concatenate) in
//!   the paper's Configs I/II/III. This baseline is *measured*, not
//!   simulated.
//! * [`accel`] — the PIPER accelerator as a functional + cycle-level
//!   simulator: heterogeneous PEs with the paper's initiation intervals,
//!   FIFO channels, SRAM/HBM vocabulary placement, local (PCIe) and
//!   network-attached modes.
//! * [`gpu_sim`] — the RAPIDS-style column-parallel GPU baseline
//!   (functional column pipeline + V100-calibrated timing model).
//! * [`net`] — network-attached mode over real TCP (loopback): leader
//!   streams raw rows, the accelerator node preprocesses in a pipelined
//!   fashion.
//! * [`service`] — the disaggregated preprocessing service: a
//!   dispatcher splits the input over a worker pool and each
//!   vocabulary column is *owned* by one worker (hash partition), so
//!   index assignment is local to the owner and the whole cluster runs
//!   the fused single-pass dataflow with no global merge barrier.
//! * [`pipeline`] — the composable streaming execution engine: a
//!   [`pipeline::Source`] of raw chunks (in-memory buffer, file, synth
//!   generator, TCP stream) feeds a planned operator graph through any
//!   [`pipeline::Executor`] (CPU baseline, GPU model, the three PIPER
//!   modes) into a [`pipeline::Sink`], with bounded memory and a
//!   [`pipeline::Pipeline`] that is planned once and reused across
//!   submissions. Decoded chunks travel as the column-major, zero-alloc
//!   [`data::RowBlock`]; raw buffers and the decode scratch recycle, so
//!   steady state allocates nothing per chunk. This is the public
//!   execution API; everything else (CLI, coordinator, benches) builds
//!   on it.
//! * `runtime` / `train` — PJRT runtime that loads the AOT-compiled
//!   JAX/Pallas DLRM (`artifacts/*.hlo.txt`) and the training loop that
//!   consumes preprocessed batches (paper Fig. 1 consumer). Both are
//!   gated behind the `pjrt` cargo feature (they need the xla_extension
//!   shared library).
//! * [`coordinator`] — the [`coordinator::Backend`] enumeration and the
//!   one-shot [`coordinator::run_backend`] / [`coordinator::compare`]
//!   drivers, now thin adapters over [`pipeline`].
//! * [`report`] — the table/figure renderers used by `rust/benches/`.
//!
//! Simulated time and measured wallclock are never mixed silently — see
//! [`report::TimeTag`].

pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod cpu_baseline;
pub mod data;
pub mod decode;
pub mod gpu_sim;
pub mod net;
pub mod ops;
pub mod accel;
pub mod pipeline;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod service;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
