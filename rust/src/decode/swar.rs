//! SWAR (SIMD-within-a-register) primitives for the wide-word decode
//! fast path — the software analog of paper Script 1's W-byte
//! combination decoder, at W = 8.
//!
//! The hardware decoder classifies all W bytes of a word in one cycle
//! and folds the partial fields combinationally. In software the same
//! structure becomes: load a `u64`, compute one branch-free *special*
//! mask (everything that is not a hex nibble), and fold the nibble runs
//! between specials in word-sized gulps ([`pack_hex`] / [`fold_dec`])
//! instead of one LUT lookup per byte. The per-byte state machines in
//! [`super::scalar`] stay untouched as the bit-exactness oracle, and
//! the modeled cycle counts never come from this module — cycles are
//! a property of the *hardware* width, not of how fast the simulator
//! decodes (see EXPERIMENTS.md §Decode for the sweep methodology).
//!
//! Every helper here is **exact for all 256 byte values** — including
//! bytes ≥ 0x80 and the false-positive-prone neighbors of `\0` that the
//! classic `(w - 0x01…) & !w & 0x80…` zero test misclassifies. The
//! equivalence suite (`tests/decode_equivalence.rs`) pins SWAR output
//! bit-identical to the scalar oracle on adversarial byte soup, not
//! just well-formed tables.

/// `0x01` in every byte lane.
pub const LO: u64 = 0x0101_0101_0101_0101;
/// `0x80` in every byte lane — the lane-flag bit all masks here use.
pub const HI: u64 = 0x8080_8080_8080_8080;

/// Broadcast one byte to all 8 lanes.
#[inline]
pub fn splat(b: u8) -> u64 {
    LO.wrapping_mul(b as u64)
}

/// Exact per-lane zero test: bit 7 of lane `i` is set iff byte `i` of
/// `v` is zero. Uses the carry-free Hacker's Delight form rather than
/// the cheaper `(v - LO) & !v & HI`, whose borrow propagation flags a
/// `0x01` lane that follows a zero lane (a real miss for adversarial
/// input: `"\t\x08"` would classify `\x08` as a tab).
#[inline]
pub fn zero_bytes(v: u64) -> u64 {
    !(((v & !HI).wrapping_add(!HI)) | v | !HI)
}

/// Per-lane equality with `b`: bit 7 of lane `i` set iff byte `i == b`.
#[inline]
pub fn eq_bytes(w: u64, b: u8) -> u64 {
    zero_bytes(w ^ splat(b))
}

/// Per-lane `v >= c` for lanes already known < 0x80 and `c <= 0x80`.
/// Adding `0x80 - c` cannot carry across lanes (max 0x7f + 0x80 = 0xff).
#[inline]
fn ge7(v: u64, c: u8) -> u64 {
    v.wrapping_add(splat(0x80 - c)) & HI
}

/// Per-lane mask of hex-nibble bytes (`0-9`, `a-f`), exact for all byte
/// values: lanes with bit 7 set in the input are excluded before the
/// range checks (a `0xb5` lane must not alias `0x35`'s digit range).
#[inline]
pub fn nibble_mask(w: u64) -> u64 {
    let hib = w & HI;
    let v = w & !HI;
    let digit = ge7(v, b'0') & !ge7(v, b'9' + 1);
    let letter = ge7(v, b'a') & !ge7(v, b'f' + 1);
    (digit | letter) & !hib
}

/// Per-lane nibble *values* for lanes that hold hex nibbles: digits map
/// via the low nibble, letters add 9 (`'a'` = 0x61 → 1 + 9 = 10). Lanes
/// that are not nibbles produce garbage the caller must mask out.
#[inline]
pub fn nibble_values(w: u64) -> u64 {
    (w & splat(0x0f)) + ((w >> 6) & LO).wrapping_mul(9)
}

/// Pack 8 nibble-value lanes into a `u32`, lane 0 (the first byte of
/// the stream) becoming the most significant nibble — the wide-word
/// form of eight successive `reg = (reg << 4) | n` steps. Unused high
/// lanes must be zero (they become trailing zero nibbles the caller
/// shifts away).
#[inline]
pub fn pack_hex(v: u64) -> u32 {
    // Pairs → quads → octet: each step halves the lane count by gluing
    // lane 2i (high nibble side) to lane 2i+1.
    let y = ((v << 4) | (v >> 8)) & 0x00ff_00ff_00ff_00ff;
    let z = ((y << 8) | (y >> 16)) & 0x0000_ffff_0000_ffff;
    (((z << 16) | (z >> 32)) & 0xffff_ffff) as u32
}

/// Fold 8 decimal-digit-value lanes into their value, lane 0 most
/// significant — the wide-word form of eight `reg = reg*10 + d` steps
/// (Lemire's two-multiply digit gather). Lanes may legally hold values
/// up to 15: the scalar state machine accumulates hex letters in
/// decimal columns as `reg*10 + 12` and so must we; every intermediate
/// lane stays below its carry bound (pair ≤ 165, total ≤ 15·11111111).
/// Callers place shorter runs in the *high* lanes and zero the low
/// ones, which act as leading zero digits.
#[inline]
pub fn fold_dec(v: u64) -> u32 {
    let v = v.wrapping_mul(10).wrapping_add(v >> 8);
    const MASK: u64 = 0x0000_00ff_0000_00ff;
    const MUL1: u64 = 100 + (1_000_000u64 << 32);
    const MUL2: u64 = 1 + (10_000u64 << 32);
    let r = (v & MASK)
        .wrapping_mul(MUL1)
        .wrapping_add(((v >> 16) & MASK).wrapping_mul(MUL2));
    (r >> 32) as u32
}

/// Load up to 8 bytes little-endian, zero-padding the high lanes.
#[inline]
pub fn load_le(bytes: &[u8]) -> u64 {
    debug_assert!(bytes.len() <= 8);
    let mut buf = [0u8; 8];
    buf[..bytes.len()].copy_from_slice(bytes);
    u64::from_le_bytes(buf)
}

/// Powers of ten for the decimal gulp (`10^8` still fits a `u32`).
pub const POW10: [u32; 9] =
    [1, 10, 100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000];

/// Count `\n` bytes — the SWAR form of the row-count prefix pass
/// (one popcount per word instead of one compare per byte).
pub fn count_newlines(bytes: &[u8]) -> usize {
    let mut n = 0usize;
    let mut words = bytes.chunks_exact(8);
    for w in words.by_ref() {
        let w = u64::from_le_bytes(w.try_into().expect("chunks_exact(8)"));
        n += eq_bytes(w, b'\n').count_ones() as usize;
    }
    n + words.remainder().iter().filter(|&&b| b == b'\n').count()
}

/// First `\n` at or after `from` (SWAR memchr).
pub fn find_newline(bytes: &[u8], from: usize) -> Option<usize> {
    let mut i = from;
    while i + 8 <= bytes.len() {
        let w = u64::from_le_bytes(bytes[i..i + 8].try_into().expect("8-byte window"));
        let m = eq_bytes(w, b'\n');
        if m != 0 {
            return Some(i + (m.trailing_zeros() >> 3) as usize);
        }
        i += 8;
    }
    bytes[i..].iter().position(|&b| b == b'\n').map(|p| i + p)
}

/// Last `\n` in `bytes`, if any.
pub fn rfind_newline(bytes: &[u8]) -> Option<usize> {
    let mut i = bytes.len();
    let tail = bytes.len() % 8;
    if let Some(p) = bytes[i - tail..].iter().rposition(|&b| b == b'\n') {
        return Some(i - tail + p);
    }
    i -= tail;
    while i >= 8 {
        let w = u64::from_le_bytes(bytes[i - 8..i].try_into().expect("8-byte window"));
        let m = eq_bytes(w, b'\n');
        if m != 0 {
            return Some(i - 8 + (63 - m.leading_zeros() as usize) / 8);
        }
        i -= 8;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_is_exact_per_lane() {
        // The classic borrow-propagating test fails on [0x00, 0x01]; the
        // exact form must not.
        let w = u64::from_le_bytes([0x00, 0x01, 0xff, 0x80, 0x00, 0x7f, 0x01, 0x00]);
        let m = zero_bytes(w);
        for lane in 0..8 {
            let byte = (w >> (8 * lane)) as u8;
            let flagged = m & (0x80u64 << (8 * lane)) != 0;
            assert_eq!(flagged, byte == 0, "lane {lane} byte {byte:#x}");
        }
    }

    #[test]
    fn eq_bytes_matches_naive_on_all_values() {
        for b in [b'\t', b'\n', b'-', 0u8, 0x80, 0xff] {
            for base in 0..=255u8 {
                let bytes = [base, b, base.wrapping_add(1), 0, 0xff, b, 0x80, base];
                let m = eq_bytes(u64::from_le_bytes(bytes), b);
                for (lane, &x) in bytes.iter().enumerate() {
                    let flagged = m & (0x80u64 << (8 * lane)) != 0;
                    assert_eq!(flagged, x == b, "b={b:#x} lane={lane} x={x:#x}");
                }
            }
        }
    }

    #[test]
    fn nibble_mask_matches_classifier_for_all_bytes() {
        for b in 0..=255u8 {
            let bytes = [b; 8];
            let m = nibble_mask(u64::from_le_bytes(bytes));
            let is_nibble = b.is_ascii_digit() || (b'a'..=b'f').contains(&b);
            let expect = if is_nibble { HI } else { 0 };
            assert_eq!(m, expect, "byte {b:#x}");
        }
    }

    #[test]
    fn nibble_values_map_hex_digits() {
        let w = u64::from_le_bytes(*b"09afbc18");
        let v = nibble_values(w);
        let expect = [0u8, 9, 10, 15, 11, 12, 1, 8];
        for (lane, &e) in expect.iter().enumerate() {
            assert_eq!((v >> (8 * lane)) as u8, e, "lane {lane}");
        }
    }

    #[test]
    fn pack_hex_packs_in_stream_order() {
        let v = u64::from_le_bytes([1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(pack_hex(v), 0x1234_5678);
        // Short runs: zero-padded high lanes become trailing nibbles.
        let v = u64::from_le_bytes([0xd, 0xe, 0xa, 0, 0, 0, 0, 0]);
        assert_eq!(pack_hex(v) >> (4 * 5), 0xdea);
    }

    #[test]
    fn fold_dec_matches_horner() {
        let v = u64::from_le_bytes([1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(fold_dec(v), 12_345_678);
        // Hex letters in a decimal column accumulate as values > 9,
        // exactly like the scalar `reg*10 + n` loop.
        let v = u64::from_le_bytes([15, 9, 0, 0, 0, 0, 0, 0]);
        let mut reg = 0u32;
        for d in [15u32, 9, 0, 0, 0, 0, 0, 0] {
            reg = reg.wrapping_mul(10).wrapping_add(d);
        }
        assert_eq!(fold_dec(v), reg);
    }

    #[test]
    fn newline_scan_matches_naive() {
        let data: Vec<u8> = (0..1000u32)
            .map(|i| if i % 7 == 3 { b'\n' } else { (i % 251) as u8 })
            .collect();
        assert_eq!(count_newlines(&data), data.iter().filter(|&&b| b == b'\n').count());
        let naive_first = data.iter().position(|&b| b == b'\n');
        assert_eq!(find_newline(&data, 0), naive_first);
        for from in [0usize, 1, 7, 63, 997, 1000] {
            let naive = data[from..].iter().position(|&b| b == b'\n').map(|p| from + p);
            assert_eq!(find_newline(&data, from), naive, "from {from}");
        }
        assert_eq!(rfind_newline(&data), data.iter().rposition(|&b| b == b'\n'));
        assert_eq!(find_newline(b"abc", 0), None);
        assert_eq!(rfind_newline(b"abc"), None);
        assert_eq!(rfind_newline(b""), None);
    }
}
