//! Row-sharded parallel UTF-8 decode: split a raw chunk at `\n`
//! boundaries and decode the shards on scoped threads, each writing
//! into a disjoint row range of one shared [`RowBlock`].
//!
//! This is the software counterpart of scaling Piper's PE array: where
//! the paper widens the combination decoder (Script 1) to raise
//! bytes/cycle, the engine raises bytes/second by running the SWAR
//! decoder on `N` row shards at once. The split is cheap and exact:
//!
//! 1. a SWAR newline scan picks shard boundaries at `\n` bytes near the
//!    even byte-split points (shards always hold whole rows);
//! 2. a prefix row-count pass ([`swar::count_newlines`]) sizes each
//!    shard's row range — every `\n` emits exactly one row, so the
//!    count is exact before any field is parsed;
//! 3. [`RowBlock::disjoint_row_windows`] commits the rows and hands
//!    each thread `&mut` column slices over its range only — no
//!    post-merge memmove, no locks, and the column-major
//!    stride-=-capacity invariant holds throughout.
//!
//! Bit-exactness falls out of the state machine: the assembler's
//! carried state is fully reset after every `\n`, so a fresh
//! [`RowAssembler`] per shard reproduces the sequential decode exactly,
//! for *any* input bytes (pinned against the scalar oracle by
//! `tests/decode_equivalence.rs`). Illegal-byte offsets are rebased per
//! shard ([`RowAssembler::set_stream_offset`]) so errors report
//! positions within the original stream, never within a shard.

use std::ops::Range;

use crate::data::{DecodedRow, RowBlock, Schema};

use super::{
    swar, DecodeTally, ErrorConfig, ErrorPolicy, IllegalLog, QuarantinedRow, RowAssembler,
    RowErrorLog,
};

/// Don't spin up a shard for less than this many bytes — below it the
/// scoped-thread overhead outweighs the decode (EXPERIMENTS.md §Decode).
const MIN_SHARD_BYTES: usize = 16 * 1024;

/// Streaming UTF-8 decoder that survives arbitrary chunk boundaries and
/// decodes each chunk's interior rows on `threads` scoped threads.
/// `threads <= 1` is exactly the sequential engine path (one persistent
/// assembler); `swar = false` selects the byte-at-a-time loop in both
/// cases (the ablation baseline).
#[derive(Debug)]
pub struct ShardedUtf8Decoder {
    schema: Schema,
    threads: usize,
    swar: bool,
    cfg: ErrorConfig,
    /// The persistent assembler: carries the row straddling chunk
    /// boundaries, and decodes each chunk's prefix/tail sequentially.
    carry: RowAssembler,
    /// Absolute offset of the next chunk's first byte.
    stream_pos: u64,
    /// Absolute index of the next row (kept or not) — the base for
    /// per-shard row numbering.
    rows_seen: u64,
    illegal: IllegalLog,
    errors: RowErrorLog,
    quarantined: Vec<QuarantinedRow>,
}

impl ShardedUtf8Decoder {
    pub fn new(schema: Schema, threads: usize, swar: bool) -> Self {
        Self::with_errors(schema, threads, swar, ErrorConfig::default())
    }

    pub fn with_errors(schema: Schema, threads: usize, swar: bool, cfg: ErrorConfig) -> Self {
        ShardedUtf8Decoder {
            schema,
            threads: threads.max(1),
            swar,
            cfg,
            carry: RowAssembler::with_errors(schema, cfg),
            stream_pos: 0,
            rows_seen: 0,
            illegal: IllegalLog::with_cap(cfg.detail_cap),
            errors: RowErrorLog::with_cap(cfg.detail_cap),
            quarantined: Vec::new(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Illegal bytes skipped so far, offsets absolute in the stream.
    pub fn illegal(&self) -> &IllegalLog {
        &self.illegal
    }

    /// Defective rows seen so far, offsets absolute in the stream.
    pub fn errors(&self) -> &RowErrorLog {
        &self.errors
    }

    /// Every row seen so far, kept or not.
    pub fn rows_seen(&self) -> u64 {
        self.rows_seen
    }

    /// Drain the rows captured under [`ErrorPolicy::Quarantine`] so far.
    pub fn take_quarantined(&mut self) -> Vec<QuarantinedRow> {
        std::mem::take(&mut self.quarantined)
    }

    /// Feed one chunk, appending every row it completes to `out`.
    /// Chunks may cut rows anywhere; the carried partial row is
    /// completed at the head of the next chunk (sequentially, through
    /// the persistent assembler) before the interior rows fan out.
    pub fn feed_into(&mut self, chunk: &[u8], out: &mut RowBlock) {
        let base = self.stream_pos;
        self.stream_pos += chunk.len() as u64;

        if self.threads <= 1 || chunk.len() < 2 * MIN_SHARD_BYTES {
            self.feed_carry(chunk, base, out);
            return;
        }
        // Prefix: finish the row carried from the previous chunk (up to
        // and including the first `\n`). No `\n` at all ⇒ the whole
        // chunk is one partial row.
        let Some(first_nl) = swar::find_newline(chunk, 0) else {
            self.feed_carry(chunk, base, out);
            return;
        };
        self.feed_carry(&chunk[..=first_nl], base, out);

        // Interior: whole rows between the first and last `\n`.
        let body_start = first_nl + 1;
        let rest = &chunk[body_start..];
        let (body, tail) = match swar::rfind_newline(rest) {
            Some(last) => rest.split_at(last + 1),
            None => rest.split_at(0),
        };
        if !body.is_empty() {
            self.decode_body(body, base + body_start as u64, out);
        }
        // Tail: the partial row carried into the next chunk.
        if !tail.is_empty() {
            let tail_base = base + (chunk.len() - tail.len()) as u64;
            self.feed_carry(tail, tail_base, out);
        }
    }

    /// Finish the stream: complete a trailing row without `\n`, if any.
    pub fn finish_into(mut self, out: &mut RowBlock) -> DecodeTally {
        self.carry.set_row_index(self.rows_seen);
        self.carry.finish_into(out);
        self.drain_carry();
        DecodeTally {
            illegal: self.illegal,
            errors: self.errors,
            quarantined: self.quarantined,
            rows_seen: self.rows_seen,
        }
    }

    /// Sequential lane: feed `bytes` through the persistent assembler
    /// and absorb its logs (keeping stream order: carry segments are
    /// always drained before and after any sharded body).
    fn feed_carry(&mut self, bytes: &[u8], base: u64, out: &mut RowBlock) {
        self.carry.set_stream_offset(base);
        self.carry.set_row_index(self.rows_seen);
        if self.swar {
            self.carry.feed_bytes_into(bytes, out);
        } else {
            self.carry.feed_bytes_scalar_into(bytes, out);
        }
        self.drain_carry();
    }

    /// Absorb the carry assembler's logs and row count.
    fn drain_carry(&mut self) {
        self.rows_seen = self.carry.row_index();
        let log = self.carry.take_illegal();
        self.illegal.merge(&log);
        let errs = self.carry.take_errors();
        if !errs.is_empty() {
            self.errors.merge(&errs);
            self.quarantined.append(&mut self.carry.take_quarantined());
        }
    }

    /// Parallel lane: `body` is whole rows (ends with `\n`). Shards are
    /// decoded on scoped threads into disjoint row windows of `out`.
    fn decode_body(&mut self, body: &[u8], base: u64, out: &mut RowBlock) {
        let shards = (body.len() / MIN_SHARD_BYTES).clamp(1, self.threads);
        if shards <= 1 {
            self.feed_carry(body, base, out);
            return;
        }
        let ranges = shard_ranges(body, shards);
        if ranges.len() <= 1 {
            self.feed_carry(body, base, out);
            return;
        }
        // The prefix row-count pass: rows per shard = newlines per
        // shard, exact before any field is parsed. Every `\n` closes a
        // row whether it is kept or dropped, so the counts are also
        // exact row-index bases for each shard.
        let counts: Vec<usize> =
            ranges.iter().map(|r| swar::count_newlines(&body[r.clone()])).collect();
        let row_bases: Vec<u64> = counts
            .iter()
            .scan(self.rows_seen, |next, &c| {
                let base = *next;
                *next += c as u64;
                Some(base)
            })
            .collect();
        let start_row = out.num_rows();
        let windows = out.disjoint_row_windows(&counts);

        let schema = self.schema;
        let swar_on = self.swar;
        let cfg = self.cfg;
        type ShardResult = (usize, IllegalLog, RowErrorLog, Vec<QuarantinedRow>);
        let mut results: Vec<ShardResult> = Vec::with_capacity(ranges.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .iter()
                .zip(windows)
                .zip(&row_bases)
                .map(|((r, mut win), &row_base)| {
                    let seg = &body[r.clone()];
                    let seg_base = base + r.start as u64;
                    scope.spawn(move || {
                        let mut asm = RowAssembler::with_errors(schema, cfg);
                        asm.set_stream_offset(seg_base);
                        asm.set_row_index(row_base);
                        if swar_on {
                            asm.feed_bytes_into(seg, &mut win);
                        } else {
                            asm.feed_bytes_scalar_into(seg, &mut win);
                        }
                        // A dropping policy may leave the window short;
                        // under zero-fill every counted `\n` emits a row.
                        debug_assert!(
                            win.is_full() || cfg.policy != ErrorPolicy::Zero,
                            "shard decoded {} of {} rows",
                            win.filled(),
                            win.rows()
                        );
                        (win.filled(), asm.take_illegal(), asm.take_errors(), asm.take_quarantined())
                    })
                })
                .collect();
            for h in handles {
                results.push(h.join().expect("decode shard panicked"));
            }
        });
        let filled: Vec<usize> = results.iter().map(|r| r.0).collect();
        for (_, log, errs, mut quarantined) in results {
            self.illegal.merge(&log);
            self.errors.merge(&errs);
            self.quarantined.append(&mut quarantined);
        }
        self.rows_seen += counts.iter().map(|&c| c as u64).sum::<u64>();
        // Close the gaps dropped rows left in the committed windows
        // (no-op when every window is full — the clean path).
        if filled.iter().zip(&counts).any(|(f, c)| f != c) {
            out.compact_rows(start_row, &counts, &filled);
        }
    }
}

/// Newline-aligned shard byte ranges over `body` (which must end with
/// `\n`): boundaries land on the first `\n` at or after each even
/// byte-split point, so shards hold whole rows and stay within one row
/// of equal byte share.
fn shard_ranges(body: &[u8], shards: usize) -> Vec<Range<usize>> {
    let mut ranges = Vec::with_capacity(shards);
    let mut start = 0usize;
    for i in 1..shards {
        let target = body.len() * i / shards;
        if target <= start {
            continue;
        }
        match swar::find_newline(body, target) {
            Some(nl) if nl + 1 < body.len() => {
                ranges.push(start..nl + 1);
                start = nl + 1;
            }
            // The split point fell inside the final row: everything
            // left belongs to the last shard.
            _ => break,
        }
    }
    if start < body.len() {
        ranges.push(start..body.len());
    }
    ranges
}

/// One-shot parallel decode of a whole raw UTF-8 buffer into rows — the
/// functional front end the sim executors (GPU model, PIPER kernel)
/// use. Bit-identical to [`super::ScalarDecoder`]; cycle counts are the
/// caller's concern (they model hardware width, not software speed).
pub fn decode_rows(schema: Schema, raw: &[u8], threads: usize) -> Vec<DecodedRow> {
    let mut block = RowBlock::with_capacity(schema, swar::count_newlines(raw) + 1);
    let mut dec = ShardedUtf8Decoder::new(schema, threads, true);
    dec.feed_into(raw, &mut block);
    dec.finish_into(&mut block);
    block.to_rows()
}

/// Default decode-thread count: one per available core (the engine's
/// planning default; 1 when parallelism cannot be probed).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{utf8, SynthConfig, SynthDataset};
    use crate::decode::ScalarDecoder;

    #[test]
    fn shard_ranges_cover_exactly_and_end_on_newlines() {
        let ds = SynthDataset::generate(SynthConfig::small(500));
        let raw = utf8::encode_dataset(&ds);
        for shards in [2usize, 3, 4, 7, 16] {
            let ranges = shard_ranges(&raw, shards);
            assert!(!ranges.is_empty());
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, raw.len());
            for pair in ranges.windows(2) {
                assert_eq!(pair[0].end, pair[1].start, "gap at shard seam");
            }
            for r in &ranges {
                assert_eq!(raw[r.end - 1], b'\n', "shard must end after a row");
            }
        }
    }

    #[test]
    fn sharded_matches_scalar_across_thread_counts() {
        let ds = SynthDataset::generate(SynthConfig::small(2_000));
        let raw = utf8::encode_dataset(&ds);
        let want = ScalarDecoder::new(ds.schema()).decode(&raw);
        for threads in [1usize, 2, 3, 8] {
            let mut block = RowBlock::new(ds.schema());
            let mut dec = ShardedUtf8Decoder::new(ds.schema(), threads, true);
            dec.feed_into(&raw, &mut block);
            dec.finish_into(&mut block);
            assert_eq!(block.to_rows(), want.rows, "{threads} threads");
        }
        assert_eq!(decode_rows(ds.schema(), &raw, 4), want.rows);
    }

    #[test]
    fn sharded_survives_chunk_boundaries_mid_field() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let raw = utf8::encode_dataset(&ds);
        let want = ScalarDecoder::new(ds.schema()).decode(&raw);
        for chunk in [1usize, 7, 131, 4096] {
            let mut dec = ShardedUtf8Decoder::new(ds.schema(), 4, true);
            let mut block = RowBlock::new(ds.schema());
            for c in raw.chunks(chunk) {
                dec.feed_into(c, &mut block);
            }
            dec.finish_into(&mut block);
            assert_eq!(block.to_rows(), want.rows, "chunk {chunk}");
        }
    }

    #[test]
    fn illegal_offsets_are_stream_absolute() {
        // Rows padded so the body is large enough to shard; the illegal
        // bytes sit at known absolute offsets.
        let mut raw = Vec::new();
        let mut offsets = Vec::new();
        for i in 0..4_000u32 {
            let line = format!("{}\t{:06}\tdeadbeef\n", i % 2, i);
            let mut line = line.into_bytes();
            if i % 1000 == 17 {
                offsets.push(raw.len() as u64 + 2);
                line[2] = b'@'; // corrupt inside the dense field
            }
            raw.extend_from_slice(&line);
        }
        let schema = Schema::new(1, 1);
        let want = ScalarDecoder::new(schema).decode(&raw);
        let mut dec = ShardedUtf8Decoder::new(schema, 4, true);
        let mut block = RowBlock::new(schema);
        dec.feed_into(&raw, &mut block);
        let log = dec.finish_into(&mut block).illegal;
        assert_eq!(block.to_rows(), want.rows);
        assert_eq!(log, want.illegal);
        let got: Vec<u64> = log.recorded.iter().map(|b| b.offset).collect();
        assert_eq!(got, offsets);
    }
}
