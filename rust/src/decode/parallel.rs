//! Parallel combination decoder — paper Script 1.
//!
//! The paper's key decode optimization: instead of one byte per cycle, the
//! PE ingests a W-byte word (W = 4 in Script 1), classifies all W bytes
//! combinationally ("upstream module"), counts the delimiters to determine
//! how many of the 0..=W outputs are valid, and merges the partial-field
//! nibbles into the carry register in one cycle ("downstream module" —
//! a state machine extracting valid 32-bit outputs from the wide input
//! stream).
//!
//! The software model reproduces the exact same combination semantics —
//! one *group* of W classified bytes is folded per modeled cycle, carrying
//! the register across group boundaries — and is checked bit-exact against
//! [`super::ScalarDecoder`] by unit + property tests. Width is a runtime
//! parameter so the ablation bench can sweep W ∈ {1, 2, 4, 8}.

use crate::data::{DecodedRow, Schema};

use super::{classify, ByteClass, DecodeOutput, RowAssembler};

/// The parallel decode PE (paper Script 1; default width 4).
#[derive(Debug)]
pub struct ParallelDecoder {
    schema: Schema,
    width: usize,
}

impl ParallelDecoder {
    /// Script 1's 4-byte configuration.
    pub fn new(schema: Schema) -> Self {
        Self::with_width(schema, 4)
    }

    /// Generalized width (1, 2, 4, 8, ... — ablation bench).
    pub fn with_width(schema: Schema, width: usize) -> Self {
        assert!(width >= 1 && width <= 64, "decode width out of range");
        ParallelDecoder { schema, width }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Decode a raw buffer. Cycles = number of W-byte groups
    /// (`ceil(len/W)`): the whole group is folded combinationally in one
    /// modeled cycle.
    ///
    /// The group fold is associative over the byte stream (each group's
    /// effect is exactly the left-to-right byte fold carrying the
    /// register), so functionally the whole buffer can be fed in one
    /// pass — the group structure only determines the cycle count. The
    /// software pass is the SWAR wide-word loop
    /// ([`RowAssembler::feed_bytes_into`], the genuine software
    /// combination decoder — EXPERIMENTS.md §Decode); the cycle model
    /// is untouched by it, because modeled cycles are a property of the
    /// hardware width, not of simulator speed. [`Self::fold_group`]
    /// remains the faithful per-cycle form and the property tests
    /// assert both produce identical rows and cycles.
    pub fn decode(&self, raw: &[u8]) -> DecodeOutput {
        let mut asm = RowAssembler::new(self.schema);
        let mut rows: Vec<DecodedRow> = Vec::new();
        asm.feed_bytes_into(raw, &mut rows);
        let cycles = (raw.len() as u64).div_ceil(self.width as u64);
        let illegal = asm.take_illegal();
        asm.finish_into(&mut rows);
        DecodeOutput { rows, cycles, illegal }
    }

    /// The faithful per-cycle decode: fold group by group (slower in
    /// software, identical output — used by tests and the FIFO burst
    /// model, which needs per-cycle emission counts).
    pub fn decode_by_groups(&self, raw: &[u8]) -> DecodeOutput {
        let mut asm = RowAssembler::new(self.schema);
        let mut cycles = 0u64;
        for group in raw.chunks(self.width) {
            cycles += 1;
            self.fold_group(group, &mut asm);
        }
        let illegal = asm.take_illegal();
        DecodeOutput { rows: asm.finish(), cycles, illegal }
    }

    /// Fold one W-byte group into the assembler.
    ///
    /// Mirrors Script 1's structure: split the group into sub-inputs
    /// s0..s{W-1}, classify each, and resolve the (delimiter-count →
    /// valid-output-count) combination by scanning the classified lanes
    /// in order, merging nibble runs into the carried register `v` and
    /// emitting an output o_i at each delimiter. In HLS this unrolls into
    /// the 2^W-case combinational network the paper enumerates (16
    /// combinations for W = 4); semantically it is this exact fold.
    #[inline]
    fn fold_group(&self, group: &[u8], asm: &mut RowAssembler) {
        // Upstream module: map ASCII → {delim, minus, nibble} (LUT).
        // Downstream module: merge lanes left-to-right. The scan is data-
        // independent per lane, which is what makes the hardware version a
        // fixed-depth circuit.
        asm.feed_bytes(group);
    }

    /// Count the delimiters in one group — the quantity Script 1 computes
    /// first ("count how many \t exist in the input because it determines
    /// the number of valid outputs"). Exposed for the PE's output-FIFO
    /// width assertions in [`crate::accel`].
    pub fn delimiters_in(group: &[u8]) -> usize {
        group
            .iter()
            .filter(|&&b| matches!(classify(b), ByteClass::Delim { .. }))
            .count()
    }

    /// Decode a single line.
    pub fn decode_line(&self, line: &[u8]) -> Option<DecodedRow> {
        self.decode(line).rows.into_iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, utf8, SynthDataset};
    use crate::decode::ScalarDecoder;
    use crate::util::XorShift64;

    #[test]
    fn matches_scalar_on_synth_dataset() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let raw = utf8::encode_dataset(&ds);
        let scalar = ScalarDecoder::new(ds.schema()).decode(&raw);
        for w in [1usize, 2, 4, 8] {
            let par = ParallelDecoder::with_width(ds.schema(), w).decode(&raw);
            assert_eq!(par.rows, scalar.rows, "width {w} diverged from scalar");
        }
    }

    #[test]
    fn cycle_count_is_quarter_of_scalar_at_width_4() {
        let ds = SynthDataset::generate(SynthConfig::small(100));
        let raw = utf8::encode_dataset(&ds);
        let s = ScalarDecoder::new(ds.schema()).decode(&raw);
        let p = ParallelDecoder::new(ds.schema()).decode(&raw);
        assert_eq!(s.cycles, raw.len() as u64);
        assert_eq!(p.cycles, (raw.len() as u64).div_ceil(4));
    }

    #[test]
    fn fast_path_equals_per_group_fold() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let raw = utf8::encode_dataset(&ds);
        for w in [1usize, 2, 4, 8] {
            let d = ParallelDecoder::with_width(ds.schema(), w);
            let fast = d.decode(&raw);
            let slow = d.decode_by_groups(&raw);
            assert_eq!(fast.rows, slow.rows, "width {w}");
            assert_eq!(fast.cycles, slow.cycles, "width {w}");
        }
    }

    #[test]
    fn delimiter_count() {
        assert_eq!(ParallelDecoder::delimiters_in(b"\t1\n2"), 2);
        assert_eq!(ParallelDecoder::delimiters_in(b"abcd"), 0);
        assert_eq!(ParallelDecoder::delimiters_in(b"\t\t\t\t"), 4);
    }

    #[test]
    fn fields_split_across_group_boundaries() {
        // "12345" spans two 4-byte groups; register must carry across.
        let schema = crate::data::Schema::new(1, 0);
        let p = ParallelDecoder::new(schema);
        let row = p.decode_line(b"0\t12345").unwrap();
        assert_eq!(row.dense[0], 12345);
    }

    /// Property test: random legal-byte soup decodes identically under
    /// scalar and all parallel widths (even when it isn't a well-formed
    /// table — the state machines must still agree).
    #[test]
    fn property_random_soup_bit_exact() {
        let legal = b"\t\n-0123456789abcdef";
        let schema = crate::data::Schema::new(3, 3);
        let mut rng = XorShift64::new(0xDEC0DE);
        for case in 0..200 {
            let len = rng.below(200) as usize;
            let raw: Vec<u8> =
                (0..len).map(|_| legal[rng.below(legal.len() as u64) as usize]).collect();
            let s = ScalarDecoder::new(schema).decode(&raw);
            for w in [2usize, 4, 8] {
                let p = ParallelDecoder::with_width(schema, w).decode(&raw);
                assert_eq!(p.rows, s.rows, "case {case} width {w}: {:?}", raw);
            }
        }
    }

    /// Property test: encode(decode(x)) == x for well-formed datasets of
    /// random shapes.
    #[test]
    fn property_roundtrip_random_schemas() {
        let mut rng = XorShift64::new(0xE2E);
        for case in 0..30 {
            let schema = crate::data::Schema::new(
                1 + rng.below(8) as usize,
                1 + rng.below(8) as usize,
            );
            let mut cfg = SynthConfig::small(40);
            cfg.schema = schema;
            cfg.seed = rng.next_u64();
            let ds = SynthDataset::generate(cfg);
            let raw = utf8::encode_dataset(&ds);
            let out = ParallelDecoder::new(schema).decode(&raw);
            assert_eq!(out.rows, ds.rows, "case {case} schema {schema:?}");
        }
    }
}
