//! UTF-8 decoding: the paper's `Decode` + `FillMissing` operators.
//!
//! Three implementations, bit-exact to each other:
//!
//! * [`scalar`] — the byte-at-a-time state machine of paper Fig. 6
//!   (II = 1 cycle/byte on the FPGA ⇒ ~300 MB/s at 300 MHz, the paper's
//!   identified bottleneck); kept branch-by-branch simple because it is
//!   the bit-exactness **oracle** every faster path is pinned against;
//! * [`parallel`] — the 4-byte-per-cycle combination decoder of paper
//!   Script 1 (generalized to width 1/2/4/8 for the ablation bench).
//!   Its *cycle model* is unchanged by any software optimization; its
//!   software fast path now runs the [`swar`] wide-word loop;
//! * [`swar`] + [`shard`] — the software throughput path: a SWAR
//!   classifier finds delimiter/minus/illegal bytes 8 bytes at a time
//!   and folds nibble runs in word-sized gulps, and the shard module
//!   splits a chunk at `\n` boundaries to decode row shards on threads
//!   into disjoint ranges of one [`crate::data::RowBlock`].
//!
//! All paths consume raw bytes and produce decoded rows with missing
//! fields already filled with 0 (on hardware there is no `Null`, paper
//! §3.1), plus — for the one-shot decoders — a cycle count for the
//! accelerator timing model. The shared [`RowAssembler`] writes
//! completed rows into any [`PushRow`] sink: a column-major
//! [`crate::data::RowBlock`] (the engine's zero-alloc streaming path),
//! a [`crate::data::RowWindow`] (the parallel path's disjoint slice of
//! a block) or a `Vec<DecodedRow>` (the one-shot decoders' legacy
//! view).
//!
//! Illegal bytes are skipped non-panicking (hardware would flag an
//! error line) but are now *recorded*: every path logs the byte and its
//! absolute offset in the fed stream ([`IllegalLog`]), so a sharded
//! decode reports positions within the original chunk, never within a
//! shard.
//!
//! Beyond the byte level, the assembler classifies whole defective rows
//! into the [`errors::RowError`] taxonomy and applies an
//! [`errors::ErrorPolicy`] to each: emit zero-filled (legacy), skip,
//! quarantine the raw bytes, or flag for abort. Detection runs in both
//! the scalar and SWAR paths with identical results — same kinds, same
//! stream-absolute offsets (pinned by `tests/decode_equivalence.rs`).

pub mod errors;
pub mod parallel;
pub mod scalar;
pub mod shard;
pub mod swar;

use crate::data::{DecodedRow, PushRow, Schema};

pub use errors::{
    DataError, DecodeTally, ErrorBudget, ErrorConfig, ErrorPolicy, QuarantinedRow, RowError,
    RowErrorKind, RowErrorLog,
};
pub use parallel::ParallelDecoder;
pub use scalar::ScalarDecoder;
pub use shard::ShardedUtf8Decoder;

/// Byte classes of the raw format (paper §3.2: only `\t \n - 0-9 a-f`
/// can appear).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByteClass {
    /// `\t` or `\n` — both delimiters ("we regard \t and \n the same",
    /// paper §3.3); `\n` additionally ends the row.
    Delim { end_of_row: bool },
    /// `-` minus sign (dense features only).
    Minus,
    /// A hex nibble `0-9a-f` with its 4-bit value.
    Nibble(u8),
    /// Anything else — illegal in the format.
    Illegal,
}

/// Classify one byte (the "upstream module" of paper §3.3 that maps ASCII
/// values to `\t`, `\n`, `-`, `0~f`).
#[inline]
pub fn classify(b: u8) -> ByteClass {
    match CLASS_LUT[b as usize] {
        c if c < 16 => ByteClass::Nibble(c),
        CODE_TAB => ByteClass::Delim { end_of_row: false },
        CODE_NL => ByteClass::Delim { end_of_row: true },
        CODE_MINUS => ByteClass::Minus,
        _ => ByteClass::Illegal,
    }
}

// Byte-class codes for the scalar loop: 0..=15 nibble value, then
// specials. In hardware this is the one-cycle combinational classifier;
// in software it is a 256-entry table lookup, which keeps the per-byte
// oracle loop branch-lean (EXPERIMENTS.md §Perf). The SWAR fast path
// replaces the per-byte lookup with [`swar::nibble_mask`] over whole
// words and only consults the LUT at special bytes.
const CODE_TAB: u8 = 16;
const CODE_NL: u8 = 17;
const CODE_MINUS: u8 = 18;
const CODE_ILLEGAL: u8 = 19;

const CLASS_LUT: [u8; 256] = {
    let mut t = [CODE_ILLEGAL; 256];
    let mut b = b'0';
    while b <= b'9' {
        t[b as usize] = b - b'0';
        b += 1;
    }
    let mut b = b'a';
    while b <= b'f' {
        t[b as usize] = b - b'a' + 10;
        b += 1;
    }
    t[b'\t' as usize] = CODE_TAB;
    t[b'\n' as usize] = CODE_NL;
    t[b'-' as usize] = CODE_MINUS;
    t
};

/// One skipped illegal byte: its value and its absolute offset in the
/// byte stream fed so far (for a sharded decode, offsets are relative
/// to the original chunk/stream, never to a shard —
/// [`RowAssembler::set_stream_offset`] rebases each shard's assembler).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IllegalByte {
    pub offset: u64,
    pub byte: u8,
}

/// Default detail cap of [`IllegalLog`]: garbage input must not grow
/// memory without bound, so only the first bytes are recorded
/// individually while `total` keeps counting. Configurable per run via
/// [`IllegalLog::with_cap`] / `ErrorConfig::detail_cap`.
pub const MAX_RECORDED_ILLEGAL: usize = 64;

/// A single field longer than this is classified
/// [`RowErrorKind::OversizedField`] — no legal Criteo-dialect field
/// (decimal i32 or 8-nibble hex) comes anywhere near it.
pub const MAX_FIELD_BYTES: u32 = 64;

/// Raw-byte capture cap per quarantined row: a pathological multi-MB
/// "row" is recorded truncated rather than ballooning memory (such rows
/// always carry an oversized-field or wrong-field-count defect anyway).
pub const MAX_QUARANTINE_ROW_BYTES: usize = 1 << 20;

/// Record of the illegal bytes a decode skipped: the first `cap` in
/// stream order, plus the total count.
#[derive(Debug, Clone)]
pub struct IllegalLog {
    /// The first illegal bytes, in stream order.
    pub recorded: Vec<IllegalByte>,
    /// Total illegal bytes seen (may exceed `recorded.len()`).
    pub total: u64,
    cap: usize,
}

impl Default for IllegalLog {
    fn default() -> Self {
        IllegalLog::with_cap(MAX_RECORDED_ILLEGAL)
    }
}

/// The cap is a tuning knob, not an observation — logs compare by what
/// they saw.
impl PartialEq for IllegalLog {
    fn eq(&self, other: &Self) -> bool {
        self.recorded == other.recorded && self.total == other.total
    }
}

impl Eq for IllegalLog {}

impl IllegalLog {
    pub fn with_cap(cap: usize) -> IllegalLog {
        IllegalLog { recorded: Vec::new(), total: 0, cap }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    #[inline]
    pub fn note(&mut self, offset: u64, byte: u8) {
        if self.recorded.len() < self.cap {
            self.recorded.push(IllegalByte { offset, byte });
        }
        self.total += 1;
    }

    /// Append another log's entries (stream order: `other` follows
    /// `self`). Per-shard prefix truncation followed by this merge
    /// equals global prefix truncation, because a shard only drops
    /// entries once it has recorded `cap` of its own — all of which
    /// precede the dropped ones globally.
    pub fn merge(&mut self, other: &IllegalLog) {
        for b in &other.recorded {
            if self.recorded.len() >= self.cap {
                break;
            }
            self.recorded.push(*b);
        }
        self.total += other.total;
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }
}

/// Shared row-assembly state machine: accumulates nibbles into the 32-bit
/// register, finalizes fields on delimiters, assembles rows.
///
/// The field's *mode* (decimal vs hexadecimal accumulate) is selected by
/// the column counter against the [`Schema`] — "what we should know in
/// advance is the data format for each feature" (paper §3.2).
///
/// Completed rows go to any caller-provided [`PushRow`] sink
/// ([`Self::feed_bytes_into`] / [`Self::finish_into`] — the engine's
/// zero-alloc path: the assembler owns one fixed scratch row and never
/// allocates per row). [`Self::feed_bytes_into`] runs the SWAR
/// wide-word loop; [`Self::feed_bytes_scalar_into`] is the same state
/// machine one byte at a time (the ablation baseline). The row-wise API
/// ([`Self::feed_bytes`], [`Self::take_rows`], [`Self::finish`])
/// materializes [`DecodedRow`]s directly (two heap `Vec`s per row, the
/// pre-`RowBlock` cost) — kept byte-at-a-time as the faithful oracle
/// for the one-shot [`ScalarDecoder`] and the `rows_columnar` baseline.
#[derive(Debug)]
pub struct RowAssembler {
    schema: Schema,
    /// 32-bit accumulation register (paper keeps the same width).
    reg: u32,
    /// Set when a `-` was seen in the current field.
    negative_flag: bool,
    /// Current column index (0 = label, then dense, then sparse).
    col: usize,
    /// Cached accumulate mode of the current column (avoids re-deriving
    /// it per nibble — EXPERIMENTS.md §Perf).
    hex_mode: bool,
    cur_label: i32,
    cur_dense: Vec<i32>,
    cur_sparse: Vec<u32>,
    /// Rows completed through the row-wise API only; the `_into`
    /// methods bypass it entirely.
    out: Vec<DecodedRow>,
    /// Absolute offset of the next byte to be fed — the base for
    /// illegal-byte positions. Advances with every feed; shard decoding
    /// rebases it per shard via [`Self::set_stream_offset`].
    stream_offset: u64,
    illegal: IllegalLog,
    /// Containment configuration (policy + detail cap; the budget is
    /// enforced above the assembler, at chunk granularity).
    cfg: ErrorConfig,
    /// Defective rows seen so far (populated under every policy).
    errors: RowErrorLog,
    /// Rows captured under [`ErrorPolicy::Quarantine`]; drained by the
    /// owner.
    quarantined: Vec<QuarantinedRow>,
    /// Raw bytes of the open row — maintained only when quarantining.
    row_buf: Vec<u8>,
    /// `cfg.policy == Quarantine`, hoisted out of the byte loop.
    track_raw: bool,
    /// Stream-absolute offset of the open row's first byte.
    row_start: Option<u64>,
    /// Stream-absolute offset of the open field's first byte.
    field_start: u64,
    /// Bytes in the open field (digits and `-`), for the oversize check.
    field_len: u32,
    /// Sticky per-field flag: the untruncated value exceeded `u32::MAX`.
    field_overflow: bool,
    /// First defect detected in the open row, if any.
    defect: Option<(u64, RowErrorKind)>,
    /// Absolute index of the next row to complete (kept or not); shard
    /// decoding rebases it via [`Self::set_row_index`].
    rows_seen: u64,
}

impl RowAssembler {
    pub fn new(schema: Schema) -> Self {
        RowAssembler::with_errors(schema, ErrorConfig::default())
    }

    /// An assembler with an explicit containment configuration. The
    /// default ([`ErrorPolicy::Zero`], unlimited budget) is bit-identical
    /// to the engine's historical behavior.
    pub fn with_errors(schema: Schema, cfg: ErrorConfig) -> Self {
        RowAssembler {
            schema,
            reg: 0,
            negative_flag: false,
            col: 0,
            hex_mode: false, // column 0 is the (decimal) label
            cur_label: 0,
            cur_dense: vec![0; schema.num_dense],
            cur_sparse: vec![0; schema.num_sparse],
            out: Vec::new(),
            stream_offset: 0,
            illegal: IllegalLog::with_cap(cfg.detail_cap),
            cfg,
            errors: RowErrorLog::with_cap(cfg.detail_cap),
            quarantined: Vec::new(),
            row_buf: Vec::new(),
            track_raw: cfg.policy == ErrorPolicy::Quarantine,
            row_start: None,
            field_start: 0,
            field_len: 0,
            field_overflow: false,
            defect: None,
            rows_seen: 0,
        }
    }

    pub fn schema(&self) -> Schema {
        self.schema
    }

    /// Rebase the absolute offset used for illegal-byte positions: a
    /// shard's assembler reports offsets within the *original* chunk,
    /// so the shard decoder sets this to the shard's start offset.
    pub fn set_stream_offset(&mut self, offset: u64) {
        self.stream_offset = offset;
    }

    /// Illegal bytes skipped so far (absolute offsets).
    pub fn illegal(&self) -> &IllegalLog {
        &self.illegal
    }

    /// Drain the illegal-byte log (the shard decoder aggregates shard
    /// logs in stream order).
    pub fn take_illegal(&mut self) -> IllegalLog {
        std::mem::replace(&mut self.illegal, IllegalLog::with_cap(self.cfg.detail_cap))
    }

    /// Defective rows seen so far.
    pub fn errors(&self) -> &RowErrorLog {
        &self.errors
    }

    /// Drain the row-error log (shard decoders aggregate in stream order).
    pub fn take_errors(&mut self) -> RowErrorLog {
        std::mem::replace(&mut self.errors, RowErrorLog::with_cap(self.cfg.detail_cap))
    }

    /// Drain rows captured under [`ErrorPolicy::Quarantine`].
    pub fn take_quarantined(&mut self) -> Vec<QuarantinedRow> {
        std::mem::take(&mut self.quarantined)
    }

    /// Absolute index of the next row to complete (== rows seen when the
    /// base was 0).
    pub fn row_index(&self) -> u64 {
        self.rows_seen
    }

    /// Rebase the absolute row index, as [`Self::set_stream_offset`]
    /// rebases byte offsets: a shard's assembler numbers rows within the
    /// original stream.
    pub fn set_row_index(&mut self, index: u64) {
        self.rows_seen = index;
    }

    #[inline]
    fn push_nibble(&mut self, n: u8) {
        // (a)/(b) of paper §3.2: decimal ×10+digit, hex <<4|digit — the
        // fold runs in u64 so overflow past the 32-bit register is
        // *observable* (sticky per-field flag) before the hardware-
        // faithful truncation.
        let wide = if self.hex_mode {
            ((self.reg as u64) << 4) | n as u64
        } else {
            (self.reg as u64) * 10 + n as u64
        };
        self.field_overflow |= wide > u32::MAX as u64;
        self.reg = wide as u32;
    }

    #[inline]
    fn note_illegal(&mut self, rel: usize, byte: u8) {
        let abs = self.stream_offset + rel as u64;
        self.illegal.note(abs, byte);
        self.note_defect(abs, RowErrorKind::IllegalByte);
    }

    /// Record the row's defect — first detected wins, so every decode
    /// path (scalar, SWAR, sharded) classifies a row identically.
    #[inline]
    fn note_defect(&mut self, offset: u64, kind: RowErrorKind) {
        if self.defect.is_none() {
            self.defect = Some((offset, kind));
        }
    }

    /// Append to the open row's raw capture, bounded by
    /// [`MAX_QUARANTINE_ROW_BYTES`].
    #[inline]
    fn raw_bytes(&mut self, bytes: &[u8]) {
        let room = MAX_QUARANTINE_ROW_BYTES.saturating_sub(self.row_buf.len());
        let take = bytes.len().min(room);
        self.row_buf.extend_from_slice(&bytes[..take]);
    }

    /// Emit the scratch row into the sink — or contain it, when a defect
    /// was detected and the policy says so — and reset for the next row.
    #[inline]
    fn emit_row<S: PushRow + ?Sized>(&mut self, out: &mut S) {
        // A well-formed row has exactly label + dense + sparse fields;
        // anything else (truncated or over-wide) is a defect unless an
        // earlier one already classified the row.
        if self.defect.is_none()
            && self.col != 1 + self.schema.num_dense + self.schema.num_sparse
        {
            self.defect = Some((
                self.row_start.unwrap_or(self.stream_offset),
                RowErrorKind::WrongFieldCount,
            ));
        }
        if self.defect.is_none() {
            out.push_row(self.cur_label, &self.cur_dense, &self.cur_sparse);
        } else {
            self.contain_row(out);
        }
        self.rows_seen += 1;
        self.reset_row();
    }

    /// Apply the containment policy to a defective row.
    #[cold]
    fn contain_row<S: PushRow + ?Sized>(&mut self, out: &mut S) {
        let (offset, kind) = self.defect.take().expect("contain_row without defect");
        self.errors.note(RowError { kind, offset, row: self.rows_seen });
        match self.cfg.policy {
            // Legacy behavior: unparseable content reads as 0.
            ErrorPolicy::Zero => {
                out.push_row(self.cur_label, &self.cur_dense, &self.cur_sparse)
            }
            // The row is dropped; strict mode aborts above the assembler
            // (the owner checks the log after the feed).
            ErrorPolicy::Fail | ErrorPolicy::Skip => {}
            ErrorPolicy::Quarantine => {
                let bytes = std::mem::take(&mut self.row_buf);
                self.quarantined.push(QuarantinedRow {
                    row: self.rows_seen,
                    offset: self.row_start.unwrap_or(offset),
                    kind,
                    bytes,
                });
            }
        }
    }

    /// One classified byte through the state machine — THE byte-class
    /// dispatch, shared by the scalar loop, the SWAR loop's special
    /// bytes and its sub-word tail, so the SWAR == scalar bit-exactness
    /// contract has a single point of truth. `rel` is the byte's offset
    /// within the current feed (for the illegal log).
    #[inline]
    fn step<S: PushRow + ?Sized>(&mut self, rel: usize, b: u8, out: &mut S) {
        if self.track_raw {
            self.raw_bytes(&[b]);
        }
        if self.row_start.is_none() {
            self.row_start = Some(self.stream_offset + rel as u64);
        }
        let code = CLASS_LUT[b as usize];
        if code < 16 {
            if self.field_len == 0 {
                self.field_start = self.stream_offset + rel as u64;
            }
            self.field_len += 1;
            self.push_nibble(code);
        } else if code == CODE_TAB {
            self.finish_field();
        } else if code == CODE_NL {
            self.finish_field();
            self.emit_row(out);
        } else if code == CODE_MINUS {
            if self.field_len == 0 {
                self.field_start = self.stream_offset + rel as u64;
            }
            self.field_len += 1;
            self.negative_flag = true;
        } else {
            self.note_illegal(rel, b);
        }
    }

    /// The hot loop: the SWAR wide-word classifier over `bytes`,
    /// appending every completed row to `out` — this is what the
    /// streaming engine calls (EXPERIMENTS.md §Decode). Each 8-byte
    /// word is classified branch-free ([`swar::nibble_mask`]); a word
    /// with no special bytes folds all 8 nibbles into the register in
    /// one gulp, and words with specials gulp the nibble runs between
    /// them. No allocation happens per row: fields accumulate in the
    /// assembler's scratch row, and `emit_row` writes it column-wise
    /// into the sink. Illegal bytes are skipped non-panicking and
    /// logged with their absolute offset, so fuzzed inputs can't crash
    /// the PE. Bit-exact to [`Self::feed_bytes_scalar_into`] for all
    /// 256 byte values (pinned by `tests/decode_equivalence.rs`).
    #[inline]
    pub fn feed_bytes_into<S: PushRow + ?Sized>(&mut self, bytes: &[u8], out: &mut S) {
        let mut words = bytes.chunks_exact(8);
        let mut pos = 0usize;
        for word in words.by_ref() {
            let w = u64::from_le_bytes(word.try_into().expect("8-byte word"));
            let specials = swar::HI & !swar::nibble_mask(w);
            if specials == 0 {
                self.gulp(word, pos);
            } else {
                self.fold_word(word, specials, pos, out);
            }
            pos += 8;
        }
        for (j, &b) in words.remainder().iter().enumerate() {
            self.step(pos + j, b, out);
        }
        self.stream_offset += bytes.len() as u64;
    }

    /// Fold a run of 1..=8 nibble bytes into the register in one step —
    /// the software form of Script 1's combinational merge. Equivalent
    /// to `push_nibble` per byte: hex runs OR into a left-shifted
    /// register (`u32` truncation discards overflow exactly like eight
    /// single shifts), decimal runs use `reg·10^k + D mod 2^32`, which
    /// equals `k` wrapping `reg = reg*10 + d` steps by distributivity.
    /// `rel` is the run's offset within the current feed.
    ///
    /// The overflow flag agrees with the per-byte path: both folds are
    /// monotone in added digits, so *some* per-byte intermediate exceeds
    /// `u32::MAX` iff the gulp's untruncated result does; and once a
    /// field has overflowed, the flag is sticky while the register
    /// stays bit-exact (mod-2^32 arithmetic commutes with truncation).
    #[inline]
    fn gulp(&mut self, run: &[u8], rel: usize) {
        let k = run.len();
        debug_assert!((1..=8).contains(&k));
        if self.track_raw {
            self.raw_bytes(run);
        }
        if self.row_start.is_none() {
            self.row_start = Some(self.stream_offset + rel as u64);
        }
        if self.field_len == 0 {
            self.field_start = self.stream_offset + rel as u64;
        }
        self.field_len += k as u32;
        let vals = swar::nibble_values(swar::load_le(run));
        let wide = if self.hex_mode {
            let packed = swar::pack_hex(vals) >> (4 * (8 - k));
            ((self.reg as u64) << (4 * k)) | packed as u64
        } else {
            let d = swar::fold_dec(vals << (8 * (8 - k)));
            (self.reg as u64) * swar::POW10[k] as u64 + d as u64
        };
        self.field_overflow |= wide > u32::MAX as u64;
        self.reg = wide as u32;
    }

    /// Slow lane of the SWAR loop: a word containing at least one
    /// special byte. Nibble runs between specials still fold in gulps;
    /// each special byte is resolved through the scalar classifier.
    fn fold_word<S: PushRow + ?Sized>(
        &mut self,
        word: &[u8],
        mut specials: u64,
        base: usize,
        out: &mut S,
    ) {
        let mut i = 0usize;
        while specials != 0 {
            let sp = (specials.trailing_zeros() >> 3) as usize;
            if sp > i {
                self.gulp(&word[i..sp], base + i);
            }
            self.step(base + sp, word[sp], out);
            i = sp + 1;
            specials &= specials - 1;
        }
        if i < word.len() {
            self.gulp(&word[i..], base + i);
        }
    }

    /// The scalar hot loop: one LUT lookup per byte — the pre-SWAR
    /// engine path, kept as the streaming oracle and the "SWAR off" arm
    /// of the ablation benches.
    pub fn feed_bytes_scalar_into<S: PushRow + ?Sized>(&mut self, bytes: &[u8], out: &mut S) {
        for (j, &b) in bytes.iter().enumerate() {
            self.step(j, b, out);
        }
        self.stream_offset += bytes.len() as u64;
    }

    /// Row-wise feed: the byte-at-a-time classifier loop, materializing
    /// each completed row as a [`DecodedRow`] (two allocations per row —
    /// exactly the representation the columnar engine retired; kept
    /// un-degraded so the one-shot scalar oracle and the `rows_columnar`
    /// baseline measure the true pre-`RowBlock` cost).
    #[inline]
    pub fn feed_bytes(&mut self, bytes: &[u8]) {
        // Same single-point-of-truth dispatch, sinking into the
        // assembler's own row buffer (briefly moved out so `step` can
        // borrow it as the sink).
        let mut out = std::mem::take(&mut self.out);
        for (j, &b) in bytes.iter().enumerate() {
            self.step(j, b, &mut out);
        }
        self.out = out;
        self.stream_offset += bytes.len() as u64;
    }

    /// (c) of paper §3.2: extract the register on a delimiter. An empty
    /// field leaves reg = 0, which *is* the FillMissing default.
    #[inline]
    fn finish_field(&mut self) {
        if self.field_len > MAX_FIELD_BYTES {
            self.note_defect(self.field_start, RowErrorKind::OversizedField);
        } else if self.field_overflow {
            self.note_defect(self.field_start, RowErrorKind::NumericOverflow);
        }
        self.field_len = 0;
        self.field_overflow = false;
        let value = if self.negative_flag {
            (self.reg as i32).wrapping_neg() as u32 // two's complement
        } else {
            self.reg
        };
        let nd = self.schema.num_dense;
        if self.col == 0 {
            self.cur_label = value as i32;
        } else if self.col <= nd {
            self.cur_dense[self.col - 1] = value as i32;
        } else if self.col <= nd + self.schema.num_sparse {
            self.cur_sparse[self.col - 1 - nd] = value;
        }
        // Columns beyond the schema are dropped (malformed line).
        self.reg = 0;
        self.negative_flag = false;
        self.col += 1;
        self.hex_mode = self.col > nd;
    }

    /// Reset the scratch row after emitting: unseen trailing columns of
    /// the next row must read as FillMissing's 0.
    #[inline]
    fn reset_row(&mut self) {
        self.cur_label = 0;
        self.cur_dense.fill(0);
        self.cur_sparse.fill(0);
        self.col = 0;
        self.hex_mode = false;
        self.row_start = None;
        self.field_len = 0;
        self.field_overflow = false;
        self.defect = None;
        self.row_buf.clear();
    }

    #[inline]
    fn finish_row_vec(&mut self) {
        self.out.push(DecodedRow {
            label: self.cur_label,
            dense: self.cur_dense.clone(),
            sparse: self.cur_sparse.clone(),
        });
        self.reset_row();
    }

    /// Drain the rows completed so far through the row-wise API without
    /// consuming the assembler.
    pub fn take_rows(&mut self) -> Vec<DecodedRow> {
        std::mem::take(&mut self.out)
    }

    /// Flush into `out`: if input ended without a trailing `\n`, complete
    /// the open row. Callers that fed via [`Self::feed_bytes_into`] must
    /// finish through here (any row-wise-fed rows are appended first,
    /// in order).
    pub fn finish_into<S: PushRow + ?Sized>(&mut self, out: &mut S) {
        for row in std::mem::take(&mut self.out) {
            out.push_row(row.label, &row.dense, &row.sparse);
        }
        if self.col != 0 || self.reg != 0 || self.negative_flag {
            self.finish_field();
            self.emit_row(out);
        } else if self.defect.is_some() || self.field_len > 0 {
            // Trailing bytes that never formed a row the zero-fill path
            // would materialize (garbage after the last newline, or a
            // dangling all-zero field): no row under any policy — the
            // historical behavior — but still one defective row.
            let (offset, kind) = self.defect.take().unwrap_or((
                self.row_start.unwrap_or(self.stream_offset),
                RowErrorKind::WrongFieldCount,
            ));
            self.errors.note(RowError { kind, offset, row: self.rows_seen });
            if self.track_raw {
                let bytes = std::mem::take(&mut self.row_buf);
                self.quarantined.push(QuarantinedRow {
                    row: self.rows_seen,
                    offset: self.row_start.unwrap_or(offset),
                    kind,
                    bytes,
                });
            }
            self.rows_seen += 1;
            self.reset_row();
        }
    }

    /// Row-wise flush: complete the open row, return everything.
    pub fn finish(mut self) -> Vec<DecodedRow> {
        if self.col != 0 || self.reg != 0 || self.negative_flag {
            self.finish_field();
            self.finish_row_vec();
        }
        self.out
    }

    pub fn rows_so_far(&self) -> usize {
        self.out.len()
    }
}

/// Output of a decoder run: the rows plus the cycle count of the modeled
/// hardware unit (used by [`crate::accel`]'s timing model; meaningless
/// for pure-software use) and the illegal bytes the run skipped.
#[derive(Debug)]
pub struct DecodeOutput {
    pub rows: Vec<DecodedRow>,
    /// Modeled FPGA cycles consumed by the decode PE.
    pub cycles: u64,
    /// Illegal bytes skipped, with absolute offsets in `raw`.
    pub illegal: IllegalLog,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RowBlock;

    #[test]
    fn classify_all_legal() {
        assert_eq!(classify(b'\t'), ByteClass::Delim { end_of_row: false });
        assert_eq!(classify(b'\n'), ByteClass::Delim { end_of_row: true });
        assert_eq!(classify(b'-'), ByteClass::Minus);
        assert_eq!(classify(b'0'), ByteClass::Nibble(0));
        assert_eq!(classify(b'9'), ByteClass::Nibble(9));
        assert_eq!(classify(b'a'), ByteClass::Nibble(10));
        assert_eq!(classify(b'f'), ByteClass::Nibble(15));
        assert_eq!(classify(b'g'), ByteClass::Illegal);
        assert_eq!(classify(b' '), ByteClass::Illegal);
    }

    #[test]
    fn illegal_log_caps_details_but_counts_all() {
        let mut log = IllegalLog::default();
        for i in 0..(MAX_RECORDED_ILLEGAL as u64 + 10) {
            log.note(i, b'!');
        }
        assert_eq!(log.recorded.len(), MAX_RECORDED_ILLEGAL);
        assert_eq!(log.total, MAX_RECORDED_ILLEGAL as u64 + 10);
        assert_eq!(log.recorded[0].offset, 0);
    }

    #[test]
    fn illegal_merge_preserves_stream_order_prefix() {
        let mut a = IllegalLog::default();
        a.note(3, b'x');
        let mut b = IllegalLog::default();
        b.note(9, b'y');
        b.note(11, b'z');
        a.merge(&b);
        assert_eq!(a.total, 3);
        assert_eq!(a.recorded.iter().map(|i| i.offset).collect::<Vec<_>>(), vec![3, 9, 11]);
    }

    #[test]
    fn swar_feed_records_offsets_like_scalar() {
        let schema = Schema::new(1, 1);
        let raw = b"1\t4 2\t00x0ff\n9\t!8\taa\n";
        let mut swar_asm = RowAssembler::new(schema);
        let mut swar_rows = RowBlock::new(schema);
        swar_asm.feed_bytes_into(raw, &mut swar_rows);
        let mut scalar_asm = RowAssembler::new(schema);
        let mut scalar_rows = RowBlock::new(schema);
        scalar_asm.feed_bytes_scalar_into(raw, &mut scalar_rows);
        assert_eq!(swar_asm.illegal(), scalar_asm.illegal());
        assert_eq!(swar_rows.to_rows(), scalar_rows.to_rows());
        let offsets: Vec<u64> = swar_asm.illegal().recorded.iter().map(|i| i.offset).collect();
        assert_eq!(offsets, vec![3, 8, 15]); // ' ', 'x', '!'
    }
}
