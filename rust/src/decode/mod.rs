//! UTF-8 decoding: the paper's `Decode` + `FillMissing` operators.
//!
//! Two implementations, bit-exact to each other:
//!
//! * [`scalar`] — the byte-at-a-time state machine of paper Fig. 6
//!   (II = 1 cycle/byte on the FPGA ⇒ ~300 MB/s at 300 MHz, the paper's
//!   identified bottleneck);
//! * [`parallel`] — the 4-byte-per-cycle combination decoder of paper
//!   Script 1 (generalized to width 1/2/4/8 for the ablation bench).
//!
//! Both consume raw bytes and produce [`DecodedRow`]s with missing fields
//! already filled with 0 (on hardware there is no `Null`, paper §3.1),
//! plus a cycle count for the accelerator timing model.

pub mod parallel;
pub mod scalar;

use crate::data::{DecodedRow, Schema};

pub use parallel::ParallelDecoder;
pub use scalar::ScalarDecoder;

/// Byte classes of the raw format (paper §3.2: only `\t \n - 0-9 a-f`
/// can appear).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByteClass {
    /// `\t` or `\n` — both delimiters ("we regard \t and \n the same",
    /// paper §3.3); `\n` additionally ends the row.
    Delim { end_of_row: bool },
    /// `-` minus sign (dense features only).
    Minus,
    /// A hex nibble `0-9a-f` with its 4-bit value.
    Nibble(u8),
    /// Anything else — illegal in the format.
    Illegal,
}

/// Classify one byte (the "upstream module" of paper §3.3 that maps ASCII
/// values to `\t`, `\n`, `-`, `0~f`).
#[inline]
pub fn classify(b: u8) -> ByteClass {
    match CLASS_LUT[b as usize] {
        c if c < 16 => ByteClass::Nibble(c),
        CODE_TAB => ByteClass::Delim { end_of_row: false },
        CODE_NL => ByteClass::Delim { end_of_row: true },
        CODE_MINUS => ByteClass::Minus,
        _ => ByteClass::Illegal,
    }
}

// Byte-class codes for the hot loop: 0..=15 nibble value, then specials.
// In hardware this is the one-cycle combinational classifier; in software
// it is a 256-entry table lookup, which is what lets the per-byte loop
// run branch-lean (EXPERIMENTS.md §Perf).
const CODE_TAB: u8 = 16;
const CODE_NL: u8 = 17;
const CODE_MINUS: u8 = 18;
const CODE_ILLEGAL: u8 = 19;

const CLASS_LUT: [u8; 256] = {
    let mut t = [CODE_ILLEGAL; 256];
    let mut b = b'0';
    while b <= b'9' {
        t[b as usize] = b - b'0';
        b += 1;
    }
    let mut b = b'a';
    while b <= b'f' {
        t[b as usize] = b - b'a' + 10;
        b += 1;
    }
    t[b'\t' as usize] = CODE_TAB;
    t[b'\n' as usize] = CODE_NL;
    t[b'-' as usize] = CODE_MINUS;
    t
};

/// Shared row-assembly state machine: accumulates nibbles into the 32-bit
/// register, finalizes fields on delimiters, assembles [`DecodedRow`]s.
///
/// The field's *mode* (decimal vs hexadecimal accumulate) is selected by
/// the column counter against the [`Schema`] — "what we should know in
/// advance is the data format for each feature" (paper §3.2).
#[derive(Debug)]
pub struct RowAssembler {
    schema: Schema,
    /// 32-bit accumulation register (paper keeps the same width).
    reg: u32,
    /// Set when a `-` was seen in the current field.
    negative_flag: bool,
    /// Current column index (0 = label, then dense, then sparse).
    col: usize,
    /// Cached accumulate mode of the current column (avoids re-deriving
    /// it per nibble — §Perf).
    hex_mode: bool,
    cur: DecodedRow,
    out: Vec<DecodedRow>,
}

impl RowAssembler {
    pub fn new(schema: Schema) -> Self {
        RowAssembler {
            schema,
            reg: 0,
            negative_flag: false,
            col: 0,
            hex_mode: false, // column 0 is the (decimal) label
            cur: DecodedRow::zeroed(schema),
            out: Vec::new(),
        }
    }

    /// Feed one classified byte.
    #[inline]
    pub fn step(&mut self, class: ByteClass) {
        match class {
            ByteClass::Nibble(n) => self.push_nibble(n),
            ByteClass::Minus => self.negative_flag = true,
            ByteClass::Delim { end_of_row } => {
                self.finish_field();
                if end_of_row {
                    self.finish_row();
                }
            }
            ByteClass::Illegal => {
                // Hardware would flag an error line; we skip the byte.
                // Kept non-panicking so fuzzed inputs can't crash the PE.
            }
        }
    }

    #[inline]
    fn push_nibble(&mut self, n: u8) {
        // (a)/(b) of paper §3.2: decimal ×10+digit, hex <<4|digit.
        self.reg = if self.hex_mode {
            (self.reg << 4) | n as u32
        } else {
            self.reg.wrapping_mul(10).wrapping_add(n as u32)
        };
    }

    /// The hot loop: feed a raw byte slice through the LUT classifier.
    /// Equivalent to `for b in bytes { step(classify(b)) }` but
    /// branch-lean — this is what both decoders and the streaming path
    /// call (EXPERIMENTS.md §Perf).
    #[inline]
    pub fn feed_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            let code = CLASS_LUT[b as usize];
            if code < 16 {
                self.push_nibble(code);
            } else if code == CODE_TAB {
                self.finish_field();
            } else if code == CODE_NL {
                self.finish_field();
                self.finish_row();
            } else if code == CODE_MINUS {
                self.negative_flag = true;
            }
            // CODE_ILLEGAL: skipped
        }
    }

    /// (c) of paper §3.2: extract the register on a delimiter. An empty
    /// field leaves reg = 0, which *is* the FillMissing default.
    #[inline]
    fn finish_field(&mut self) {
        let value = if self.negative_flag {
            (self.reg as i32).wrapping_neg() as u32 // two's complement
        } else {
            self.reg
        };
        let nd = self.schema.num_dense;
        if self.col == 0 {
            self.cur.label = value as i32;
        } else if self.col <= nd {
            self.cur.dense[self.col - 1] = value as i32;
        } else if self.col <= nd + self.schema.num_sparse {
            self.cur.sparse[self.col - 1 - nd] = value;
        }
        // Columns beyond the schema are dropped (malformed line).
        self.reg = 0;
        self.negative_flag = false;
        self.col += 1;
        self.hex_mode = self.col > nd;
    }

    #[inline]
    fn finish_row(&mut self) {
        let done = std::mem::replace(&mut self.cur, DecodedRow::zeroed(self.schema));
        self.out.push(done);
        self.col = 0;
        self.hex_mode = false;
    }

    /// Drain the rows completed so far without consuming the assembler —
    /// the streaming (network) path calls this after each chunk.
    pub fn take_rows(&mut self) -> Vec<DecodedRow> {
        std::mem::take(&mut self.out)
    }

    /// Flush: if input ended without a trailing `\n`, complete the open row.
    pub fn finish(mut self) -> Vec<DecodedRow> {
        if self.col != 0 || self.reg != 0 || self.negative_flag {
            self.finish_field();
            self.finish_row();
        }
        self.out
    }

    pub fn rows_so_far(&self) -> usize {
        self.out.len()
    }
}

/// Output of a decoder run: the rows plus the cycle count of the modeled
/// hardware unit (used by [`crate::accel`]'s timing model; meaningless
/// for pure-software use).
#[derive(Debug)]
pub struct DecodeOutput {
    pub rows: Vec<DecodedRow>,
    /// Modeled FPGA cycles consumed by the decode PE.
    pub cycles: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_all_legal() {
        assert_eq!(classify(b'\t'), ByteClass::Delim { end_of_row: false });
        assert_eq!(classify(b'\n'), ByteClass::Delim { end_of_row: true });
        assert_eq!(classify(b'-'), ByteClass::Minus);
        assert_eq!(classify(b'0'), ByteClass::Nibble(0));
        assert_eq!(classify(b'9'), ByteClass::Nibble(9));
        assert_eq!(classify(b'a'), ByteClass::Nibble(10));
        assert_eq!(classify(b'f'), ByteClass::Nibble(15));
        assert_eq!(classify(b'g'), ByteClass::Illegal);
        assert_eq!(classify(b' '), ByteClass::Illegal);
    }
}
