//! UTF-8 decoding: the paper's `Decode` + `FillMissing` operators.
//!
//! Two implementations, bit-exact to each other:
//!
//! * [`scalar`] — the byte-at-a-time state machine of paper Fig. 6
//!   (II = 1 cycle/byte on the FPGA ⇒ ~300 MB/s at 300 MHz, the paper's
//!   identified bottleneck);
//! * [`parallel`] — the 4-byte-per-cycle combination decoder of paper
//!   Script 1 (generalized to width 1/2/4/8 for the ablation bench).
//!
//! Both consume raw bytes and produce decoded rows with missing fields
//! already filled with 0 (on hardware there is no `Null`, paper §3.1),
//! plus a cycle count for the accelerator timing model. The shared
//! [`RowAssembler`] writes completed rows either into a column-major
//! [`RowBlock`] (the engine's zero-alloc streaming path) or into
//! [`DecodedRow`]s (the one-shot decoders' legacy view).

pub mod parallel;
pub mod scalar;

use crate::data::{DecodedRow, RowBlock, Schema};

pub use parallel::ParallelDecoder;
pub use scalar::ScalarDecoder;

/// Byte classes of the raw format (paper §3.2: only `\t \n - 0-9 a-f`
/// can appear).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByteClass {
    /// `\t` or `\n` — both delimiters ("we regard \t and \n the same",
    /// paper §3.3); `\n` additionally ends the row.
    Delim { end_of_row: bool },
    /// `-` minus sign (dense features only).
    Minus,
    /// A hex nibble `0-9a-f` with its 4-bit value.
    Nibble(u8),
    /// Anything else — illegal in the format.
    Illegal,
}

/// Classify one byte (the "upstream module" of paper §3.3 that maps ASCII
/// values to `\t`, `\n`, `-`, `0~f`).
#[inline]
pub fn classify(b: u8) -> ByteClass {
    match CLASS_LUT[b as usize] {
        c if c < 16 => ByteClass::Nibble(c),
        CODE_TAB => ByteClass::Delim { end_of_row: false },
        CODE_NL => ByteClass::Delim { end_of_row: true },
        CODE_MINUS => ByteClass::Minus,
        _ => ByteClass::Illegal,
    }
}

// Byte-class codes for the hot loop: 0..=15 nibble value, then specials.
// In hardware this is the one-cycle combinational classifier; in software
// it is a 256-entry table lookup, which is what lets the per-byte loop
// run branch-lean (EXPERIMENTS.md §Perf).
const CODE_TAB: u8 = 16;
const CODE_NL: u8 = 17;
const CODE_MINUS: u8 = 18;
const CODE_ILLEGAL: u8 = 19;

const CLASS_LUT: [u8; 256] = {
    let mut t = [CODE_ILLEGAL; 256];
    let mut b = b'0';
    while b <= b'9' {
        t[b as usize] = b - b'0';
        b += 1;
    }
    let mut b = b'a';
    while b <= b'f' {
        t[b as usize] = b - b'a' + 10;
        b += 1;
    }
    t[b'\t' as usize] = CODE_TAB;
    t[b'\n' as usize] = CODE_NL;
    t[b'-' as usize] = CODE_MINUS;
    t
};

/// Shared row-assembly state machine: accumulates nibbles into the 32-bit
/// register, finalizes fields on delimiters, assembles rows.
///
/// The field's *mode* (decimal vs hexadecimal accumulate) is selected by
/// the column counter against the [`Schema`] — "what we should know in
/// advance is the data format for each feature" (paper §3.2).
///
/// Completed rows go to a caller-provided column-major [`RowBlock`]
/// ([`Self::feed_bytes_into`] / [`Self::finish_into`] — the engine's
/// zero-alloc path: the assembler owns one fixed scratch row and never
/// allocates per row). The row-wise API ([`Self::feed_bytes`],
/// [`Self::take_rows`], [`Self::finish`]) materializes [`DecodedRow`]s
/// directly (two heap `Vec`s per row, the pre-`RowBlock` cost) — kept
/// for the one-shot decoders, tests, and as the faithful baseline the
/// `rows_columnar` bench measures against.
#[derive(Debug)]
pub struct RowAssembler {
    schema: Schema,
    /// 32-bit accumulation register (paper keeps the same width).
    reg: u32,
    /// Set when a `-` was seen in the current field.
    negative_flag: bool,
    /// Current column index (0 = label, then dense, then sparse).
    col: usize,
    /// Cached accumulate mode of the current column (avoids re-deriving
    /// it per nibble — §Perf).
    hex_mode: bool,
    cur_label: i32,
    cur_dense: Vec<i32>,
    cur_sparse: Vec<u32>,
    /// Rows completed through the row-wise API only; the `_into`
    /// methods bypass it entirely.
    out: Vec<DecodedRow>,
}

impl RowAssembler {
    pub fn new(schema: Schema) -> Self {
        RowAssembler {
            schema,
            reg: 0,
            negative_flag: false,
            col: 0,
            hex_mode: false, // column 0 is the (decimal) label
            cur_label: 0,
            cur_dense: vec![0; schema.num_dense],
            cur_sparse: vec![0; schema.num_sparse],
            out: Vec::new(),
        }
    }

    #[inline]
    fn push_nibble(&mut self, n: u8) {
        // (a)/(b) of paper §3.2: decimal ×10+digit, hex <<4|digit.
        self.reg = if self.hex_mode {
            (self.reg << 4) | n as u32
        } else {
            self.reg.wrapping_mul(10).wrapping_add(n as u32)
        };
    }

    /// The hot loop: feed a raw byte slice through the LUT classifier
    /// (see [`classify`] for the byte-class semantics), appending every
    /// completed row to `out` — this is what the streaming engine calls
    /// (EXPERIMENTS.md §Perf). No allocation happens per row: fields
    /// accumulate in the assembler's scratch row, and `finish_row_into`
    /// writes it column-wise into the block. Illegal bytes are skipped
    /// non-panicking (hardware would flag an error line), so fuzzed
    /// inputs can't crash the PE.
    #[inline]
    pub fn feed_bytes_into(&mut self, bytes: &[u8], out: &mut RowBlock) {
        for &b in bytes {
            let code = CLASS_LUT[b as usize];
            if code < 16 {
                self.push_nibble(code);
            } else if code == CODE_TAB {
                self.finish_field();
            } else if code == CODE_NL {
                self.finish_field();
                self.finish_row_into(out);
            } else if code == CODE_MINUS {
                self.negative_flag = true;
            }
            // CODE_ILLEGAL: skipped
        }
    }

    /// Row-wise feed: the same classifier loop, materializing each
    /// completed row as a [`DecodedRow`] (two allocations per row —
    /// exactly the representation the columnar engine retired; kept
    /// un-degraded so the one-shot decoders and the `rows_columnar`
    /// baseline measure the true pre-`RowBlock` cost).
    #[inline]
    pub fn feed_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            let code = CLASS_LUT[b as usize];
            if code < 16 {
                self.push_nibble(code);
            } else if code == CODE_TAB {
                self.finish_field();
            } else if code == CODE_NL {
                self.finish_field();
                self.finish_row_vec();
            } else if code == CODE_MINUS {
                self.negative_flag = true;
            }
            // CODE_ILLEGAL: skipped
        }
    }

    /// (c) of paper §3.2: extract the register on a delimiter. An empty
    /// field leaves reg = 0, which *is* the FillMissing default.
    #[inline]
    fn finish_field(&mut self) {
        let value = if self.negative_flag {
            (self.reg as i32).wrapping_neg() as u32 // two's complement
        } else {
            self.reg
        };
        let nd = self.schema.num_dense;
        if self.col == 0 {
            self.cur_label = value as i32;
        } else if self.col <= nd {
            self.cur_dense[self.col - 1] = value as i32;
        } else if self.col <= nd + self.schema.num_sparse {
            self.cur_sparse[self.col - 1 - nd] = value;
        }
        // Columns beyond the schema are dropped (malformed line).
        self.reg = 0;
        self.negative_flag = false;
        self.col += 1;
        self.hex_mode = self.col > nd;
    }

    /// Reset the scratch row after emitting: unseen trailing columns of
    /// the next row must read as FillMissing's 0.
    #[inline]
    fn reset_row(&mut self) {
        self.cur_label = 0;
        self.cur_dense.fill(0);
        self.cur_sparse.fill(0);
        self.col = 0;
        self.hex_mode = false;
    }

    #[inline]
    fn finish_row_into(&mut self, out: &mut RowBlock) {
        out.push_row(self.cur_label, &self.cur_dense, &self.cur_sparse);
        self.reset_row();
    }

    #[inline]
    fn finish_row_vec(&mut self) {
        self.out.push(DecodedRow {
            label: self.cur_label,
            dense: self.cur_dense.clone(),
            sparse: self.cur_sparse.clone(),
        });
        self.reset_row();
    }

    /// Drain the rows completed so far through the row-wise API without
    /// consuming the assembler.
    pub fn take_rows(&mut self) -> Vec<DecodedRow> {
        std::mem::take(&mut self.out)
    }

    /// Flush into `out`: if input ended without a trailing `\n`, complete
    /// the open row. Callers that fed via [`Self::feed_bytes_into`] must
    /// finish through here (any row-wise-fed rows are appended first,
    /// in order).
    pub fn finish_into(mut self, out: &mut RowBlock) {
        for row in &self.out {
            out.push_row(row.label, &row.dense, &row.sparse);
        }
        if self.col != 0 || self.reg != 0 || self.negative_flag {
            self.finish_field();
            self.finish_row_into(out);
        }
    }

    /// Row-wise flush: complete the open row, return everything.
    pub fn finish(mut self) -> Vec<DecodedRow> {
        if self.col != 0 || self.reg != 0 || self.negative_flag {
            self.finish_field();
            self.finish_row_vec();
        }
        self.out
    }

    pub fn rows_so_far(&self) -> usize {
        self.out.len()
    }
}

/// Output of a decoder run: the rows plus the cycle count of the modeled
/// hardware unit (used by [`crate::accel`]'s timing model; meaningless
/// for pure-software use).
#[derive(Debug)]
pub struct DecodeOutput {
    pub rows: Vec<DecodedRow>,
    /// Modeled FPGA cycles consumed by the decode PE.
    pub cycles: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_all_legal() {
        assert_eq!(classify(b'\t'), ByteClass::Delim { end_of_row: false });
        assert_eq!(classify(b'\n'), ByteClass::Delim { end_of_row: true });
        assert_eq!(classify(b'-'), ByteClass::Minus);
        assert_eq!(classify(b'0'), ByteClass::Nibble(0));
        assert_eq!(classify(b'9'), ByteClass::Nibble(9));
        assert_eq!(classify(b'a'), ByteClass::Nibble(10));
        assert_eq!(classify(b'f'), ByteClass::Nibble(15));
        assert_eq!(classify(b'g'), ByteClass::Illegal);
        assert_eq!(classify(b' '), ByteClass::Illegal);
    }
}
