//! Row-level error containment: a typed defect taxonomy, containment
//! policies, and error budgets.
//!
//! The decoder (scalar and SWAR alike) classifies every malformed row it
//! meets into a [`RowErrorKind`] and then applies an [`ErrorPolicy`] to
//! decide the row's fate: emit it zero-filled (the engine's historical
//! behavior), drop it, capture its raw bytes for replay, or abort the job.
//! Detection is **independent of policy** — the same input produces the
//! same [`RowErrorLog`] under every policy, which is what lets two-pass
//! plans make identical keep/drop decisions on both passes and lets a
//! cluster merge per-worker counters without re-reading bytes.
//!
//! Offsets in this module are **stream-absolute**: byte positions in the
//! logical input stream, stable across chunk boundaries, shard splits, and
//! decode-thread counts. The equivalence suite pins that the scalar and
//! SWAR paths report the same kinds at the same offsets.

use std::fmt;
use std::path::PathBuf;

use super::IllegalLog;

/// Classification of a malformed row.
///
/// A row carries at most one kind: the first defect *detected* wins.
/// Detection order is deterministic and identical across decode paths —
/// field-level defects (overflow, oversize) are noted when their field
/// closes, illegal bytes immediately, and wrong field count when the row
/// ends — but it is not necessarily offset order within the row.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum RowErrorKind {
    /// A byte outside the dialect (not a nibble, `\t`, `\n`, or `-`).
    IllegalByte = 0,
    /// The row closed with a field count different from the schema's
    /// `1 + dense + sparse`. Truncated rows and over-wide rows both land
    /// here, as does a binary stream that ends mid-row.
    WrongFieldCount = 1,
    /// A numeric field whose value exceeds `u32::MAX` before wrapping.
    NumericOverflow = 2,
    /// A single field longer than [`MAX_FIELD_BYTES`](super::MAX_FIELD_BYTES).
    OversizedField = 3,
}

impl RowErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            RowErrorKind::IllegalByte => "illegal-byte",
            RowErrorKind::WrongFieldCount => "wrong-field-count",
            RowErrorKind::NumericOverflow => "numeric-overflow",
            RowErrorKind::OversizedField => "oversized-field",
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(b: u8) -> Option<RowErrorKind> {
        match b {
            0 => Some(RowErrorKind::IllegalByte),
            1 => Some(RowErrorKind::WrongFieldCount),
            2 => Some(RowErrorKind::NumericOverflow),
            3 => Some(RowErrorKind::OversizedField),
            _ => None,
        }
    }
}

impl fmt::Display for RowErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One defective row: what was wrong, where the defect sits in the stream,
/// and which row (0-based, counted over *all* rows, kept or not) it was.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowError {
    pub kind: RowErrorKind,
    /// Stream-absolute byte offset of the defect: the illegal byte, the
    /// first byte of the offending field, or the row start for a wrong
    /// field count.
    pub offset: u64,
    /// 0-based index of the row in the input stream.
    pub row: u64,
}

impl fmt::Display for RowError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "row {}: {} at byte {}", self.row, self.kind, self.offset)
    }
}

/// Default number of [`RowError`] details (and illegal-byte details) kept
/// per run; totals keep counting past the cap.
pub const DEFAULT_ERROR_DETAILS: usize = 64;

/// Bounded log of defective rows: full counts, capped detail.
///
/// Mirrors [`IllegalLog`]'s contract: `recorded` keeps the first `cap`
/// errors in stream order, `total` and the per-kind counters never stop.
/// Merging shard logs in shard order preserves "first `cap` in stream
/// order" because each shard's log is itself a stream-ordered prefix.
#[derive(Debug, Clone)]
pub struct RowErrorLog {
    pub recorded: Vec<RowError>,
    pub total: u64,
    /// Per-kind totals, indexed by `RowErrorKind as u8`.
    pub by_kind: [u64; 4],
    cap: usize,
}

impl Default for RowErrorLog {
    fn default() -> Self {
        RowErrorLog::with_cap(DEFAULT_ERROR_DETAILS)
    }
}

/// Capacity is a tuning knob, not an observation — two logs that saw the
/// same errors compare equal even if their caps differ.
impl PartialEq for RowErrorLog {
    fn eq(&self, other: &Self) -> bool {
        self.recorded == other.recorded
            && self.total == other.total
            && self.by_kind == other.by_kind
    }
}

impl Eq for RowErrorLog {}

impl RowErrorLog {
    pub fn with_cap(cap: usize) -> RowErrorLog {
        RowErrorLog { recorded: Vec::new(), total: 0, by_kind: [0; 4], cap }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn note(&mut self, err: RowError) {
        if self.recorded.len() < self.cap {
            self.recorded.push(err);
        }
        self.total += 1;
        self.by_kind[err.kind.as_u8() as usize] += 1;
    }

    /// Fold `other` (a later stream segment) into `self`, keeping detail
    /// up to `self.cap`.
    pub fn merge(&mut self, other: &RowErrorLog) {
        for err in &other.recorded {
            if self.recorded.len() >= self.cap {
                break;
            }
            self.recorded.push(*err);
        }
        self.total += other.total;
        for (mine, theirs) in self.by_kind.iter_mut().zip(other.by_kind) {
            *mine += theirs;
        }
    }

    /// The earliest recorded error (stream order), if any.
    pub fn first(&self) -> Option<&RowError> {
        self.recorded.first()
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }
}

/// What to do with a row the decoder has classified as defective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum ErrorPolicy {
    /// Abort the job with a typed [`DataError`] naming the first defect.
    Fail = 0,
    /// Emit the row with unparseable content zero-filled — the engine's
    /// historical behavior and the default.
    #[default]
    Zero = 1,
    /// Drop the row and count it.
    Skip = 2,
    /// Drop the row, count it, and capture its raw bytes + offset + reason
    /// for the quarantine sink.
    Quarantine = 3,
}

impl ErrorPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ErrorPolicy::Fail => "fail",
            ErrorPolicy::Zero => "zero",
            ErrorPolicy::Skip => "skip",
            ErrorPolicy::Quarantine => "quarantine",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ErrorPolicy> {
        match s {
            "fail" => Ok(ErrorPolicy::Fail),
            "zero" => Ok(ErrorPolicy::Zero),
            "skip" => Ok(ErrorPolicy::Skip),
            "quarantine" => Ok(ErrorPolicy::Quarantine),
            _ => anyhow::bail!(
                "unknown error policy '{s}' (expected fail|zero|skip|quarantine)"
            ),
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(b: u8) -> Option<ErrorPolicy> {
        match b {
            0 => Some(ErrorPolicy::Fail),
            1 => Some(ErrorPolicy::Zero),
            2 => Some(ErrorPolicy::Skip),
            3 => Some(ErrorPolicy::Quarantine),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// How many defective rows a job tolerates before aborting.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ErrorBudget {
    #[default]
    Unlimited,
    /// Abort once more than `n` rows are defective.
    Count(u64),
    /// Abort once the defective fraction of rows seen exceeds this rate
    /// (checked at chunk granularity, so short bursts early in the stream
    /// are judged against the rows seen so far, not the whole input).
    Rate(f64),
}

impl ErrorBudget {
    /// `true` once the budget is blown: `errors` defective rows out of
    /// `rows` seen so far.
    pub fn exceeded(&self, errors: u64, rows: u64) -> bool {
        match *self {
            ErrorBudget::Unlimited => false,
            ErrorBudget::Count(n) => errors > n,
            ErrorBudget::Rate(r) => rows > 0 && (errors as f64) > r * (rows as f64),
        }
    }

    /// Parse a CLI budget: `none`, an absolute count (`12`), a percentage
    /// (`0.5%`), or a bare fraction (`0.005`).
    pub fn parse(s: &str) -> anyhow::Result<ErrorBudget> {
        if s == "none" || s == "unlimited" {
            return Ok(ErrorBudget::Unlimited);
        }
        if let Some(pct) = s.strip_suffix('%') {
            let r: f64 = pct
                .parse()
                .map_err(|_| anyhow::anyhow!("bad error rate '{s}'"))?;
            anyhow::ensure!(
                (0.0..=100.0).contains(&r),
                "error rate '{s}' out of range"
            );
            return Ok(ErrorBudget::Rate(r / 100.0));
        }
        if s.contains('.') {
            let r: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad error rate '{s}'"))?;
            anyhow::ensure!((0.0..=1.0).contains(&r), "error rate '{s}' out of range");
            return Ok(ErrorBudget::Rate(r));
        }
        let n: u64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad error budget '{s}'"))?;
        Ok(ErrorBudget::Count(n))
    }

    /// Wire form: a tag byte plus a little-endian f64 payload (counts are
    /// exact below 2^53, far beyond any realistic budget).
    pub fn to_wire(self) -> (u8, f64) {
        match self {
            ErrorBudget::Unlimited => (0, 0.0),
            ErrorBudget::Count(n) => (1, n as f64),
            ErrorBudget::Rate(r) => (2, r),
        }
    }

    pub fn from_wire(tag: u8, val: f64) -> Option<ErrorBudget> {
        match tag {
            0 => Some(ErrorBudget::Unlimited),
            1 => Some(ErrorBudget::Count(val as u64)),
            2 => Some(ErrorBudget::Rate(val)),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorBudget {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ErrorBudget::Unlimited => f.write_str("unlimited"),
            ErrorBudget::Count(n) => write!(f, "{n} rows"),
            ErrorBudget::Rate(r) => write!(f, "{:.4}% of rows", r * 100.0),
        }
    }
}

/// Complete containment configuration threaded from the CLI / wire job
/// down to every row assembler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorConfig {
    pub policy: ErrorPolicy,
    pub budget: ErrorBudget,
    /// Detail cap for both [`RowErrorLog`] and [`IllegalLog`].
    pub detail_cap: usize,
}

impl Default for ErrorConfig {
    fn default() -> Self {
        ErrorConfig {
            policy: ErrorPolicy::default(),
            budget: ErrorBudget::default(),
            detail_cap: DEFAULT_ERROR_DETAILS,
        }
    }
}

impl ErrorConfig {
    /// The configuration for a non-emitting (vocabulary observation) pass.
    ///
    /// Quarantine downgrades to skip: the keep/drop decisions are
    /// identical, but raw bytes are captured — and counters reported —
    /// only on the emit pass, matching the engine's "a two-pass plan reads
    /// the bytes twice but reports them once" convention.
    pub fn for_observe_pass(self) -> ErrorConfig {
        ErrorConfig {
            policy: match self.policy {
                ErrorPolicy::Quarantine => ErrorPolicy::Skip,
                p => p,
            },
            ..self
        }
    }
}

/// A row captured for the quarantine sink: enough to re-ingest it after an
/// upstream fix, and enough to explain why it was pulled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuarantinedRow {
    /// 0-based index of the row in the input stream.
    pub row: u64,
    /// Stream-absolute offset of the row's first byte.
    pub offset: u64,
    pub kind: RowErrorKind,
    /// The raw row bytes as read (utf8 rows include their terminator when
    /// the stream had one), truncated at
    /// [`MAX_QUARANTINE_ROW_BYTES`](super::MAX_QUARANTINE_ROW_BYTES).
    pub bytes: Vec<u8>,
}

/// Everything a finished decoder knows about the stream's defects.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct DecodeTally {
    pub illegal: IllegalLog,
    pub errors: RowErrorLog,
    /// Rows quarantined at finish time (per-chunk captures are drained
    /// incrementally; see `ChunkDecoder::take_quarantined`).
    pub quarantined: Vec<QuarantinedRow>,
    /// Every row the decoder saw, kept or not.
    pub rows_seen: u64,
}

/// Typed abort raised by `on_error=fail` and blown error budgets. Sits at
/// the root of an `anyhow` chain; recover it with [`DataError::of`].
#[derive(Debug, Clone, PartialEq)]
pub enum DataError {
    /// Strict mode hit a defective row.
    Row(RowError),
    /// The error budget is exhausted.
    BudgetExceeded {
        errors: u64,
        rows: u64,
        budget: ErrorBudget,
        /// The first recorded defect, when detail survived the cap.
        first: Option<RowError>,
    },
}

impl fmt::Display for DataError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataError::Row(err) => {
                write!(f, "malformed input ({}): {err}", err.kind)
            }
            DataError::BudgetExceeded { errors, rows, budget, first } => {
                write!(
                    f,
                    "error budget exceeded: {errors} defective of {rows} rows (budget {budget})"
                )?;
                if let Some(err) = first {
                    write!(f, "; first: {err}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for DataError {}

impl DataError {
    /// Recover the typed fault from an `anyhow` chain, if one is there.
    pub fn of(err: &anyhow::Error) -> Option<&DataError> {
        err.chain().find_map(|e| e.downcast_ref::<DataError>())
    }
}

/// Where quarantined rows went: the side file plus how many records it
/// holds. Carried on `RunReport`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct QuarantineSummary {
    pub path: Option<PathBuf>,
    pub rows: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_semantics() {
        assert!(!ErrorBudget::Unlimited.exceeded(u64::MAX, 1));
        assert!(!ErrorBudget::Count(3).exceeded(3, 10));
        assert!(ErrorBudget::Count(3).exceeded(4, 10));
        assert!(!ErrorBudget::Rate(0.5).exceeded(5, 10));
        assert!(ErrorBudget::Rate(0.5).exceeded(6, 10));
        assert!(!ErrorBudget::Rate(0.5).exceeded(0, 0));
    }

    #[test]
    fn budget_parses() {
        assert_eq!(ErrorBudget::parse("none").unwrap(), ErrorBudget::Unlimited);
        assert_eq!(ErrorBudget::parse("12").unwrap(), ErrorBudget::Count(12));
        assert_eq!(ErrorBudget::parse("0.5%").unwrap(), ErrorBudget::Rate(0.005));
        assert_eq!(ErrorBudget::parse("0.02").unwrap(), ErrorBudget::Rate(0.02));
        assert!(ErrorBudget::parse("101%").is_err());
        assert!(ErrorBudget::parse("nope").is_err());
    }

    #[test]
    fn policy_round_trips() {
        for p in [
            ErrorPolicy::Fail,
            ErrorPolicy::Zero,
            ErrorPolicy::Skip,
            ErrorPolicy::Quarantine,
        ] {
            assert_eq!(ErrorPolicy::parse(p.name()).unwrap(), p);
            assert_eq!(ErrorPolicy::from_u8(p.as_u8()), Some(p));
        }
        assert!(ErrorPolicy::parse("drop").is_err());
    }

    #[test]
    fn log_caps_detail_not_totals() {
        let mut log = RowErrorLog::with_cap(2);
        for i in 0..5 {
            log.note(RowError { kind: RowErrorKind::IllegalByte, offset: i, row: i });
        }
        assert_eq!(log.recorded.len(), 2);
        assert_eq!(log.total, 5);
        assert_eq!(log.by_kind[RowErrorKind::IllegalByte.as_u8() as usize], 5);
        assert_eq!(log.first().unwrap().offset, 0);
    }

    #[test]
    fn log_merge_keeps_stream_order_prefix() {
        let mut a = RowErrorLog::with_cap(3);
        a.note(RowError { kind: RowErrorKind::WrongFieldCount, offset: 1, row: 0 });
        let mut b = RowErrorLog::with_cap(3);
        b.note(RowError { kind: RowErrorKind::NumericOverflow, offset: 9, row: 4 });
        b.note(RowError { kind: RowErrorKind::OversizedField, offset: 12, row: 5 });
        b.note(RowError { kind: RowErrorKind::IllegalByte, offset: 20, row: 6 });
        a.merge(&b);
        assert_eq!(a.total, 4);
        assert_eq!(a.recorded.len(), 3);
        assert_eq!(a.recorded[1].offset, 9);
        assert_eq!(a.by_kind, [1, 1, 1, 1]);
    }

    #[test]
    fn observe_pass_downgrades_quarantine_only() {
        let cfg = ErrorConfig {
            policy: ErrorPolicy::Quarantine,
            budget: ErrorBudget::Count(5),
            detail_cap: 7,
        };
        let obs = cfg.for_observe_pass();
        assert_eq!(obs.policy, ErrorPolicy::Skip);
        assert_eq!(obs.budget, cfg.budget);
        assert_eq!(obs.detail_cap, 7);
        assert_eq!(
            ErrorConfig { policy: ErrorPolicy::Skip, ..cfg }.for_observe_pass().policy,
            ErrorPolicy::Skip
        );
    }
}
