//! Byte-at-a-time decoder — paper Fig. 6.
//!
//! On the FPGA this PE has II = 1 cycle but consumes **one byte per
//! cycle**: a 512-bit memory lane delivers 64 B/cycle, so the straight
//! decoder caps effective memory throughput at 1/64th (paper §3.3 —
//! "decoding data per byte is 64 times slower and limits the valid
//! throughput to 300MB/s"). It is the reference implementation the
//! parallel decoder must match bit-for-bit.

use crate::data::{DecodedRow, Schema};

use super::{DecodeOutput, RowAssembler};

/// The scalar decode PE.
#[derive(Debug)]
pub struct ScalarDecoder {
    schema: Schema,
}

impl ScalarDecoder {
    pub fn new(schema: Schema) -> Self {
        ScalarDecoder { schema }
    }

    /// Decode a whole raw buffer. Cycles = number of input bytes
    /// (II = 1, one byte/cycle).
    pub fn decode(&self, raw: &[u8]) -> DecodeOutput {
        let mut asm = RowAssembler::new(self.schema);
        asm.feed_bytes(raw);
        let illegal = asm.take_illegal();
        DecodeOutput { rows: asm.finish(), cycles: raw.len() as u64, illegal }
    }

    /// Decode a single line (no trailing newline required).
    pub fn decode_line(&self, line: &[u8]) -> Option<DecodedRow> {
        let mut asm = RowAssembler::new(self.schema);
        asm.feed_bytes(line);
        asm.finish().into_iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, utf8, SynthDataset};

    fn tiny_schema() -> Schema {
        Schema::new(2, 2)
    }

    #[test]
    fn decodes_simple_line() {
        let d = ScalarDecoder::new(tiny_schema());
        let row = d.decode_line(b"1\t42\t-7\tdeadbeef\t0000000a").unwrap();
        assert_eq!(row.label, 1);
        assert_eq!(row.dense, vec![42, -7]);
        assert_eq!(row.sparse, vec![0xdeadbeef, 0xa]);
    }

    #[test]
    fn empty_fields_become_zero() {
        let d = ScalarDecoder::new(tiny_schema());
        let row = d.decode_line(b"0\t\t5\t\tff").unwrap();
        assert_eq!(row.dense, vec![0, 5]);
        assert_eq!(row.sparse, vec![0, 0xff]);
    }

    #[test]
    fn negative_two_complement() {
        let d = ScalarDecoder::new(tiny_schema());
        let row = d.decode_line(b"0\t-123\t-1\t0\t0").unwrap();
        assert_eq!(row.dense, vec![-123, -1]);
    }

    #[test]
    fn multiple_rows_and_cycles() {
        let d = ScalarDecoder::new(tiny_schema());
        let raw = b"1\t1\t2\taa\tbb\n0\t3\t4\tcc\tdd\n";
        let out = d.decode(raw);
        assert_eq!(out.rows.len(), 2);
        assert_eq!(out.cycles, raw.len() as u64);
        assert_eq!(out.rows[1].sparse, vec![0xcc, 0xdd]);
    }

    #[test]
    fn missing_trailing_newline_still_emits_row() {
        let d = ScalarDecoder::new(tiny_schema());
        let out = d.decode(b"1\t1\t2\taa\tbb");
        assert_eq!(out.rows.len(), 1);
        assert_eq!(out.rows[0].sparse, vec![0xaa, 0xbb]);
    }

    #[test]
    fn roundtrips_synth_dataset() {
        let ds = SynthDataset::generate(SynthConfig::small(400));
        let raw = utf8::encode_dataset(&ds);
        let out = ScalarDecoder::new(ds.schema()).decode(&raw);
        assert_eq!(out.rows, ds.rows, "decode(encode(x)) must equal x");
    }

    #[test]
    fn illegal_bytes_skipped_not_panic() {
        let d = ScalarDecoder::new(tiny_schema());
        let row = d.decode_line(b"1\t4 2\t0\t0\t0").unwrap();
        assert_eq!(row.dense[0], 42); // space skipped
    }

    #[test]
    fn hex_register_shift_matches_paper() {
        // sparse accumulation: reg = (reg << 4) | nibble
        let d = ScalarDecoder::new(Schema::new(0, 1));
        let row = d.decode_line(b"0\t00000123").unwrap();
        assert_eq!(row.sparse[0], 0x123);
    }
}
