//! `Hex2Int` — hexadecimal string → integer (paper Table 1).
//!
//! In Meta's CPU pipeline this is a real per-value string conversion
//! ("each thread has to convert them first to decimal values before
//! processing", paper §2.3) and one of the costliest operators in
//! Table 4 (655 s single-thread over the dataset). On PIPER it
//! disappears: the decode PE already leaves a 32-bit value in the
//! register, so "there is no need to transform from hexadecimal to
//! decimal explicitly" (paper §3.1).
//!
//! The CPU baseline calls [`hex2int`] in its GV hot loop to reproduce
//! that cost honestly.

/// Parse an up-to-8-digit lowercase-hex field. Returns `None` on any
/// illegal byte (caller treats as missing → 0).
#[inline]
pub fn hex2int(field: &[u8]) -> Option<u32> {
    if field.is_empty() || field.len() > 8 {
        return None;
    }
    let mut v: u32 = 0;
    for &b in field {
        let nibble = match b {
            b'0'..=b'9' => b - b'0',
            b'a'..=b'f' => b - b'a' + 10,
            _ => return None,
        };
        v = (v << 4) | nibble as u32;
    }
    Some(v)
}

/// Parse a signed decimal field (dense features / label).
#[inline]
pub fn dec2int(field: &[u8]) -> Option<i32> {
    if field.is_empty() {
        return None;
    }
    let (neg, digits) = match field[0] {
        b'-' => (true, &field[1..]),
        _ => (false, field),
    };
    if digits.is_empty() {
        return None;
    }
    let mut v: i64 = 0;
    for &b in digits {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v * 10 + (b - b'0') as i64;
        if v > u32::MAX as i64 {
            return None; // 32-bit register semantics
        }
    }
    Some(if neg { -(v as i32) } else { v as i32 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_parses() {
        assert_eq!(hex2int(b"0"), Some(0));
        assert_eq!(hex2int(b"ff"), Some(255));
        assert_eq!(hex2int(b"deadbeef"), Some(0xdeadbeef));
        assert_eq!(hex2int(b"00000001"), Some(1));
    }

    #[test]
    fn hex_rejects_bad() {
        assert_eq!(hex2int(b""), None);
        assert_eq!(hex2int(b"deadbeef0"), None); // 9 digits
        assert_eq!(hex2int(b"xyz"), None);
        assert_eq!(hex2int(b"DEAD"), None); // uppercase not in format
    }

    #[test]
    fn dec_parses() {
        assert_eq!(dec2int(b"0"), Some(0));
        assert_eq!(dec2int(b"42"), Some(42));
        assert_eq!(dec2int(b"-7"), Some(-7));
    }

    #[test]
    fn dec_rejects_bad() {
        assert_eq!(dec2int(b""), None);
        assert_eq!(dec2int(b"-"), None);
        assert_eq!(dec2int(b"1a"), None);
        assert_eq!(dec2int(b"99999999999"), None);
    }

    #[test]
    fn hex_matches_decoder_register_semantics() {
        // The decode PE computes reg = (reg<<4)|nibble — same result.
        use crate::data::Schema;
        use crate::decode::ScalarDecoder;
        let d = ScalarDecoder::new(Schema::new(0, 1));
        for s in [&b"abc123"[..], b"0", b"ffffffff"] {
            let mut line = b"0\t".to_vec();
            line.extend_from_slice(s);
            let row = d.decode_line(&line).unwrap();
            assert_eq!(row.sparse[0], hex2int(s).unwrap());
        }
    }
}
