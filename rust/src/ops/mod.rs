//! The operator library of paper Table 1.
//!
//! | op          | here                                   |
//! |-------------|----------------------------------------|
//! | Decode      | [`crate::decode`]                      |
//! | FillMissing | merged into Decode (hardware default 0) + [`fill_missing`] |
//! | Hex2Int     | [`hex::hex2int`] (string→u32; a no-op post-decode, paper §3.1) |
//! | Modulus     | [`Modulus`]                            |
//! | GenVocab    | [`vocab::Vocab::observe`] / loop-1 PEs  |
//! | ApplyVocab  | [`vocab::Vocab::apply`] / loop-2 PEs    |
//! | Neg2Zero    | [`neg2zero`]                           |
//! | Logarithm   | [`log1p`]                              |
//! | Concatenate | [`crate::data::row::ProcessedColumns::extend_from`] |
//! | Clip        | [`DenseKernel::Clip`] (per-column extension)     |
//! | Bucketize   | [`DenseKernel::Bucketize`] (per-column extension) |
//!
//! All operators are value-level functions plus slice-level batch forms —
//! the batch forms are what the CPU baseline's hot loops and the
//! accelerator's PE models call. Which operator runs on which column is
//! decided by typed per-column programs ([`program`]): a
//! [`PipelineSpec`] binds a [`ColumnProgram`] to column selectors and
//! compiles to one fixed-function slot per column ([`ColumnPlans`]).

pub mod artifact;
pub mod hex;
pub mod program;
pub mod spec;
pub mod vocab;

pub use artifact::VocabArtifact;
pub use program::{
    ColumnKind, ColumnOp, ColumnPlans, ColumnProgram, ColumnRange, ColumnSelector,
    DenseColPlan, DenseKernel, SparseColPlan,
};
/// Historical name for [`ColumnOp`] — the parsed spec token.
pub use program::ColumnOp as OpSpec;
pub use spec::{PipelineSpec, SpecRule};
pub use vocab::{DirectVocab, HashVocab, Vocab, VocabSet, VOCAB_MISS};

/// `FillMissing`: absent value → 0 (paper Table 1 — the default for empty
/// entries "irrespective of whether the feature is sparse or dense").
#[inline]
pub fn fill_missing<T: Default>(v: Option<T>) -> T {
    v.unwrap_or_default()
}

/// `Neg2Zero`: the ternary operator `x < 0 ? 0 : x` (paper §3.2 — dense
/// features have a non-negativity constraint).
#[inline]
pub fn neg2zero(x: i32) -> i32 {
    if x < 0 {
        0
    } else {
        x
    }
}

/// Batch `Neg2Zero` over a dense column.
pub fn neg2zero_slice(xs: &mut [i32]) {
    for x in xs {
        *x = neg2zero(*x);
    }
}

/// `Logarithm`: `log(x + 1)` (paper Table 1). Input is post-`Neg2Zero`,
/// i.e. non-negative; negative inputs are clamped first so the function
/// is total. Computed as f32 `ln_1p` — exact to f32 rounding for the
/// integer inputs this pipeline sees, and ~2× faster than the f64 path
/// (EXPERIMENTS.md §Perf).
#[inline]
pub fn log1p(x: i32) -> f32 {
    (neg2zero(x) as f32).ln_1p()
}

/// Batch dense finisher: `Neg2Zero` + `Logarithm` fused (the accelerator
/// chains the two PEs; software fuses the loop). Small non-negative
/// integers — the overwhelmingly common case for count features — hit an
/// L1-resident lookup table instead of `ln_1p` (§Perf).
pub fn dense_finish_slice(xs: &[i32], out: &mut Vec<f32>) {
    out.reserve(xs.len());
    for &x in xs {
        let v = if (x as usize) < LOG_LUT_SIZE {
            // non-negative and < LUT size (negatives wrap to huge usize)
            log_lut()[x as usize]
        } else {
            log1p(x)
        };
        out.push(v);
    }
}

const LOG_LUT_SIZE: usize = 4096;

/// `log(x+1)` for x in 0..4096, built once (16 KiB, L1-resident).
fn log_lut() -> &'static [f32; LOG_LUT_SIZE] {
    use std::sync::OnceLock;
    static LUT: OnceLock<[f32; LOG_LUT_SIZE]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0.0f32; LOG_LUT_SIZE];
        for (i, v) in t.iter_mut().enumerate() {
            *v = log1p(i as i32);
        }
        t
    })
}

/// `Modulus`: positive modulus limiting a sparse feature to the embedding
/// range (paper Table 1 — "sets the range of sparse features to limit the
/// size ... of the embedding table").
///
/// Uses Lemire's fastmod (precomputed magic) instead of a hardware
/// divide: the parse hot loop applies this 26× per row, and the
/// division was a measurable fraction of GV (§Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Modulus {
    pub range: u32,
    magic: u64,
}

const fn fastmod_magic(range: u32) -> u64 {
    if range == 1 {
        0 // unused: x % 1 == 0, special-cased in apply()
    } else {
        (u64::MAX / range as u64) + 1
    }
}

impl Modulus {
    pub fn new(range: u32) -> Self {
        assert!(range > 0, "modulus range must be positive");
        Modulus { range, magic: fastmod_magic(range) }
    }

    /// The paper's two vocabulary regimes.
    pub const VOCAB_5K: Modulus =
        Modulus { range: 5_000, magic: fastmod_magic(5_000) };
    pub const VOCAB_1M: Modulus =
        Modulus { range: 1_000_000, magic: fastmod_magic(1_000_000) };

    #[inline]
    pub fn apply(&self, x: u32) -> u32 {
        if self.range == 1 {
            return 0; // magic overflows for d=1; trivially 0 anyway
        }
        let lowbits = self.magic.wrapping_mul(x as u64);
        ((lowbits as u128 * self.range as u128) >> 64) as u32
    }

    /// Positive modulus of a *signed* value (Meta's software treats the
    /// hash as signed; `((x % m) + m) % m` keeps the result in range).
    #[inline]
    pub fn apply_signed(&self, x: i64) -> u32 {
        let m = self.range as i64;
        (((x % m) + m) % m) as u32
    }

    /// Batch form over a sparse column.
    pub fn apply_slice(&self, xs: &mut [u32]) {
        for x in xs {
            *x %= self.range;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_missing_defaults() {
        assert_eq!(fill_missing::<i32>(None), 0);
        assert_eq!(fill_missing(Some(7)), 7);
    }

    #[test]
    fn neg2zero_ternary() {
        assert_eq!(neg2zero(-5), 0);
        assert_eq!(neg2zero(0), 0);
        assert_eq!(neg2zero(5), 5);
        assert_eq!(neg2zero(i32::MIN), 0);
    }

    #[test]
    fn log1p_values() {
        assert_eq!(log1p(0), 0.0);
        assert!((log1p(1) - std::f32::consts::LN_2).abs() < 1e-6);
        // negative input clamps to 0 first
        assert_eq!(log1p(-10), 0.0);
        // monotone
        assert!(log1p(100) < log1p(101));
    }

    #[test]
    fn modulus_limits_range() {
        let m = Modulus::new(5000);
        assert_eq!(m.apply(4999), 4999);
        assert_eq!(m.apply(5000), 0);
        assert_eq!(m.apply(123_456_789), 123_456_789 % 5000);
    }

    #[test]
    fn modulus_signed_is_positive() {
        let m = Modulus::new(100);
        assert_eq!(m.apply_signed(-1), 99);
        assert_eq!(m.apply_signed(-100), 0);
        assert_eq!(m.apply_signed(250), 50);
    }

    #[test]
    fn batch_forms_match_scalar() {
        let mut xs = vec![5u32, 10_001, 4_999];
        Modulus::new(5000).apply_slice(&mut xs);
        assert_eq!(xs, vec![5, 5001 % 5000, 4999]);

        let mut d = vec![-1, 0, 3];
        neg2zero_slice(&mut d);
        assert_eq!(d, vec![0, 0, 3]);

        let mut out = Vec::new();
        dense_finish_slice(&[-1, 0, 1], &mut out);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 0.0);
        assert!((out[2] - std::f32::consts::LN_2).abs() < 1e-6);
    }
}
