//! Typed per-column operator programs — the plan layer of the paper's
//! generalizability claim (§5: the modular PEs can be "dynamically
//! configured" per pipeline and per dataset).
//!
//! Different tabular workloads need *different transforms on different
//! columns* (per-feature vocabulary sizes, log-scaling only some dense
//! features, bucketizing one column). This module provides the typed
//! vocabulary for that:
//!
//! * [`ColumnOp`] — one per-value kernel, parsed from a spec token
//!   (`modulus:5000`, `clip:0:100`, `bucketize:1:10:100`, ...);
//! * [`ColumnProgram`] — a **validated** op chain for one column, typed
//!   by [`ColumnKind`] (sparse chains may hold Modulus/GenVocab/
//!   ApplyVocab, dense chains Neg2Zero/Logarithm/Clip/Bucketize;
//!   FillMissing/Hex2Int are legal in both and compile to nothing —
//!   they are implied by the decoded-row boundary);
//! * [`ColumnSelector`]/[`ColumnRange`] — which columns a program binds
//!   to in the spec grammar (`sparse[*]`, `dense[3]`, `sparse[0..4]`);
//! * the compiled physical side: [`SparseColPlan`] (fixed-function
//!   modulus + vocab slots), [`DenseKernel`]/[`DenseColPlan`] (an f32
//!   kernel chain), and [`ColumnPlans`] — one slot per column of a
//!   [`Schema`], the thing executor hot loops dispatch on.
//!
//! Validation happens at **construction** ([`ColumnProgram::new`]), so
//! everything downstream of a program is infallible on the validation
//! axis; resolution against a concrete schema (selector bounds) happens
//! once at planning time ([`crate::ops::PipelineSpec::compile`]).

use std::fmt;
use std::ops::Range;

use crate::data::row::ProcessedColumns;
use crate::data::{DecodedRow, Schema};
use crate::ops::{log1p, neg2zero, DirectVocab, HashVocab, Modulus, Vocab, VOCAB_MISS};
use crate::Result;

// ---------------------------------------------------------------------
// Ops
// ---------------------------------------------------------------------

/// One operator token (Table 1 names plus the per-column extensions).
///
/// `Decode` and `Concatenate` are pipeline *boundary markers*: they are
/// accepted by the flat spec grammar for compatibility (the classic
/// `decode | ... | concatenate` string) but are not column operators —
/// a [`ColumnProgram`] rejects them.
#[derive(Debug, Clone, PartialEq)]
pub enum ColumnOp {
    Decode,
    FillMissing,
    Hex2Int,
    Modulus(u32),
    GenVocab,
    ApplyVocab,
    Neg2Zero,
    Logarithm,
    /// Clamp a dense value into `[lo, hi]`.
    Clip { lo: f32, hi: f32 },
    /// Map a dense value to its bucket index: the number of (strictly
    /// increasing) boundaries ≤ the value.
    Bucketize { boundaries: Vec<f32> },
    Concatenate,
}

impl ColumnOp {
    /// Parse one spec token. Multi-argument ops separate arguments with
    /// `:` (commas stay free as a top-level op separator):
    /// `clip:0:100`, `bucketize:1:10:100`.
    pub fn parse(token: &str) -> Result<ColumnOp> {
        let t = token.trim().to_ascii_lowercase();
        let (name, arg) = match t.split_once(':') {
            Some((n, a)) => (n.trim().to_string(), Some(a.trim().to_string())),
            None => (t, None),
        };
        let no_arg = |op: ColumnOp| -> Result<ColumnOp> {
            anyhow::ensure!(arg.is_none(), "operator `{name}` takes no argument");
            Ok(op)
        };
        let f32_args = |what: &str| -> Result<Vec<f32>> {
            arg.as_deref()
                .ok_or_else(|| anyhow::anyhow!("{name} needs arguments, e.g. {what}"))?
                .split(':')
                .map(|s| {
                    let v: f32 = s
                        .trim()
                        .replace('_', "")
                        .parse()
                        .map_err(|e| anyhow::anyhow!("{name} argument `{s}`: {e}"))?;
                    anyhow::ensure!(v.is_finite(), "{name} argument `{s}` must be finite");
                    Ok(v)
                })
                .collect()
        };
        match name.as_str() {
            "decode" => no_arg(ColumnOp::Decode),
            "fillmissing" => no_arg(ColumnOp::FillMissing),
            "hex2int" => no_arg(ColumnOp::Hex2Int),
            "modulus" => {
                let r: u32 = arg
                    .as_deref()
                    .ok_or_else(|| anyhow::anyhow!("modulus needs a range, e.g. modulus:5000"))?
                    .replace('_', "")
                    .parse()
                    .map_err(|e| anyhow::anyhow!("modulus range: {e}"))?;
                ColumnOp::Modulus(r).validated()
            }
            "genvocab" => no_arg(ColumnOp::GenVocab),
            "applyvocab" => no_arg(ColumnOp::ApplyVocab),
            "neg2zero" => no_arg(ColumnOp::Neg2Zero),
            "logarithm" | "log" => no_arg(ColumnOp::Logarithm),
            "clip" => {
                let args = f32_args("clip:0:100")?;
                anyhow::ensure!(args.len() == 2, "clip takes exactly two arguments (lo:hi)");
                ColumnOp::Clip { lo: args[0], hi: args[1] }.validated()
            }
            "bucketize" => ColumnOp::Bucketize { boundaries: f32_args("bucketize:1:10:100")? }
                .validated(),
            "concatenate" | "concat" => no_arg(ColumnOp::Concatenate),
            other => anyhow::bail!("unknown operator `{other}`"),
        }
    }

    /// [`Self::validate_args`] in builder position.
    fn validated(self) -> Result<ColumnOp> {
        self.validate_args()?;
        Ok(self)
    }

    /// Argument well-formedness — the single source of truth shared by
    /// the token parser and [`ColumnProgram::new`], so programs built in
    /// code (the fields are public) uphold the same rules as parsed
    /// ones.
    pub fn validate_args(&self) -> Result<()> {
        match self {
            ColumnOp::Modulus(r) => {
                anyhow::ensure!(*r > 0, "modulus range must be positive");
            }
            ColumnOp::Clip { lo, hi } => {
                anyhow::ensure!(lo.is_finite() && hi.is_finite(), "clip bounds must be finite");
                anyhow::ensure!(lo <= hi, "clip lo ({lo}) must be <= hi ({hi})");
            }
            ColumnOp::Bucketize { boundaries } => {
                anyhow::ensure!(!boundaries.is_empty(), "bucketize needs >= 1 boundary");
                anyhow::ensure!(
                    boundaries.iter().all(|b| b.is_finite()),
                    "bucketize boundaries must be finite"
                );
                anyhow::ensure!(
                    boundaries.windows(2).all(|w| w[0] < w[1]),
                    "bucketize boundaries must be strictly increasing"
                );
            }
            _ => {}
        }
        Ok(())
    }

    /// Is this a real column operator (vs a flat-grammar boundary marker)?
    pub fn is_column_op(&self) -> bool {
        !matches!(self, ColumnOp::Decode | ColumnOp::Concatenate)
    }

    /// Which column kinds may run this op.
    pub fn applies_to(&self, kind: ColumnKind) -> bool {
        match self {
            ColumnOp::FillMissing => true,
            ColumnOp::Hex2Int
            | ColumnOp::Modulus(_)
            | ColumnOp::GenVocab
            | ColumnOp::ApplyVocab => kind == ColumnKind::Sparse,
            ColumnOp::Neg2Zero
            | ColumnOp::Logarithm
            | ColumnOp::Clip { .. }
            | ColumnOp::Bucketize { .. } => kind == ColumnKind::Dense,
            ColumnOp::Decode | ColumnOp::Concatenate => false,
        }
    }
}

impl fmt::Display for ColumnOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ColumnOp::Decode => write!(f, "decode"),
            ColumnOp::FillMissing => write!(f, "fillmissing"),
            ColumnOp::Hex2Int => write!(f, "hex2int"),
            ColumnOp::Modulus(r) => write!(f, "modulus:{r}"),
            ColumnOp::GenVocab => write!(f, "genvocab"),
            ColumnOp::ApplyVocab => write!(f, "applyvocab"),
            ColumnOp::Neg2Zero => write!(f, "neg2zero"),
            ColumnOp::Logarithm => write!(f, "logarithm"),
            ColumnOp::Clip { lo, hi } => write!(f, "clip:{lo}:{hi}"),
            ColumnOp::Bucketize { boundaries } => {
                write!(f, "bucketize")?;
                for b in boundaries {
                    write!(f, ":{b}")?;
                }
                Ok(())
            }
            ColumnOp::Concatenate => write!(f, "concatenate"),
        }
    }
}

// ---------------------------------------------------------------------
// Programs
// ---------------------------------------------------------------------

/// The two feature-column kinds of the tabular [`Schema`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnKind {
    Sparse,
    Dense,
}

impl ColumnKind {
    pub fn name(&self) -> &'static str {
        match self {
            ColumnKind::Sparse => "sparse",
            ColumnKind::Dense => "dense",
        }
    }
}

/// A validated op chain for one column. Construction is the validation
/// boundary: a `ColumnProgram` that exists is well-formed, so compiling
/// and executing it cannot fail on the validation axis.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnProgram {
    kind: ColumnKind,
    ops: Vec<ColumnOp>,
}

impl ColumnProgram {
    /// Validate an op chain for a column kind.
    ///
    /// Shared rules: only column ops (no Decode/Concatenate), each op
    /// applicable to `kind`. Sparse rules: Modulus/GenVocab/ApplyVocab
    /// at most once each; GenVocab requires an earlier Modulus (it
    /// bounds the vocabulary capacity); ApplyVocab requires an earlier
    /// GenVocab. Dense rule: Neg2Zero precedes Logarithm when both are
    /// present (Table 1's order; Logarithm alone still clamps).
    pub fn new(kind: ColumnKind, ops: Vec<ColumnOp>) -> Result<ColumnProgram> {
        anyhow::ensure!(!ops.is_empty(), "empty {} program", kind.name());
        for op in &ops {
            anyhow::ensure!(
                op.is_column_op(),
                "`{op}` is a pipeline boundary marker, not a column operator"
            );
            anyhow::ensure!(
                op.applies_to(kind),
                "`{op}` does not apply to {} columns",
                kind.name()
            );
            // Programs built in code (ColumnOp fields are public) must
            // uphold the same argument rules parsed tokens do.
            op.validate_args()?;
        }
        // Stateful/argumented ops may appear at most once per column.
        for (what, hit) in [
            ("modulus", ops.iter().filter(|o| matches!(o, ColumnOp::Modulus(_))).count()),
            ("genvocab", ops.iter().filter(|o| matches!(o, ColumnOp::GenVocab)).count()),
            ("applyvocab", ops.iter().filter(|o| matches!(o, ColumnOp::ApplyVocab)).count()),
        ] {
            anyhow::ensure!(hit <= 1, "{what} may appear at most once per column");
        }
        let pos = |f: fn(&ColumnOp) -> bool| ops.iter().position(f);
        if let Some(g) = pos(|o| matches!(o, ColumnOp::GenVocab)) {
            let m = pos(|o| matches!(o, ColumnOp::Modulus(_)))
                .ok_or_else(|| anyhow::anyhow!("GenVocab requires Modulus earlier in the program"))?;
            anyhow::ensure!(m < g, "Modulus must precede GenVocab");
        }
        if let Some(a) = pos(|o| matches!(o, ColumnOp::ApplyVocab)) {
            let g = pos(|o| matches!(o, ColumnOp::GenVocab)).ok_or_else(|| {
                anyhow::anyhow!("ApplyVocab requires GenVocab earlier in the program")
            })?;
            anyhow::ensure!(g < a, "GenVocab must precede ApplyVocab");
        }
        if let (Some(l), Some(n)) = (
            pos(|o| matches!(o, ColumnOp::Logarithm)),
            pos(|o| matches!(o, ColumnOp::Neg2Zero)),
        ) {
            anyhow::ensure!(n < l, "Neg2Zero must precede Logarithm");
        }
        Ok(ColumnProgram { kind, ops })
    }

    pub fn kind(&self) -> ColumnKind {
        self.kind
    }

    pub fn ops(&self) -> &[ColumnOp] {
        &self.ops
    }

    /// Compile to the fixed-function sparse slot. Panics in debug if the
    /// program is dense-kinded (construction prevents it).
    pub(crate) fn compile_sparse(&self) -> SparseColPlan {
        debug_assert_eq!(self.kind, ColumnKind::Sparse);
        let mut slot = SparseColPlan::default();
        for op in &self.ops {
            match op {
                ColumnOp::Modulus(r) => slot.modulus = Some(Modulus::new(*r)),
                ColumnOp::GenVocab => slot.gen_vocab = true,
                ColumnOp::ApplyVocab => slot.apply_vocab = true,
                // implied by the decoded-row boundary
                ColumnOp::FillMissing | ColumnOp::Hex2Int => {}
                _ => unreachable!("validated sparse program"),
            }
        }
        slot
    }

    /// Compile to the dense kernel chain.
    pub(crate) fn compile_dense(&self) -> DenseColPlan {
        debug_assert_eq!(self.kind, ColumnKind::Dense);
        let kernels = self
            .ops
            .iter()
            .filter_map(|op| match op {
                ColumnOp::Neg2Zero => Some(DenseKernel::Neg2Zero),
                ColumnOp::Logarithm => Some(DenseKernel::Log1p),
                ColumnOp::Clip { lo, hi } => Some(DenseKernel::Clip { lo: *lo, hi: *hi }),
                ColumnOp::Bucketize { boundaries } => {
                    Some(DenseKernel::Bucketize { boundaries: boundaries.clone() })
                }
                ColumnOp::FillMissing => None, // implied by decode
                _ => unreachable!("validated dense program"),
            })
            .collect();
        DenseColPlan { kernels }
    }
}

impl fmt::Display for ColumnProgram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, op) in self.ops.iter().enumerate() {
            if i > 0 {
                write!(f, "|")?;
            }
            write!(f, "{op}")?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Selectors
// ---------------------------------------------------------------------

/// Column indices a spec rule binds to, within one column kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnRange {
    /// Every column of the kind: `[*]`.
    All,
    /// A single column: `[3]`.
    One(usize),
    /// A half-open span: `[0..4]` = columns 0,1,2,3.
    Span(usize, usize),
}

impl ColumnRange {
    /// Concrete indices against a kind with `n` columns — bounds are a
    /// *resolution* error (schema mismatch), not a validation error.
    pub fn resolve(&self, n: usize) -> Result<Range<usize>> {
        match *self {
            ColumnRange::All => Ok(0..n),
            ColumnRange::One(i) => {
                anyhow::ensure!(i < n, "column index {i} out of range (have {n})");
                Ok(i..i + 1)
            }
            ColumnRange::Span(a, b) => {
                anyhow::ensure!(a < b, "empty column range {a}..{b}");
                anyhow::ensure!(b <= n, "column range {a}..{b} out of range (have {n})");
                Ok(a..b)
            }
        }
    }

    fn parse(body: &str) -> Result<ColumnRange> {
        let body = body.trim();
        if body == "*" {
            return Ok(ColumnRange::All);
        }
        if let Some((a, b)) = body.split_once("..") {
            let a: usize = a.trim().parse().map_err(|e| anyhow::anyhow!("range start: {e}"))?;
            let b: usize = b.trim().parse().map_err(|e| anyhow::anyhow!("range end: {e}"))?;
            anyhow::ensure!(a < b, "empty column range {a}..{b}");
            return Ok(ColumnRange::Span(a, b));
        }
        let i: usize = body.parse().map_err(|e| anyhow::anyhow!("column index: {e}"))?;
        Ok(ColumnRange::One(i))
    }
}

impl fmt::Display for ColumnRange {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ColumnRange::All => write!(f, "*"),
            ColumnRange::One(i) => write!(f, "{i}"),
            ColumnRange::Span(a, b) => write!(f, "{a}..{b}"),
        }
    }
}

/// A column selector of the spec grammar: kind + range.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColumnSelector {
    pub kind: ColumnKind,
    pub range: ColumnRange,
}

impl ColumnSelector {
    pub fn sparse(range: ColumnRange) -> Self {
        ColumnSelector { kind: ColumnKind::Sparse, range }
    }

    pub fn dense(range: ColumnRange) -> Self {
        ColumnSelector { kind: ColumnKind::Dense, range }
    }

    /// Parse `sparse[*]` / `dense[0..4]` / `sparse[3]`.
    pub fn parse(s: &str) -> Result<ColumnSelector> {
        let s = s.trim().to_ascii_lowercase();
        let (kind, rest) = if let Some(r) = s.strip_prefix("sparse") {
            (ColumnKind::Sparse, r)
        } else if let Some(r) = s.strip_prefix("dense") {
            (ColumnKind::Dense, r)
        } else {
            anyhow::bail!("selector `{s}` must start with sparse[...] or dense[...]");
        };
        let body = rest
            .trim()
            .strip_prefix('[')
            .and_then(|r| r.strip_suffix(']'))
            .ok_or_else(|| anyhow::anyhow!("selector `{s}` needs [*], [i] or [a..b]"))?;
        Ok(ColumnSelector { kind, range: ColumnRange::parse(body)? })
    }
}

impl fmt::Display for ColumnSelector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.kind.name(), self.range)
    }
}

// ---------------------------------------------------------------------
// Compiled physical plans
// ---------------------------------------------------------------------

/// The compiled fixed-function slot of one sparse column: optional
/// modulus plus the vocabulary stages — exactly the modular-PE chain
/// (Modulus → GenVocab → ApplyVocab) the accelerator instantiates per
/// sparse dataflow.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SparseColPlan {
    pub modulus: Option<Modulus>,
    pub gen_vocab: bool,
    pub apply_vocab: bool,
}

impl SparseColPlan {
    /// The stateless prefix of the chain (modulus limiting).
    #[inline]
    pub fn map(&self, v: u32) -> u32 {
        self.modulus.map_or(v, |m| m.apply(v))
    }

    /// Does this column touch no vocabulary state at all
    /// (modulus-only / passthrough)? Stateless columns are shardable
    /// across threads even under the fused strategy — the engine's
    /// stateless stage fills them, the sequential fused stage skips
    /// them.
    #[inline]
    pub fn is_stateless(&self) -> bool {
        !self.gen_vocab && !self.apply_vocab
    }

    /// Vocabulary capacity this column needs (the modulus range bounds
    /// the key universe). `None` when the column builds no vocabulary.
    pub fn vocab_capacity(&self) -> Option<u32> {
        if self.gen_vocab {
            self.modulus.map(|m| m.range)
        } else {
            None
        }
    }

    /// Ops in the physical chain (the GPU model's dispatch unit): one
    /// per fixed-function stage plus the final store.
    pub fn num_ops(&self) -> usize {
        1 + usize::from(self.modulus.is_some())
            + usize::from(self.gen_vocab)
            + usize::from(self.apply_vocab)
    }
}

/// One compiled dense kernel: f32 → f32, applied after the decoded i32
/// is widened once (`x as f32`). The f32 chain is bit-identical to the
/// historical integer forms: `max(x as f32, 0) == neg2zero(x) as f32`
/// for every i32, and `ln_1p` of that equals [`crate::ops::log1p`].
#[derive(Debug, Clone, PartialEq)]
pub enum DenseKernel {
    Neg2Zero,
    Log1p,
    Clip { lo: f32, hi: f32 },
    Bucketize { boundaries: Vec<f32> },
}

impl DenseKernel {
    #[inline]
    pub fn apply(&self, v: f32) -> f32 {
        match self {
            DenseKernel::Neg2Zero => {
                if v < 0.0 {
                    0.0
                } else {
                    v
                }
            }
            DenseKernel::Log1p => {
                let v = if v < 0.0 { 0.0 } else { v };
                v.ln_1p()
            }
            DenseKernel::Clip { lo, hi } => v.clamp(*lo, *hi),
            DenseKernel::Bucketize { boundaries } => {
                boundaries.partition_point(|b| *b <= v) as f32
            }
        }
    }
}

/// The compiled kernel chain of one dense column.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DenseColPlan {
    pub kernels: Vec<DenseKernel>,
}

impl DenseColPlan {
    /// One dense value through the chain.
    #[inline]
    pub fn apply_value(&self, d: i32) -> f32 {
        let mut v = d as f32;
        for k in &self.kernels {
            v = k.apply(v);
        }
        v
    }

    /// A column slice through the chain, appended to `dst`. The common
    /// chains are specialized so the uniform DLRM plan keeps its exact
    /// pre-redesign hot loop (and its bit patterns).
    pub fn run(&self, col: &[i32], dst: &mut Vec<f32>) {
        dst.reserve(col.len());
        match self.kernels.as_slice() {
            [] => {
                for &d in col {
                    dst.push(d as f32);
                }
            }
            [DenseKernel::Neg2Zero] => {
                for &d in col {
                    dst.push(neg2zero(d) as f32);
                }
            }
            [DenseKernel::Neg2Zero, DenseKernel::Log1p] => {
                for &d in col {
                    dst.push(log1p(d));
                }
            }
            kernels => {
                for &d in col {
                    let mut v = d as f32;
                    for k in kernels {
                        v = k.apply(v);
                    }
                    dst.push(v);
                }
            }
        }
    }

    /// Physical ops incl. the final store (GPU dispatch model unit).
    pub fn num_ops(&self) -> usize {
        1 + self.kernels.len()
    }
}

/// The fully compiled physical plan: one slot per column of the schema.
/// This is what [`crate::pipeline::ChunkState`] dispatches on — built
/// once at planning time, immutable afterwards.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnPlans {
    pub schema: Schema,
    /// One slot per sparse column.
    pub sparse: Vec<SparseColPlan>,
    /// One kernel chain per dense column.
    pub dense: Vec<DenseColPlan>,
}

impl ColumnPlans {
    /// A passthrough plan (no ops on any column) for a schema.
    pub fn passthrough(schema: Schema) -> Self {
        ColumnPlans {
            schema,
            sparse: vec![SparseColPlan::default(); schema.num_sparse],
            dense: vec![DenseColPlan::default(); schema.num_dense],
        }
    }

    /// Does any column build a vocabulary? (Decides the two-pass rewind
    /// and the fused-vs-sharded CPU decomposition.)
    pub fn any_gen_vocab(&self) -> bool {
        self.sparse.iter().any(|c| c.gen_vocab)
    }

    /// Number of sparse columns that build a vocabulary.
    pub fn vocab_columns(&self) -> usize {
        self.sparse.iter().filter(|c| c.gen_vocab).count()
    }

    /// The largest modulus range across all columns.
    pub fn max_modulus(&self) -> Option<Modulus> {
        self.sparse
            .iter()
            .filter_map(|c| c.modulus)
            .max_by_key(|m| m.range)
    }

    /// The largest modulus range among **vocabulary-building** columns —
    /// what the accelerator's clock/placement heuristic keys on (a
    /// modulus-only passthrough column occupies no vocabulary storage,
    /// however large its range). Falls back to [`Self::max_modulus`]
    /// when no column builds a vocabulary.
    pub fn max_vocab_modulus(&self) -> Option<Modulus> {
        self.sparse
            .iter()
            .filter(|c| c.gen_vocab)
            .filter_map(|c| c.modulus)
            .max_by_key(|m| m.range)
            .or_else(|| self.max_modulus())
    }

    /// SRAM bits the vocabulary structures need, summed **per column**
    /// over each column's own capacity (a heterogeneous plan with four
    /// 100K columns and twenty-two 5K columns needs far less than a
    /// uniform 100K plan — the check prices exactly what the programs
    /// ask for).
    pub fn vocab_storage_bits(&self) -> u64 {
        self.sparse
            .iter()
            .filter_map(|c| c.vocab_capacity())
            .map(DirectVocab::storage_bits_for)
            .sum()
    }

    /// Physical op counts `(sparse_ops, dense_ops)` across all columns,
    /// incl. one store per column — the GPU model's dispatch units.
    pub fn dispatch_ops(&self) -> (usize, usize) {
        (
            self.sparse.iter().map(|c| c.num_ops()).sum(),
            self.dense.iter().map(|c| c.num_ops()).sum(),
        )
    }

    /// Reference (two-pass, row-wise) execution over decoded rows — the
    /// semantics oracle the streaming executors are pinned against.
    pub fn execute_rows(&self, rows: &[DecodedRow]) -> ProcessedColumns {
        // pass 1: vocabularies (insertion-ordered, per column)
        let mut vocabs: Vec<HashVocab> =
            (0..self.schema.num_sparse).map(|_| HashVocab::new()).collect();
        if self.any_gen_vocab() {
            for row in rows {
                for ((slot, vocab), &s) in
                    self.sparse.iter().zip(vocabs.iter_mut()).zip(&row.sparse)
                {
                    if slot.gen_vocab {
                        vocab.observe(slot.map(s));
                    }
                }
            }
        }
        // pass 2: emit
        let mut out = ProcessedColumns::with_schema(self.schema);
        for row in rows {
            out.labels.push(row.label);
            for ((plan, col), &d) in self.dense.iter().zip(out.dense.iter_mut()).zip(&row.dense)
            {
                col.push(plan.apply_value(d));
            }
            for (((slot, vocab), col), &s) in self
                .sparse
                .iter()
                .zip(&vocabs)
                .zip(out.sparse.iter_mut())
                .zip(&row.sparse)
            {
                let v = slot.map(s);
                col.push(if slot.apply_vocab {
                    // validated: ApplyVocab implies GenVocab observed v
                    vocab.apply(v).unwrap_or(VOCAB_MISS)
                } else {
                    v
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse_prog(ops: Vec<ColumnOp>) -> Result<ColumnProgram> {
        ColumnProgram::new(ColumnKind::Sparse, ops)
    }

    fn dense_prog(ops: Vec<ColumnOp>) -> Result<ColumnProgram> {
        ColumnProgram::new(ColumnKind::Dense, ops)
    }

    #[test]
    fn op_tokens_round_trip_display() {
        for token in [
            "fillmissing",
            "hex2int",
            "modulus:5000",
            "genvocab",
            "applyvocab",
            "neg2zero",
            "logarithm",
            "clip:0:100",
            "clip:-3.5:2.25",
            "bucketize:1:10:100",
            "decode",
            "concatenate",
        ] {
            let op = ColumnOp::parse(token).unwrap();
            assert_eq!(ColumnOp::parse(&op.to_string()).unwrap(), op, "{token}");
        }
    }

    #[test]
    fn clip_and_bucketize_args_validated() {
        assert!(ColumnOp::parse("clip").is_err(), "clip needs args");
        assert!(ColumnOp::parse("clip:1").is_err(), "clip needs two args");
        assert!(ColumnOp::parse("clip:5:1").is_err(), "lo > hi");
        assert!(ColumnOp::parse("clip:a:b").is_err());
        assert!(ColumnOp::parse("clip:nan:1").is_err(), "finite only");
        assert!(ColumnOp::parse("bucketize").is_err());
        assert!(ColumnOp::parse("bucketize:3:1").is_err(), "must increase");
        assert!(ColumnOp::parse("bucketize:1:1").is_err(), "strictly");
        assert_eq!(
            ColumnOp::parse("bucketize:1").unwrap(),
            ColumnOp::Bucketize { boundaries: vec![1.0] }
        );
    }

    #[test]
    fn program_kind_rules() {
        // dense ops on sparse columns and vice versa are rejected
        assert!(sparse_prog(vec![ColumnOp::Neg2Zero]).is_err());
        assert!(sparse_prog(vec![ColumnOp::Clip { lo: 0.0, hi: 1.0 }]).is_err());
        assert!(dense_prog(vec![ColumnOp::Modulus(5)]).is_err());
        assert!(dense_prog(vec![ColumnOp::GenVocab]).is_err());
        // boundary markers are not column ops
        assert!(sparse_prog(vec![ColumnOp::Decode]).is_err());
        assert!(dense_prog(vec![ColumnOp::Concatenate]).is_err());
        // fillmissing is legal on both
        assert!(sparse_prog(vec![ColumnOp::FillMissing, ColumnOp::Modulus(5)]).is_ok());
        assert!(dense_prog(vec![ColumnOp::FillMissing, ColumnOp::Neg2Zero]).is_ok());
    }

    #[test]
    fn program_dependency_rules() {
        assert!(sparse_prog(vec![ColumnOp::GenVocab]).is_err(), "needs modulus");
        assert!(
            sparse_prog(vec![ColumnOp::GenVocab, ColumnOp::Modulus(5)]).is_err(),
            "order"
        );
        assert!(
            sparse_prog(vec![ColumnOp::Modulus(5), ColumnOp::ApplyVocab]).is_err(),
            "apply needs gen"
        );
        assert!(
            sparse_prog(vec![
                ColumnOp::Modulus(5),
                ColumnOp::GenVocab,
                ColumnOp::GenVocab
            ])
            .is_err(),
            "duplicate gen"
        );
        assert!(
            sparse_prog(vec![
                ColumnOp::Modulus(5),
                ColumnOp::Modulus(7),
                ColumnOp::GenVocab
            ])
            .is_err(),
            "duplicate modulus"
        );
        assert!(
            dense_prog(vec![ColumnOp::Logarithm, ColumnOp::Neg2Zero]).is_err(),
            "n2z must precede log"
        );
        assert!(dense_prog(vec![ColumnOp::Logarithm]).is_ok(), "log alone clamps");
    }

    /// Programmatic construction must uphold the same argument
    /// well-formedness the token parser enforces — a `ColumnProgram`
    /// that exists never panics downstream.
    #[test]
    fn program_argument_rules() {
        assert!(sparse_prog(vec![ColumnOp::Modulus(0)]).is_err(), "zero modulus");
        assert!(
            dense_prog(vec![ColumnOp::Clip { lo: 5.0, hi: 1.0 }]).is_err(),
            "clip lo > hi"
        );
        assert!(
            dense_prog(vec![ColumnOp::Clip { lo: f32::NAN, hi: 1.0 }]).is_err(),
            "NaN clip bound"
        );
        assert!(
            dense_prog(vec![ColumnOp::Bucketize { boundaries: vec![] }]).is_err(),
            "empty boundaries"
        );
        assert!(
            dense_prog(vec![ColumnOp::Bucketize { boundaries: vec![3.0, 1.0] }]).is_err(),
            "unsorted boundaries"
        );
        assert!(
            dense_prog(vec![ColumnOp::Bucketize { boundaries: vec![1.0, f32::INFINITY] }])
                .is_err(),
            "non-finite boundary"
        );
    }

    #[test]
    fn selectors_parse_and_round_trip() {
        for (s, want) in [
            ("sparse[*]", ColumnSelector::sparse(ColumnRange::All)),
            ("dense[*]", ColumnSelector::dense(ColumnRange::All)),
            ("sparse[3]", ColumnSelector::sparse(ColumnRange::One(3))),
            ("dense[0..4]", ColumnSelector::dense(ColumnRange::Span(0, 4))),
            (" SPARSE[ 0..26 ] ", ColumnSelector::sparse(ColumnRange::Span(0, 26))),
        ] {
            let sel = ColumnSelector::parse(s).unwrap();
            assert_eq!(sel, want, "{s}");
            assert_eq!(ColumnSelector::parse(&sel.to_string()).unwrap(), sel);
        }
        assert!(ColumnSelector::parse("label[*]").is_err());
        assert!(ColumnSelector::parse("sparse").is_err());
        assert!(ColumnSelector::parse("sparse[4..2]").is_err());
        assert!(ColumnSelector::parse("sparse[x]").is_err());
    }

    #[test]
    fn range_resolution_bounds() {
        assert_eq!(ColumnRange::All.resolve(4).unwrap(), 0..4);
        assert_eq!(ColumnRange::One(3).resolve(4).unwrap(), 3..4);
        assert!(ColumnRange::One(4).resolve(4).is_err());
        assert_eq!(ColumnRange::Span(1, 3).resolve(4).unwrap(), 1..3);
        assert!(ColumnRange::Span(1, 5).resolve(4).is_err());
    }

    #[test]
    fn dense_kernels_semantics() {
        let clip = DenseKernel::Clip { lo: 0.0, hi: 10.0 };
        assert_eq!(clip.apply(-5.0), 0.0);
        assert_eq!(clip.apply(5.0), 5.0);
        assert_eq!(clip.apply(50.0), 10.0);
        let b = DenseKernel::Bucketize { boundaries: vec![1.0, 10.0, 100.0] };
        assert_eq!(b.apply(0.5), 0.0);
        assert_eq!(b.apply(1.0), 1.0, "boundary is inclusive below");
        assert_eq!(b.apply(9.9), 1.0);
        assert_eq!(b.apply(10.0), 2.0);
        assert_eq!(b.apply(1e9), 3.0);
    }

    /// The f32 kernel chain must reproduce the historical integer dense
    /// path bit for bit — the uniform-spec compatibility guarantee.
    #[test]
    fn dense_chain_matches_integer_forms() {
        let values: Vec<i32> =
            vec![i32::MIN, -100, -1, 0, 1, 7, 4095, 4096, 1 << 24, i32::MAX];
        let n2z = dense_prog(vec![ColumnOp::Neg2Zero]).unwrap().compile_dense();
        let n2z_log = dense_prog(vec![ColumnOp::Neg2Zero, ColumnOp::Logarithm])
            .unwrap()
            .compile_dense();
        let log_only = dense_prog(vec![ColumnOp::Logarithm]).unwrap().compile_dense();
        for &d in &values {
            assert_eq!(n2z.apply_value(d).to_bits(), (neg2zero(d) as f32).to_bits());
            assert_eq!(n2z_log.apply_value(d).to_bits(), log1p(d).to_bits());
            assert_eq!(log_only.apply_value(d).to_bits(), log1p(d).to_bits());
        }
        // the specialized slice paths equal the general per-value path
        for plan in [&n2z, &n2z_log, &log_only] {
            let mut fast = Vec::new();
            plan.run(&values, &mut fast);
            let slow: Vec<f32> = values.iter().map(|&d| plan.apply_value(d)).collect();
            assert_eq!(
                fast.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                slow.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn plans_capacity_and_dispatch_accounting() {
        let mut plans = ColumnPlans::passthrough(Schema::new(2, 3));
        assert!(!plans.any_gen_vocab());
        assert_eq!(plans.vocab_storage_bits(), 0);
        plans.sparse[0] =
            SparseColPlan { modulus: Some(Modulus::new(64)), gen_vocab: true, apply_vocab: true };
        plans.sparse[2] =
            SparseColPlan { modulus: Some(Modulus::new(128)), gen_vocab: true, apply_vocab: false };
        assert!(plans.any_gen_vocab());
        assert_eq!(plans.vocab_columns(), 2);
        assert_eq!(plans.max_modulus().unwrap().range, 128);
        assert_eq!(
            plans.vocab_storage_bits(),
            DirectVocab::storage_bits_for(64) + DirectVocab::storage_bits_for(128)
        );
        // dispatch: col0 = mod+gen+apply+store, col1 = store, col2 = mod+gen+store
        let (s, d) = plans.dispatch_ops();
        assert_eq!(s, 4 + 1 + 3);
        assert_eq!(d, 2); // two dense passthrough stores

        // a huge modulus on a vocab-FREE column must not drive the
        // vocabulary heuristic (it stores nothing) — only the storage
        // sum and placement of actual vocabularies matter
        plans.sparse[1] = SparseColPlan {
            modulus: Some(Modulus::new(1 << 20)),
            gen_vocab: false,
            apply_vocab: false,
        };
        assert_eq!(plans.max_modulus().unwrap().range, 1 << 20);
        assert_eq!(plans.max_vocab_modulus().unwrap().range, 128);
        assert_eq!(
            plans.vocab_storage_bits(),
            DirectVocab::storage_bits_for(64) + DirectVocab::storage_bits_for(128),
            "vocab-free columns occupy no vocabulary storage"
        );
    }
}
