//! `GenVocab` / `ApplyVocab` — the stateful heart of the pipeline.
//!
//! A vocabulary maps each distinct (modulus-limited) sparse value to its
//! **appearance index**: the order in which unique values were first seen
//! while scanning the dataset (paper §2.3 step 7 — "collect the appearing
//! sequence for each unique sparse feature"). This makes the pipeline
//! stateful and forces the CPU's row-partitioned threads to merge their
//! per-thread sub-dictionaries at a synchronization barrier — the exact
//! overhead PIPER eliminates.
//!
//! Two interchangeable backends:
//!
//! * [`HashVocab`] — software-style insertion-ordered hash map (what
//!   Meta's Python dict does). Open addressing, u32 keys, no deps; the
//!   CPU baseline's hot structure.
//! * [`DirectVocab`] — hardware-style direct-mapped table of size
//!   `modulus.range` with a seen-bitmap and a counter (what PIPER's
//!   GenVocab-1 bitmap in BRAM/URAM + ApplyVocab-1 counter implement).
//!
//! Both produce identical assignments for the same observation order —
//! asserted by tests and relied on by the CPU↔FPGA equivalence suite.

/// Sentinel written for a value that was never observed. `0` is a
/// legitimate appearance index (the first unique value gets it), so it
/// must not double as "unknown"; `u32::MAX` is free because keys are
/// modulus-limited (and [`HashVocab`] already reserves it as its empty
/// slot marker). In the two-loop design every applied value has been
/// observed, so seeing `VOCAB_MISS` in output means the caller skipped
/// GenVocab — an explicit, greppable signal instead of a silent `0`.
pub const VOCAB_MISS: u32 = u32::MAX;

/// Common vocabulary behaviour.
pub trait Vocab {
    /// Observe a value during the GenVocab pass. Returns `true` when the
    /// value was new (GenVocab-1 "filters some unique inputs").
    fn observe(&mut self, v: u32) -> bool;

    /// Look up a value during the ApplyVocab pass.
    fn apply(&self, v: u32) -> Option<u32>;

    /// Number of distinct values observed.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fused GenVocab+ApplyVocab: observe `v` and return its appearance
    /// index in one step — the hardware single-pass semantics (PIPER's
    /// GenVocab-1 bitmap test-and-set feeding ApplyVocab-1's counter in
    /// the same cycle). Because an appearance index is fixed at first
    /// appearance, a fused scan assigns exactly the indices the two-loop
    /// scan does. Backends override this to avoid the double lookup.
    fn observe_apply(&mut self, v: u32) -> u32 {
        self.observe(v);
        self.apply(v).unwrap_or(VOCAB_MISS) // unreachable: just observed
    }

    /// Observe every value in a column slice (GenVocab batch form).
    fn observe_slice(&mut self, xs: &[u32]) {
        for &x in xs {
            self.observe(x);
        }
    }

    /// Apply over a column slice, writing appearance indices into `out`
    /// (same length as `xs` — allocation-free, the caller provides the
    /// storage). Values never observed write the explicit [`VOCAB_MISS`]
    /// sentinel rather than a fake index.
    fn apply_slice(&self, xs: &[u32], out: &mut [u32]) {
        // Hard assert: a zip over mismatched lengths would silently leave
        // trailing rows stale — the aliasing failure VOCAB_MISS exists to
        // prevent. One comparison against a per-element loop is free.
        assert_eq!(xs.len(), out.len(), "apply_slice output length mismatch");
        for (&x, o) in xs.iter().zip(out.iter_mut()) {
            *o = self.apply(x).unwrap_or(VOCAB_MISS);
        }
    }
}

// ---------------------------------------------------------------------
// Hardware-style direct-mapped vocabulary.
// ---------------------------------------------------------------------

/// Direct-mapped table: the value (already `< range` after Modulus) is the
/// address. `seen` is GenVocab-1's bitmap; `table[v]` holds the appearance
/// index written by ApplyVocab-1's counter.
#[derive(Debug, Clone)]
pub struct DirectVocab {
    seen: Vec<u64>,
    table: Vec<u32>,
    counter: u32,
}

impl DirectVocab {
    pub fn new(range: u32) -> Self {
        let words = (range as usize).div_ceil(64);
        DirectVocab { seen: vec![0; words], table: vec![0; range as usize], counter: 0 }
    }

    #[inline]
    fn test_and_set(&mut self, v: u32) -> bool {
        let (w, b) = ((v / 64) as usize, v % 64);
        let was = self.seen[w] & (1 << b) != 0;
        self.seen[w] |= 1 << b;
        !was
    }

    /// The one hardware step both `observe` and `observe_apply` share:
    /// bitmap test-and-set, latching the counter into the table for a
    /// fresh value. Returns whether the value was new; either way
    /// `table[v]` holds the appearance index afterwards.
    #[inline]
    fn latch(&mut self, v: u32) -> bool {
        debug_assert!((v as usize) < self.table.len(), "value escaped Modulus range");
        if self.test_and_set(v) {
            self.table[v as usize] = self.counter;
            self.counter += 1;
            true
        } else {
            false
        }
    }

    /// Export the observed keys **in appearance order** — the payload of
    /// a frozen vocabulary artifact ([`crate::ops::artifact`]). The
    /// direct-mapped table stores `value → appearance index`, never the
    /// appearance sequence itself, so the export inverts it: for every
    /// set bit `v` of the seen bitmap, `keys[table[v]] = v`. One pass
    /// over the bitmap words, no sort — and byte-for-byte the same list
    /// [`HashVocab::export_keys`] yields for the same observation
    /// stream (pinned by tests; the artifact format relies on it).
    pub fn export_keys(&self) -> Vec<u32> {
        let mut keys = vec![0u32; self.counter as usize];
        for (w, &word) in self.seen.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let v = (w * 64) as u32 + bits.trailing_zeros();
                bits &= bits - 1;
                keys[self.table[v as usize] as usize] = v;
            }
        }
        keys
    }

    /// Memory footprint in bits of the bitmap + table — what decides
    /// SRAM vs HBM placement on the accelerator.
    pub fn storage_bits(&self) -> u64 {
        (self.seen.len() as u64) * 64 + (self.table.len() as u64) * 32
    }

    /// [`Self::storage_bits`] for a capacity without allocating the
    /// table — the planning-time form (the SRAM check sums this per
    /// column over each column's own vocabulary capacity).
    pub fn storage_bits_for(range: u32) -> u64 {
        let words = (range as usize).div_ceil(64) as u64;
        words * 64 + range as u64 * 32
    }
}

impl Vocab for DirectVocab {
    #[inline]
    fn observe(&mut self, v: u32) -> bool {
        self.latch(v)
    }

    #[inline]
    fn apply(&self, v: u32) -> Option<u32> {
        let (w, b) = ((v / 64) as usize, v % 64);
        if self.seen.get(w).is_some_and(|word| word & (1 << b) != 0) {
            Some(self.table[v as usize])
        } else {
            None
        }
    }

    /// The literal hardware dataflow: one bitmap test-and-set, one table
    /// access — the same [`Self::latch`] `observe` uses, plus the read.
    #[inline]
    fn observe_apply(&mut self, v: u32) -> u32 {
        self.latch(v);
        self.table[v as usize]
    }

    fn len(&self) -> usize {
        self.counter as usize
    }
}

// ---------------------------------------------------------------------
// Software-style insertion-ordered hash map.
// ---------------------------------------------------------------------

const EMPTY: u32 = u32::MAX;

/// Open-addressing insertion-ordered map `u32 → appearance index`.
///
/// Linear probing, power-of-two capacity, 0.75 max load. Keys are
/// modulus-limited sparse values, so `u32::MAX` is free as the empty
/// sentinel. Insertion order is kept in `order` so per-thread
/// sub-dictionaries merge deterministically (thread 0's uniques first,
/// then thread 1's new ones, ... — exactly what Meta's merge produces).
#[derive(Debug, Clone)]
pub struct HashVocab {
    keys: Vec<u32>,
    vals: Vec<u32>,
    order: Vec<u32>,
    mask: usize,
    len: usize,
}

impl HashVocab {
    pub fn new() -> Self {
        Self::with_capacity(16)
    }

    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.next_power_of_two().max(16);
        HashVocab {
            keys: vec![EMPTY; cap],
            vals: vec![0; cap],
            order: Vec::new(),
            mask: cap - 1,
            len: 0,
        }
    }

    #[inline]
    fn hash(v: u32) -> usize {
        // Fibonacci hashing on the 32-bit key.
        (v.wrapping_mul(0x9E37_79B9) as usize) ^ ((v >> 16) as usize)
    }

    #[inline]
    fn slot_of(&self, v: u32) -> usize {
        let mut i = Self::hash(v) & self.mask;
        loop {
            let k = self.keys[i];
            if k == v || k == EMPTY {
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// The one probe-and-insert both `observe` and `observe_apply`
    /// share: grow at 0.75 load, find `v`'s slot, insert it with the
    /// next appearance index if absent. Returns `(slot, was_new)` — the
    /// slot's `vals` entry is the appearance index either way.
    #[inline]
    fn upsert_slot(&mut self, v: u32) -> (usize, bool) {
        debug_assert_ne!(v, EMPTY, "u32::MAX is reserved");
        if (self.len + 1) * 4 > (self.mask + 1) * 3 {
            self.grow();
        }
        let s = self.slot_of(v);
        if self.keys[s] == EMPTY {
            self.keys[s] = v;
            self.vals[s] = self.len as u32;
            self.order.push(v);
            self.len += 1;
            (s, true)
        } else {
            (s, false)
        }
    }

    fn grow(&mut self) {
        let new_cap = (self.mask + 1) * 2;
        let mut bigger = HashVocab {
            keys: vec![EMPTY; new_cap],
            vals: vec![0; new_cap],
            order: std::mem::take(&mut self.order),
            mask: new_cap - 1,
            len: self.len,
        };
        for (i, &k) in self.keys.iter().enumerate() {
            if k != EMPTY {
                let s = bigger.slot_of(k);
                bigger.keys[s] = k;
                bigger.vals[s] = self.vals[i];
            }
        }
        *self = bigger;
    }

    /// Iterate keys in insertion (appearance) order.
    pub fn iter_ordered(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.order.iter().map(move |&k| {
            let s = self.slot_of(k);
            (k, self.vals[s])
        })
    }

    /// Merge another sub-dictionary into this one **in its appearance
    /// order** — the synchronization step of the CPU pipeline ("the
    /// program then synchronizes the threads and combines these
    /// sub-dictionaries", paper §2.3).
    pub fn merge_from(&mut self, sub: &HashVocab) {
        for &k in &sub.order {
            self.observe(k);
        }
    }

    /// Export the observed keys **in appearance order** — the payload of
    /// a frozen vocabulary artifact ([`crate::ops::artifact`]). The
    /// insertion-order list is kept explicitly, so this is a copy of it;
    /// identical to [`DirectVocab::export_keys`] for the same stream.
    pub fn export_keys(&self) -> Vec<u32> {
        self.order.clone()
    }

    /// Rough heap bytes — used by the baseline's memory accounting.
    pub fn heap_bytes(&self) -> usize {
        self.keys.len() * 8 + self.order.len() * 4
    }
}

impl Default for HashVocab {
    fn default() -> Self {
        Self::new()
    }
}

impl Vocab for HashVocab {
    #[inline]
    fn observe(&mut self, v: u32) -> bool {
        self.upsert_slot(v).1
    }

    #[inline]
    fn apply(&self, v: u32) -> Option<u32> {
        let s = self.slot_of(v);
        if self.keys[s] == v {
            Some(self.vals[s])
        } else {
            None
        }
    }

    /// Single probe for the fused pass: the same [`Self::upsert_slot`]
    /// `observe` uses, returning the slot's appearance index.
    #[inline]
    fn observe_apply(&mut self, v: u32) -> u32 {
        let (s, _) = self.upsert_slot(v);
        self.vals[s]
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ---------------------------------------------------------------------
// Per-column vocabulary set.
// ---------------------------------------------------------------------

/// One vocabulary per sparse column — the unit the two-loop dataflow and
/// the CPU pipeline both operate on.
#[derive(Debug, Clone)]
pub struct VocabSet {
    pub vocabs: Vec<HashVocab>,
}

impl VocabSet {
    pub fn new(num_sparse: usize) -> Self {
        VocabSet { vocabs: (0..num_sparse).map(|_| HashVocab::new()).collect() }
    }

    /// GenVocab over column-major sparse data.
    pub fn observe_columns(&mut self, cols: &[Vec<u32>]) {
        assert_eq!(cols.len(), self.vocabs.len());
        for (v, col) in self.vocabs.iter_mut().zip(cols) {
            v.observe_slice(col);
        }
    }

    /// ApplyVocab over column-major sparse data.
    pub fn apply_columns(&self, cols: &[Vec<u32>]) -> Vec<Vec<u32>> {
        assert_eq!(cols.len(), self.vocabs.len());
        self.vocabs
            .iter()
            .zip(cols)
            .map(|(v, col)| {
                let mut out = vec![0u32; col.len()];
                v.apply_slice(col, &mut out);
                out
            })
            .collect()
    }

    /// Merge per-thread sub-sets (same column count) in thread order.
    pub fn merge_all(&mut self, subs: &[VocabSet]) {
        for sub in subs {
            assert_eq!(sub.vocabs.len(), self.vocabs.len());
            for (dst, src) in self.vocabs.iter_mut().zip(&sub.vocabs) {
                dst.merge_from(src);
            }
        }
    }

    pub fn total_entries(&self) -> usize {
        self.vocabs.iter().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    fn seq(vocab: &mut dyn Vocab, xs: &[u32]) -> Vec<u32> {
        for &x in xs {
            vocab.observe(x);
        }
        xs.iter().map(|&x| vocab.apply(x).unwrap()).collect()
    }

    #[test]
    fn appearance_order_indices() {
        let mut v = HashVocab::new();
        let idx = seq(&mut v, &[30, 10, 30, 20, 10]);
        assert_eq!(idx, vec![0, 1, 0, 2, 1]);
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn direct_matches_hash() {
        let mut rng = XorShift64::new(77);
        let xs: Vec<u32> = (0..5000).map(|_| rng.below(997) as u32).collect();
        let mut h = HashVocab::new();
        let mut d = DirectVocab::new(1000);
        let hi = seq(&mut h, &xs);
        let di = seq(&mut d, &xs);
        assert_eq!(hi, di, "hash and direct vocab must assign identically");
        assert_eq!(h.len(), d.len());
    }

    #[test]
    fn apply_unknown_is_none() {
        let mut v = HashVocab::new();
        v.observe(5);
        assert_eq!(v.apply(6), None);
        let mut d = DirectVocab::new(10);
        d.observe(5);
        assert_eq!(d.apply(6), None);
    }

    #[test]
    fn apply_slice_marks_misses_with_sentinel_not_zero() {
        // 0 is the first appearance index — a miss must be told apart.
        let mut v = HashVocab::new();
        v.observe(5);
        let mut out = vec![7u32; 3];
        v.apply_slice(&[5, 6, 5], &mut out);
        assert_eq!(out, vec![0, VOCAB_MISS, 0]);
        let mut d = DirectVocab::new(10);
        d.observe(5);
        let mut out = vec![7u32; 3];
        d.apply_slice(&[5, 6, 5], &mut out);
        assert_eq!(out, vec![0, VOCAB_MISS, 0]);
    }

    /// The fused scan must assign exactly the indices the two-loop scan
    /// does, for both backends — the invariant the engine's fused
    /// strategy is built on.
    #[test]
    fn observe_apply_equals_observe_then_apply() {
        let mut rng = XorShift64::new(0xF05E);
        for _ in 0..30 {
            let range = 1 + rng.below(1500) as u32;
            let xs: Vec<u32> =
                (0..rng.below(2000) as usize).map(|_| rng.below(range as u64) as u32).collect();

            let mut two_pass = HashVocab::new();
            for &x in &xs {
                two_pass.observe(x);
            }
            let want: Vec<u32> = xs.iter().map(|&x| two_pass.apply(x).unwrap()).collect();

            let mut fused_h = HashVocab::new();
            let got_h: Vec<u32> = xs.iter().map(|&x| fused_h.observe_apply(x)).collect();
            let mut fused_d = DirectVocab::new(range);
            let got_d: Vec<u32> = xs.iter().map(|&x| fused_d.observe_apply(x)).collect();

            assert_eq!(got_h, want, "fused HashVocab drifted from two-pass");
            assert_eq!(got_d, want, "fused DirectVocab drifted from two-pass");
            assert_eq!(fused_h.len(), two_pass.len());
            assert_eq!(fused_d.len(), two_pass.len());
        }
    }

    #[test]
    fn observe_apply_grows_the_hash_table() {
        let mut v = HashVocab::with_capacity(16);
        for x in 0..10_000u32 {
            assert_eq!(v.observe_apply(x), x); // inserted in order 0,1,2,...
            assert_eq!(v.observe_apply(x), x); // second visit: pure lookup
        }
        assert_eq!(v.len(), 10_000);
    }

    #[test]
    fn growth_preserves_assignments() {
        let mut v = HashVocab::with_capacity(16);
        let xs: Vec<u32> = (0..10_000).collect();
        for &x in &xs {
            v.observe(x);
        }
        for &x in &xs {
            assert_eq!(v.apply(x), Some(x)); // inserted in order 0,1,2,...
        }
    }

    #[test]
    fn merge_reproduces_single_thread_order_when_partitioned() {
        // Row-partitioned threads then merge-in-thread-order must equal a
        // single sequential scan: thread boundaries respect row order.
        let mut rng = XorShift64::new(123);
        let xs: Vec<u32> = (0..2000).map(|_| rng.below(300) as u32).collect();

        let mut seq_vocab = HashVocab::new();
        seq_vocab.observe_slice(&xs);

        let mut subs = Vec::new();
        for chunk in xs.chunks(500) {
            let mut s = HashVocab::new();
            s.observe_slice(chunk);
            subs.push(s);
        }
        let mut merged = HashVocab::new();
        for s in &subs {
            merged.merge_from(s);
        }

        // Every key must exist in both; the *sets* agree. Appearance
        // order differs only if a later thread saw a key earlier within
        // its chunk — the merge-in-thread-order rule resolves exactly as
        // Meta's pipeline does, and on chunked row order the first
        // appearance of each key lies in the earliest chunk containing
        // it, so indices agree with the sequential scan.
        assert_eq!(merged.len(), seq_vocab.len());
        for (k, _) in seq_vocab.iter_ordered() {
            assert!(merged.apply(k).is_some());
        }
    }

    #[test]
    fn iter_ordered_is_appearance_order() {
        let mut v = HashVocab::new();
        v.observe(42);
        v.observe(7);
        v.observe(42);
        v.observe(1);
        let got: Vec<(u32, u32)> = v.iter_ordered().collect();
        assert_eq!(got, vec![(42, 0), (7, 1), (1, 2)]);
    }

    /// Both backends must export the same appearance-order key list —
    /// the invariant a frozen artifact is built on: freezing from a
    /// DirectVocab (accelerator) or a HashVocab (CPU) run of the same
    /// stream yields bit-identical artifacts.
    #[test]
    fn export_keys_is_appearance_order_for_both_backends() {
        let mut h = HashVocab::new();
        let mut d = DirectVocab::new(100);
        for v in [42u32, 7, 42, 99, 7, 0] {
            h.observe(v);
            d.observe(v);
        }
        assert_eq!(h.export_keys(), vec![42, 7, 99, 0]);
        assert_eq!(d.export_keys(), vec![42, 7, 99, 0]);

        let mut rng = XorShift64::new(0xA2F1);
        for _ in 0..20 {
            let range = 1 + rng.below(3000) as u32;
            let mut h = HashVocab::new();
            let mut d = DirectVocab::new(range);
            for _ in 0..rng.below(4000) {
                let v = rng.below(range as u64) as u32;
                h.observe(v);
                d.observe(v);
            }
            assert_eq!(h.export_keys(), d.export_keys(), "range {range}");
        }
    }

    /// Rebuilding a vocabulary by observing exported keys in order must
    /// reproduce the original assignments exactly — the load half of the
    /// artifact round trip.
    #[test]
    fn export_keys_rebuild_reproduces_assignments() {
        let mut rng = XorShift64::new(0x51AB);
        let mut v = HashVocab::new();
        for _ in 0..2000 {
            v.observe(rng.below(700) as u32);
        }
        let mut rebuilt = HashVocab::new();
        for k in v.export_keys() {
            rebuilt.observe(k);
        }
        assert_eq!(rebuilt.len(), v.len());
        for (k, idx) in v.iter_ordered() {
            assert_eq!(rebuilt.apply(k), Some(idx));
        }
    }

    #[test]
    fn vocab_set_columns() {
        let cols = vec![vec![5, 5, 6], vec![9, 8, 9]];
        let mut set = VocabSet::new(2);
        set.observe_columns(&cols);
        let applied = set.apply_columns(&cols);
        assert_eq!(applied, vec![vec![0, 0, 1], vec![0, 1, 0]]);
        assert_eq!(set.total_entries(), 4);
    }

    #[test]
    fn direct_vocab_storage_bits() {
        let d = DirectVocab::new(5000);
        // bitmap ~5000 bits + table 5000*32 bits
        assert!(d.storage_bits() > 5000 * 32);
        assert!(d.storage_bits() < 5000 * 34 + 128);
    }

    /// Property: for random streams, DirectVocab and HashVocab agree on
    /// every index and on the final size.
    #[test]
    fn property_backends_agree() {
        let mut rng = XorShift64::new(0xBEEF);
        for _ in 0..50 {
            let range = 1 + rng.below(2048) as u32;
            let n = rng.below(3000) as usize;
            let xs: Vec<u32> = (0..n).map(|_| rng.below(range as u64) as u32).collect();
            let mut h = HashVocab::new();
            let mut d = DirectVocab::new(range);
            for &x in &xs {
                assert_eq!(h.observe(x), d.observe(x));
            }
            for &x in &xs {
                assert_eq!(h.apply(x), d.apply(x));
            }
        }
    }
}
