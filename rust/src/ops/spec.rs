//! Runtime-configurable operator pipelines (paper §5: "it is feasible to
//! dynamically configure the operators in the pipeline at runtime" — the
//! modular-PE generalizability claim).
//!
//! A [`PipelineSpec`] is a list of *rules*, each binding a validated
//! [`ColumnProgram`] to a set of columns via a [`ColumnSelector`] — so
//! different columns can run different transforms (per-feature
//! vocabulary sizes, log-scaling only some dense features, a bucketized
//! column). The spec grammar:
//!
//! ```text
//! sparse[*]: modulus:5000|genvocab|applyvocab;
//! sparse[0..4]: modulus:100000|genvocab|applyvocab;
//! dense[*]: neg2zero|log;
//! dense[12]: clip:0:100|bucketize:1:10:100
//! ```
//!
//! Rules apply in order — later rules **override** earlier ones for the
//! columns they select — and columns no rule covers pass through
//! unchanged. The classic flat grammar
//!
//! ```text
//! decode | fillmissing | hex2int | modulus:5000 | genvocab | applyvocab
//!        | neg2zero | logarithm | concatenate
//! ```
//!
//! keeps parsing as `[*]`-selector sugar: sparse-applicable ops become a
//! `sparse[*]` rule, dense-applicable ops a `dense[*]` rule, and the
//! Decode/Concatenate boundary markers are dropped (they are implied by
//! the decoded-row boundary). CLI flags, tests and the wire handshake
//! therefore stay compatible.
//!
//! A spec is **validated at construction** (parse / [`PipelineSpec::from_rules`]
//! / the [`PipelineSpec::dlrm`] preset): every program obeys the operator
//! dependency rules (GenVocab needs Modulus; ApplyVocab needs GenVocab;
//! Logarithm wants Neg2Zero). Resolution against a concrete [`Schema`]
//! — selector bounds, one compiled slot per column — happens once at
//! planning time via [`PipelineSpec::compile`], which produces the
//! [`ColumnPlans`] executor hot loops dispatch on.

use std::fmt;

use crate::data::row::ProcessedColumns;
use crate::data::{DecodedRow, Schema};
use crate::ops::program::{
    ColumnKind, ColumnOp, ColumnPlans, ColumnProgram, ColumnRange, ColumnSelector,
};
use crate::Result;

/// One rule of a spec: a program bound to a set of columns.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecRule {
    pub selector: ColumnSelector,
    pub program: ColumnProgram,
}

impl fmt::Display for SpecRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.selector, self.program)
    }
}

/// A validated per-column operator pipeline: an ordered list of
/// selector→program rules. Construction validates; a `PipelineSpec`
/// that exists is well-formed.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    rules: Vec<SpecRule>,
}

impl PipelineSpec {
    /// Build from explicit rules. Validates that the list is non-empty
    /// and every selector kind matches its program kind (the programs
    /// themselves were validated at their construction).
    pub fn from_rules(rules: Vec<SpecRule>) -> Result<PipelineSpec> {
        anyhow::ensure!(!rules.is_empty(), "empty pipeline");
        for rule in &rules {
            anyhow::ensure!(
                rule.selector.kind == rule.program.kind(),
                "selector {} bound to a {} program",
                rule.selector,
                rule.program.kind().name()
            );
        }
        Ok(PipelineSpec { rules })
    }

    pub fn rules(&self) -> &[SpecRule] {
        &self.rules
    }

    /// The paper's full DLRM pipeline at a given vocabulary size, as a
    /// per-column preset: every sparse column runs
    /// `fillmissing|hex2int|modulus:v|genvocab|applyvocab`, every dense
    /// column `fillmissing|neg2zero|logarithm`.
    pub fn dlrm(vocab: u32) -> PipelineSpec {
        let sparse = ColumnProgram::new(
            ColumnKind::Sparse,
            vec![
                ColumnOp::FillMissing,
                ColumnOp::Hex2Int,
                ColumnOp::Modulus(vocab),
                ColumnOp::GenVocab,
                ColumnOp::ApplyVocab,
            ],
        )
        .expect("DLRM sparse program is valid by construction");
        let dense = ColumnProgram::new(
            ColumnKind::Dense,
            vec![ColumnOp::FillMissing, ColumnOp::Neg2Zero, ColumnOp::Logarithm],
        )
        .expect("DLRM dense program is valid by construction");
        PipelineSpec {
            rules: vec![
                SpecRule { selector: ColumnSelector::sparse(ColumnRange::All), program: sparse },
                SpecRule { selector: ColumnSelector::dense(ColumnRange::All), program: dense },
            ],
        }
    }

    /// Parse a spec string and validate it. Accepts both grammars:
    /// `;`-separated `selector: ops` rules, or the classic flat
    /// `|`/`,`-separated op list (parsed as `[*]`-selector sugar).
    pub fn parse(spec: &str) -> Result<PipelineSpec> {
        // A segment is selector-shaped when a kind keyword is followed
        // by `[` (whitespace tolerated, exactly as ColumnSelector::parse
        // accepts it) — so the same rule string routes the same way
        // whether it stands alone or beside other rules.
        let selector_style = spec.split(';').any(|seg| {
            let s = seg.trim().to_ascii_lowercase();
            ["sparse", "dense"].into_iter().any(|kind| {
                s.strip_prefix(kind).is_some_and(|r| r.trim_start().starts_with('['))
            })
        });
        if selector_style {
            Self::parse_rules(spec)
        } else {
            anyhow::ensure!(
                !spec.contains(';'),
                "rule segments need sparse[...]/dense[...] selectors"
            );
            Self::parse_flat(spec)
        }
    }

    /// The selector grammar: `sel: op|op; sel: op|op; ...`.
    fn parse_rules(spec: &str) -> Result<PipelineSpec> {
        let mut rules = Vec::new();
        for seg in spec.split(';') {
            if seg.trim().is_empty() {
                continue; // tolerate a trailing `;`
            }
            let (sel, ops) = seg
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("rule `{}` needs `selector: ops`", seg.trim()))?;
            let selector = ColumnSelector::parse(sel)?;
            let ops = ops
                .split(|c| c == '|' || c == ',')
                .filter(|s| !s.trim().is_empty())
                .map(ColumnOp::parse)
                .collect::<Result<Vec<_>>>()?;
            let program = ColumnProgram::new(selector.kind, ops)
                .map_err(|e| anyhow::anyhow!("rule `{selector}`: {e}"))?;
            rules.push(SpecRule { selector, program });
        }
        Self::from_rules(rules)
    }

    /// The flat grammar as `[*]` sugar: route each op to the column
    /// kind(s) it applies to, dropping the Decode/Concatenate boundary
    /// markers. The old flat grammar compiled to global *flags*, so a
    /// stage mentioned twice (`…|logarithm|log`) applied once and the
    /// first `modulus` won — repeated legacy tokens collapse here to
    /// keep that contract (GenVocab/ApplyVocab duplicates still fall
    /// through to program validation, which rejects them, as before).
    fn parse_flat(spec: &str) -> Result<PipelineSpec> {
        let ops = spec
            .split(|c| c == '|' || c == ',')
            .filter(|s| !s.trim().is_empty())
            .map(ColumnOp::parse)
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!ops.is_empty(), "empty pipeline");
        let push_deduped = |list: &mut Vec<ColumnOp>, op: ColumnOp| {
            let legacy_flag = matches!(
                op,
                ColumnOp::FillMissing
                    | ColumnOp::Hex2Int
                    | ColumnOp::Modulus(_)
                    | ColumnOp::Neg2Zero
                    | ColumnOp::Logarithm
            );
            let dup = legacy_flag
                && list
                    .iter()
                    .any(|o| std::mem::discriminant(o) == std::mem::discriminant(&op));
            if !dup {
                list.push(op);
            }
        };
        let mut sparse = Vec::new();
        let mut dense = Vec::new();
        for op in ops {
            if op.applies_to(ColumnKind::Sparse) {
                push_deduped(&mut sparse, op.clone());
            }
            if op.applies_to(ColumnKind::Dense) {
                push_deduped(&mut dense, op);
            }
        }
        let mut rules = Vec::new();
        if !sparse.is_empty() {
            rules.push(SpecRule {
                selector: ColumnSelector::sparse(ColumnRange::All),
                program: ColumnProgram::new(ColumnKind::Sparse, sparse)?,
            });
        }
        if !dense.is_empty() {
            rules.push(SpecRule {
                selector: ColumnSelector::dense(ColumnRange::All),
                program: ColumnProgram::new(ColumnKind::Dense, dense)?,
            });
        }
        if rules.is_empty() {
            // Only boundary markers ("decode|concatenate") — previously
            // a valid passthrough pipeline; keep accepting it by binding
            // the no-op FillMissing (merged into decode) to every
            // column.
            rules = vec![
                SpecRule {
                    selector: ColumnSelector::sparse(ColumnRange::All),
                    program: ColumnProgram::new(
                        ColumnKind::Sparse,
                        vec![ColumnOp::FillMissing],
                    )?,
                },
                SpecRule {
                    selector: ColumnSelector::dense(ColumnRange::All),
                    program: ColumnProgram::new(ColumnKind::Dense, vec![ColumnOp::FillMissing])?,
                },
            ];
        }
        Self::from_rules(rules)
    }

    /// Resolve the rules against a concrete schema into one compiled
    /// slot per column ([`ColumnPlans`]) — the planning step. Later
    /// rules override earlier ones; uncovered columns pass through.
    /// The only failure mode is a selector out of the schema's range.
    pub fn compile(&self, schema: Schema) -> Result<ColumnPlans> {
        let mut plans = ColumnPlans::passthrough(schema);
        for rule in &self.rules {
            match rule.selector.kind {
                ColumnKind::Sparse => {
                    let cols = rule
                        .selector
                        .range
                        .resolve(schema.num_sparse)
                        .map_err(|e| anyhow::anyhow!("{}: {e}", rule.selector))?;
                    for c in cols {
                        plans.sparse[c] = rule.program.compile_sparse();
                    }
                }
                ColumnKind::Dense => {
                    let cols = rule
                        .selector
                        .range
                        .resolve(schema.num_dense)
                        .map_err(|e| anyhow::anyhow!("{}: {e}", rule.selector))?;
                    for c in cols {
                        plans.dense[c] = rule.program.compile_dense();
                    }
                }
            }
        }
        Ok(plans)
    }

    /// Execute over decoded rows (the post-`Decode` boundary). The spec
    /// was validated at construction, so the only failure mode is a
    /// schema-resolution mismatch — [`Self::compile`] then the row-wise
    /// reference interpreter ([`ColumnPlans::execute_rows`]).
    pub fn execute(&self, rows: &[DecodedRow], schema: Schema) -> Result<ProcessedColumns> {
        Ok(self.compile(schema)?.execute_rows(rows))
    }
}

impl fmt::Display for PipelineSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, rule) in self.rules.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{rule}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, SynthDataset};
    use crate::ops::{neg2zero, Modulus};
    use crate::util::XorShift64;

    fn rows() -> (Vec<DecodedRow>, Schema) {
        let ds = SynthDataset::generate(SynthConfig::small(120));
        (ds.rows.clone(), ds.schema())
    }

    #[test]
    fn parses_full_dlrm_pipeline() {
        let p = PipelineSpec::parse(
            "decode | fillmissing | hex2int | modulus:5_000 | genvocab | applyvocab \
             | neg2zero | logarithm | concatenate",
        )
        .unwrap();
        assert_eq!(p, PipelineSpec::dlrm(5000));
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(PipelineSpec::parse("").is_err());
        assert!(PipelineSpec::parse("frobnicate").is_err());
        assert!(PipelineSpec::parse("modulus").is_err(), "modulus needs arg");
        assert!(PipelineSpec::parse("modulus:0").is_err());
        assert!(PipelineSpec::parse("genvocab").is_err(), "needs modulus");
        assert!(PipelineSpec::parse("applyvocab|modulus:5|genvocab").is_err(), "order");
        assert!(PipelineSpec::parse("logarithm|neg2zero").is_err(), "order");
        assert!(PipelineSpec::parse("decode:4").is_err(), "unexpected arg");
        // selector grammar errors
        assert!(PipelineSpec::parse("sparse[*]:").is_err(), "empty program");
        assert!(PipelineSpec::parse("sparse[*]: neg2zero").is_err(), "dense op");
        assert!(PipelineSpec::parse("dense[*]: modulus:5").is_err(), "sparse op");
        assert!(PipelineSpec::parse("label[*]: neg2zero").is_err(), "unknown kind");
        assert!(PipelineSpec::parse("sparse[2..2]: modulus:5").is_err(), "empty range");
        assert!(
            PipelineSpec::parse("modulus:5; neg2zero").is_err(),
            "`;` segments need selectors"
        );
        assert!(
            PipelineSpec::parse("sparse[*]: decode").is_err(),
            "boundary markers are not column ops"
        );
    }

    /// Legacy flat-grammar contracts: the old parser compiled to global
    /// flags, so repeated stage mentions applied once and the first
    /// modulus won; boundary-marker-only specs were valid passthroughs.
    #[test]
    fn flat_grammar_legacy_contracts() {
        // `logarithm|log` must apply log1p ONCE (the old flag collapse).
        let doubled =
            PipelineSpec::parse("modulus:97|genvocab|applyvocab|neg2zero|logarithm|log").unwrap();
        let single =
            PipelineSpec::parse("modulus:97|genvocab|applyvocab|neg2zero|logarithm").unwrap();
        assert_eq!(doubled, single);
        // the first modulus wins, as the old `modulus()` accessor did
        let first = PipelineSpec::parse("modulus:5|modulus:7").unwrap();
        assert_eq!(first, PipelineSpec::parse("modulus:5").unwrap());
        // stateful duplicates still error (the old validate() rule)
        assert!(PipelineSpec::parse("modulus:5|genvocab|genvocab").is_err());
        // boundary markers alone are a valid passthrough pipeline
        let (rows, schema) = rows();
        let pass = PipelineSpec::parse("decode|concatenate").unwrap();
        let got = pass.execute(&rows, schema).unwrap();
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(got.sparse[0][r], row.sparse[0]);
            assert_eq!(got.dense[0][r], row.dense[0] as f32);
        }
        // ...and round-trips through display like any other spec
        assert_eq!(PipelineSpec::parse(&pass.to_string()).unwrap(), pass);
    }

    /// Whitespace between the kind keyword and the bracket routes to
    /// the selector grammar whether the rule stands alone or not.
    #[test]
    fn selector_detection_tolerates_whitespace() {
        assert_eq!(
            PipelineSpec::parse("sparse [0]: modulus:5").unwrap(),
            PipelineSpec::parse("sparse[0]: modulus:5").unwrap()
        );
        assert_eq!(
            PipelineSpec::parse(" DENSE [ * ] : neg2zero ").unwrap(),
            PipelineSpec::parse("dense[*]: neg2zero").unwrap()
        );
    }

    #[test]
    fn selector_grammar_parses_heterogeneous_spec() {
        let p = PipelineSpec::parse(
            "sparse[*]: modulus:5000|genvocab|applyvocab; \
             sparse[0..4]: modulus:100000|genvocab|applyvocab; \
             dense[*]: neg2zero|log",
        )
        .unwrap();
        assert_eq!(p.rules().len(), 3);
        let plans = p.compile(Schema::CRITEO).unwrap();
        // later rules override earlier ones
        assert_eq!(plans.sparse[0].modulus.unwrap().range, 100_000);
        assert_eq!(plans.sparse[3].modulus.unwrap().range, 100_000);
        assert_eq!(plans.sparse[4].modulus.unwrap().range, 5_000);
        assert_eq!(plans.sparse[25].modulus.unwrap().range, 5_000);
        assert!(plans.dense.iter().all(|d| d.kernels.len() == 2));
        assert_eq!(plans.vocab_columns(), 26);
        assert_eq!(plans.max_modulus().unwrap().range, 100_000);
    }

    #[test]
    fn compile_rejects_out_of_schema_selectors() {
        let p = PipelineSpec::parse("sparse[30]: modulus:5|genvocab|applyvocab").unwrap();
        assert!(p.compile(Schema::CRITEO).is_err(), "26 sparse columns only");
        assert!(p.compile(Schema::new(13, 31)).is_ok());
        let p = PipelineSpec::parse("dense[10..20]: neg2zero").unwrap();
        assert!(p.compile(Schema::CRITEO).is_err(), "13 dense columns only");
    }

    #[test]
    fn uncovered_columns_pass_through() {
        let (rows, schema) = rows();
        let p = PipelineSpec::parse("sparse[1]: modulus:53").unwrap();
        let got = p.execute(&rows, schema).unwrap();
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(got.sparse[0][r], row.sparse[0], "col 0 untouched");
            assert_eq!(got.sparse[1][r], row.sparse[1] % 53, "col 1 modulus");
            assert_eq!(got.dense[0][r], row.dense[0] as f32, "dense untouched");
        }
    }

    /// `parse(display(spec)) == spec` — the round-trip the net layer's
    /// wire handshake serializes through. Deterministic cases plus a
    /// PRNG-driven property sweep over random rule sets.
    #[test]
    fn display_parse_round_trips() {
        for s in [
            "modulus:5000|genvocab|applyvocab|neg2zero|logarithm",
            "sparse[*]: modulus:5000|genvocab|applyvocab; dense[*]: neg2zero|log",
            "dense[3]: clip:0:100|bucketize:1:10:100",
            "sparse[0..4]: fillmissing|hex2int|modulus:97|genvocab",
        ] {
            let spec = PipelineSpec::parse(s).unwrap();
            let round = PipelineSpec::parse(&spec.to_string()).unwrap();
            assert_eq!(round, spec, "{s} → {spec}");
        }

        let mut rng = XorShift64::new(0x5EC5);
        for _ in 0..200 {
            let spec = random_spec(&mut rng);
            let shown = spec.to_string();
            let round = PipelineSpec::parse(&shown)
                .unwrap_or_else(|e| panic!("display must re-parse: `{shown}`: {e}"));
            assert_eq!(round, spec, "`{shown}`");
        }
    }

    /// Random valid spec generator for the round-trip property.
    fn random_spec(rng: &mut XorShift64) -> PipelineSpec {
        let n_rules = 1 + rng.below(4) as usize;
        let mut rules = Vec::new();
        for _ in 0..n_rules {
            let sparse = rng.below(2) == 0;
            let range = match rng.below(3) {
                0 => ColumnRange::All,
                1 => ColumnRange::One(rng.below(30) as usize),
                _ => {
                    let a = rng.below(20) as usize;
                    ColumnRange::Span(a, a + 1 + rng.below(10) as usize)
                }
            };
            let (selector, program) = if sparse {
                let mut ops = vec![ColumnOp::Modulus(1 + rng.below(1_000_000) as u32)];
                if rng.below(2) == 0 {
                    ops.insert(0, ColumnOp::Hex2Int);
                }
                if rng.below(2) == 0 {
                    ops.push(ColumnOp::GenVocab);
                    if rng.below(2) == 0 {
                        ops.push(ColumnOp::ApplyVocab);
                    }
                }
                (
                    ColumnSelector::sparse(range),
                    ColumnProgram::new(ColumnKind::Sparse, ops).unwrap(),
                )
            } else {
                let mut ops = Vec::new();
                if rng.below(2) == 0 {
                    ops.push(ColumnOp::Neg2Zero);
                }
                if rng.below(2) == 0 {
                    ops.push(ColumnOp::Logarithm);
                }
                if rng.below(2) == 0 {
                    let lo = rng.below(100) as f32 - 50.0;
                    ops.push(ColumnOp::Clip { lo, hi: lo + rng.below(100) as f32 });
                }
                if rng.below(2) == 0 {
                    let mut b = rng.below(50) as f32 - 25.0;
                    let mut boundaries = Vec::new();
                    for _ in 0..1 + rng.below(4) {
                        boundaries.push(b);
                        b += 1.0 + rng.below(20) as f32;
                    }
                    ops.push(ColumnOp::Bucketize { boundaries });
                }
                if ops.is_empty() {
                    ops.push(ColumnOp::FillMissing);
                }
                (
                    ColumnSelector::dense(range),
                    ColumnProgram::new(ColumnKind::Dense, ops).unwrap(),
                )
            };
            rules.push(SpecRule { selector, program });
        }
        PipelineSpec::from_rules(rules).unwrap()
    }

    #[test]
    fn full_pipeline_matches_fixed_implementation() {
        let (rows, schema) = rows();
        let p = PipelineSpec::dlrm(997);
        let got = p.execute(&rows, schema).unwrap();

        let raw = crate::data::utf8::encode_dataset(&SynthDataset::generate(
            SynthConfig::small(120),
        ));
        let reference = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                Modulus::new(997),
            ),
            &raw,
        )
        .processed;
        assert_eq!(got, reference);
    }

    #[test]
    fn logarithm_is_optional() {
        let (rows, schema) = rows();
        let no_log = PipelineSpec::parse("modulus:97|genvocab|applyvocab|neg2zero")
            .unwrap()
            .execute(&rows, schema)
            .unwrap();
        // dense values are the raw neg2zero'd integers as f32
        for (c, col) in no_log.dense.iter().enumerate() {
            for (r, &v) in col.iter().enumerate() {
                assert_eq!(v, neg2zero(rows[r].dense[c]) as f32);
            }
        }
    }

    #[test]
    fn modulus_only_passthrough_sparse() {
        let (rows, schema) = rows();
        let p = PipelineSpec::parse("modulus:53").unwrap();
        let got = p.execute(&rows, schema).unwrap();
        for (c, col) in got.sparse.iter().enumerate() {
            for (r, &v) in col.iter().enumerate() {
                assert_eq!(v, rows[r].sparse[c] % 53);
            }
        }
    }

    /// Heterogeneous semantics, hand-checked: two vocab sizes, partial
    /// dense log, one clipped+bucketized column, one vocab-free column.
    #[test]
    fn heterogeneous_spec_semantics() {
        let (rows, schema) = rows();
        let p = PipelineSpec::parse(
            "sparse[*]: modulus:97|genvocab|applyvocab; \
             sparse[0]: modulus:13|genvocab|applyvocab; \
             sparse[1]: modulus:13; \
             dense[*]: neg2zero|log; \
             dense[0]: clip:0:50|bucketize:1:10:100; \
             dense[1]: neg2zero",
        )
        .unwrap();
        let got = p.execute(&rows, schema).unwrap();

        // sparse[0]: its own 13-range vocabulary, appearance-ordered.
        let mut v0 = crate::ops::HashVocab::new();
        for row in &rows {
            v0.observe(row.sparse[0] % 13);
        }
        use crate::ops::Vocab as _;
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(got.sparse[0][r], v0.apply(row.sparse[0] % 13).unwrap());
            // sparse[1]: modulus only, no vocab
            assert_eq!(got.sparse[1][r], row.sparse[1] % 13);
            // dense[0]: clip then bucketize
            let clipped = (row.dense[0] as f32).clamp(0.0, 50.0);
            let bucket = [1.0f32, 10.0, 100.0].iter().filter(|&&b| b <= clipped).count();
            assert_eq!(got.dense[0][r], bucket as f32);
            // dense[1]: neg2zero only
            assert_eq!(got.dense[1][r], neg2zero(row.dense[1]) as f32);
            // dense[2]: the [*] rule
            assert_eq!(got.dense[2][r], crate::ops::log1p(row.dense[2]));
        }
    }
}
