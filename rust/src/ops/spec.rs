//! Runtime-configurable operator pipelines (paper §5: "it is feasible to
//! dynamically configure the operators in the pipeline at runtime" — the
//! modular-PE generalizability claim).
//!
//! A [`PipelineSpec`] is parsed from a compact string such as
//!
//! ```text
//! decode | fillmissing | hex2int | modulus:5000 | genvocab | applyvocab
//!        | neg2zero | logarithm | concatenate
//! ```
//!
//! validated against the operator dependency rules (GenVocab needs
//! Modulus; ApplyVocab needs GenVocab; Logarithm wants Neg2Zero), and
//! executed over decoded rows by [`PipelineSpec::execute`] — the same
//! column-wise semantics the fixed DLRM pipeline uses, with optional
//! stages actually optional (e.g. Table 1 notes Logarithm "is optional").

use crate::data::row::ProcessedColumns;
use crate::data::{DecodedRow, Schema};
use crate::ops::{neg2zero, DirectVocab, Modulus, Vocab};
use crate::Result;

/// One operator in a pipeline (Table 1 names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpSpec {
    Decode,
    FillMissing,
    Hex2Int,
    Modulus(u32),
    GenVocab,
    ApplyVocab,
    Neg2Zero,
    Logarithm,
    Concatenate,
}

impl OpSpec {
    pub fn parse(token: &str) -> Result<OpSpec> {
        let t = token.trim().to_ascii_lowercase();
        let (name, arg) = match t.split_once(':') {
            Some((n, a)) => (n.trim().to_string(), Some(a.trim().to_string())),
            None => (t, None),
        };
        let no_arg = |op: OpSpec| -> Result<OpSpec> {
            anyhow::ensure!(arg.is_none(), "operator `{name}` takes no argument");
            Ok(op)
        };
        match name.as_str() {
            "decode" => no_arg(OpSpec::Decode),
            "fillmissing" => no_arg(OpSpec::FillMissing),
            "hex2int" => no_arg(OpSpec::Hex2Int),
            "modulus" => {
                let r: u32 = arg
                    .as_deref()
                    .ok_or_else(|| anyhow::anyhow!("modulus needs a range, e.g. modulus:5000"))?
                    .replace('_', "")
                    .parse()
                    .map_err(|e| anyhow::anyhow!("modulus range: {e}"))?;
                anyhow::ensure!(r > 0, "modulus range must be positive");
                Ok(OpSpec::Modulus(r))
            }
            "genvocab" => no_arg(OpSpec::GenVocab),
            "applyvocab" => no_arg(OpSpec::ApplyVocab),
            "neg2zero" => no_arg(OpSpec::Neg2Zero),
            "logarithm" | "log" => no_arg(OpSpec::Logarithm),
            "concatenate" | "concat" => no_arg(OpSpec::Concatenate),
            other => anyhow::bail!("unknown operator `{other}`"),
        }
    }
}

/// A validated operator pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    pub ops: Vec<OpSpec>,
}

/// The optional stages of a validated spec, as flags (see
/// [`PipelineSpec::flags`]). Decode/FillMissing/Hex2Int are implied by
/// the decoded-row boundary; Modulus is carried separately because it has
/// an argument.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpFlags {
    pub gen_vocab: bool,
    pub apply_vocab: bool,
    pub neg2zero: bool,
    pub logarithm: bool,
}

impl PipelineSpec {
    /// The paper's full DLRM pipeline at a given vocabulary size.
    pub fn dlrm(vocab: u32) -> PipelineSpec {
        PipelineSpec {
            ops: vec![
                OpSpec::Decode,
                OpSpec::FillMissing,
                OpSpec::Hex2Int,
                OpSpec::Modulus(vocab),
                OpSpec::GenVocab,
                OpSpec::ApplyVocab,
                OpSpec::Neg2Zero,
                OpSpec::Logarithm,
                OpSpec::Concatenate,
            ],
        }
    }

    /// Parse a `|`- or `,`-separated spec string and validate it.
    pub fn parse(spec: &str) -> Result<PipelineSpec> {
        let ops = spec
            .split(|c| c == '|' || c == ',')
            .filter(|s| !s.trim().is_empty())
            .map(OpSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let p = PipelineSpec { ops };
        p.validate()?;
        Ok(p)
    }

    /// Dependency rules between stateful/ordered operators.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.ops.is_empty(), "empty pipeline");
        let pos = |op: fn(&OpSpec) -> bool| self.ops.iter().position(op);
        let modulus = pos(|o| matches!(o, OpSpec::Modulus(_)));
        let gen = pos(|o| matches!(o, OpSpec::GenVocab));
        let apply = pos(|o| matches!(o, OpSpec::ApplyVocab));
        let n2z = pos(|o| matches!(o, OpSpec::Neg2Zero));
        let log = pos(|o| matches!(o, OpSpec::Logarithm));

        if let Some(g) = gen {
            let m = modulus
                .ok_or_else(|| anyhow::anyhow!("GenVocab requires Modulus earlier in the pipeline"))?;
            anyhow::ensure!(m < g, "Modulus must precede GenVocab");
        }
        if let Some(a) = apply {
            let g = gen
                .ok_or_else(|| anyhow::anyhow!("ApplyVocab requires GenVocab earlier in the pipeline"))?;
            anyhow::ensure!(g < a, "GenVocab must precede ApplyVocab");
        }
        if let (Some(l), Some(n)) = (log, n2z) {
            anyhow::ensure!(n < l, "Neg2Zero must precede Logarithm");
        }
        // duplicates of stateful ops are not meaningful
        for kind in ["GenVocab", "ApplyVocab"] {
            let count = self
                .ops
                .iter()
                .filter(|o| format!("{o:?}").starts_with(kind))
                .count();
            anyhow::ensure!(count <= 1, "{kind} may appear at most once");
        }
        Ok(())
    }

    fn has(&self, f: fn(&OpSpec) -> bool) -> bool {
        self.ops.iter().any(f)
    }

    pub fn modulus(&self) -> Option<Modulus> {
        self.ops.iter().find_map(|o| match o {
            OpSpec::Modulus(r) => Some(Modulus::new(*r)),
            _ => None,
        })
    }

    /// Which optional stages this spec enables — derived once at planning
    /// time so executor hot loops branch on bools, not on the op list.
    pub fn flags(&self) -> OpFlags {
        OpFlags {
            gen_vocab: self.has(|o| matches!(o, OpSpec::GenVocab)),
            apply_vocab: self.has(|o| matches!(o, OpSpec::ApplyVocab)),
            neg2zero: self.has(|o| matches!(o, OpSpec::Neg2Zero)),
            logarithm: self.has(|o| matches!(o, OpSpec::Logarithm)),
        }
    }

    /// Execute over decoded rows (the post-`Decode` boundary — Decode /
    /// FillMissing / Hex2Int are already reflected in [`DecodedRow`]).
    ///
    /// Sparse columns: Modulus → (GenVocab → ApplyVocab) as configured —
    /// without ApplyVocab the (modulus-limited) raw values pass through.
    /// Dense columns: Neg2Zero and/or Logarithm as configured.
    pub fn execute(&self, rows: &[DecodedRow], schema: Schema) -> Result<ProcessedColumns> {
        self.validate()?;
        let modulus = self.modulus();
        let OpFlags {
            gen_vocab: do_gen,
            apply_vocab: do_apply,
            neg2zero: do_n2z,
            logarithm: do_log,
        } = self.flags();

        let mut out = ProcessedColumns::with_schema(schema);
        // pass 1: vocabularies
        let mut vocabs: Vec<DirectVocab> = Vec::new();
        if do_gen {
            let m = modulus.expect("validated: GenVocab implies Modulus");
            vocabs = (0..schema.num_sparse).map(|_| DirectVocab::new(m.range)).collect();
            for row in rows {
                for (c, &s) in row.sparse.iter().enumerate() {
                    vocabs[c].observe(m.apply(s));
                }
            }
        }
        // pass 2: emit
        for row in rows {
            out.labels.push(row.label);
            for (c, &d) in row.dense.iter().enumerate() {
                let v = if do_n2z { neg2zero(d) } else { d };
                let v = if do_log { crate::ops::log1p(v) } else { v as f32 };
                out.dense[c].push(v);
            }
            for (c, &s) in row.sparse.iter().enumerate() {
                let v = modulus.map_or(s, |m| m.apply(s));
                let v = if do_apply {
                    // validated: GenVocab ran, so every value was observed
                    vocabs[c].apply(v).unwrap_or(crate::ops::VOCAB_MISS)
                } else {
                    v
                };
                out.sparse[c].push(v);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, SynthDataset};

    fn rows() -> (Vec<DecodedRow>, Schema) {
        let ds = SynthDataset::generate(SynthConfig::small(120));
        (ds.rows.clone(), ds.schema())
    }

    #[test]
    fn parses_full_dlrm_pipeline() {
        let p = PipelineSpec::parse(
            "decode | fillmissing | hex2int | modulus:5_000 | genvocab | applyvocab \
             | neg2zero | logarithm | concatenate",
        )
        .unwrap();
        assert_eq!(p, PipelineSpec::dlrm(5000));
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(PipelineSpec::parse("").is_err());
        assert!(PipelineSpec::parse("frobnicate").is_err());
        assert!(PipelineSpec::parse("modulus").is_err(), "modulus needs arg");
        assert!(PipelineSpec::parse("modulus:0").is_err());
        assert!(PipelineSpec::parse("genvocab").is_err(), "needs modulus");
        assert!(PipelineSpec::parse("applyvocab|modulus:5|genvocab").is_err(), "order");
        assert!(PipelineSpec::parse("logarithm|neg2zero").is_err(), "order");
        assert!(PipelineSpec::parse("decode:4").is_err(), "unexpected arg");
    }

    #[test]
    fn full_pipeline_matches_fixed_implementation() {
        let (rows, schema) = rows();
        let p = PipelineSpec::dlrm(997);
        let got = p.execute(&rows, schema).unwrap();

        let raw = crate::data::utf8::encode_dataset(&SynthDataset::generate(
            SynthConfig::small(120),
        ));
        let reference = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                Modulus::new(997),
            ),
            &raw,
        )
        .processed;
        assert_eq!(got, reference);
    }

    #[test]
    fn logarithm_is_optional() {
        let (rows, schema) = rows();
        let no_log = PipelineSpec::parse("modulus:97|genvocab|applyvocab|neg2zero")
            .unwrap()
            .execute(&rows, schema)
            .unwrap();
        // dense values are the raw neg2zero'd integers as f32
        for (c, col) in no_log.dense.iter().enumerate() {
            for (r, &v) in col.iter().enumerate() {
                assert_eq!(v, neg2zero(rows[r].dense[c]) as f32);
            }
        }
    }

    #[test]
    fn modulus_only_passthrough_sparse() {
        let (rows, schema) = rows();
        let p = PipelineSpec::parse("modulus:53").unwrap();
        let got = p.execute(&rows, schema).unwrap();
        for (c, col) in got.sparse.iter().enumerate() {
            for (r, &v) in col.iter().enumerate() {
                assert_eq!(v, rows[r].sparse[c] % 53);
            }
        }
    }
}
