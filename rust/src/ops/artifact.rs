//! Frozen vocabulary artifacts — the serialization format of the
//! freeze → serve lifecycle (ROADMAP item 2).
//!
//! A batch run builds per-column vocabularies (GenVocab); a serving
//! deployment must pin them: requests at inference time are transformed
//! against the *same* appearance indices training saw, or the embedding
//! rows they address are garbage. The artifact captures everything a
//! worker needs to reconstruct that state bit-for-bit:
//!
//! * the full [`PipelineSpec`] in its canonical display form (re-parsed
//!   and therefore re-validated at load — the same trick the wire
//!   [`crate::net::protocol::Job`] uses);
//! * the [`Schema`] the spec was compiled against;
//! * every sparse column's vocabulary as **keys in appearance order**
//!   ([`crate::ops::HashVocab::export_keys`] /
//!   [`crate::ops::DirectVocab::export_keys`] — both backends export
//!   the identical list, so artifacts are backend-independent);
//! * content hashes of the spec and schema, so a consumer can check a
//!   candidate plan against the artifact *without* decoding the key
//!   lists, and a whole-file checksum so corruption is an explicit
//!   load error, never a silently wrong index.
//!
//! ## Binary layout (all integers little-endian)
//!
//! ```text
//! magic      4 bytes  "PIPA"
//! version    u16      ARTIFACT_VERSION
//! num_dense  u32      ┐ schema
//! num_sparse u32      ┘
//! spec_hash  u64      FNV-1a 64 of the spec's display string
//! schema_hash u64     FNV-1a 64 of (num_dense, num_sparse) as LE words
//! spec_len   u32
//! spec       utf8     canonical PipelineSpec display form
//! ncols      u32      == num_sparse
//! per column:         len:u32  keys:u32 × len   (appearance order)
//! checksum   u64      FNV-1a 64 of every preceding byte
//! ```
//!
//! The checksum is last so the writer streams the body once; the reader
//! verifies it before trusting any length field beyond the basic bounds
//! checks. Decoding rejects: bad magic, unknown version, truncation,
//! trailing bytes, checksum mismatch, a spec that no longer parses or
//! compiles, and stored spec/schema hashes that disagree with the
//! recomputed ones (a hash mismatch with a valid checksum means the
//! artifact was assembled inconsistently — refuse it rather than serve
//! wrong indices).

use std::path::Path;

use crate::data::Schema;
use crate::ops::PipelineSpec;
use crate::Result;

/// First four bytes of every artifact file.
pub const ARTIFACT_MAGIC: [u8; 4] = *b"PIPA";

/// Current artifact format version. Bump on any layout change — old
/// readers must reject newer artifacts instead of misreading them.
pub const ARTIFACT_VERSION: u16 = 1;

/// FNV-1a 64-bit over a byte slice — the artifact's content hash and
/// checksum primitive (no dependencies, stable across platforms; the
/// same mix the engine's bench checksums use).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A frozen, self-describing vocabulary snapshot: spec + schema +
/// per-sparse-column keys in appearance order (empty lists for columns
/// whose program builds no vocabulary).
#[derive(Debug, Clone, PartialEq)]
pub struct VocabArtifact {
    spec: PipelineSpec,
    schema: Schema,
    vocabs: Vec<Vec<u32>>,
}

impl VocabArtifact {
    /// Assemble an artifact. Validates up front that the spec still
    /// compiles against the schema and that there is exactly one key
    /// list per sparse column — an artifact that cannot be loaded must
    /// not be saveable.
    pub fn new(spec: PipelineSpec, schema: Schema, vocabs: Vec<Vec<u32>>) -> Result<VocabArtifact> {
        spec.compile(schema)?;
        anyhow::ensure!(
            vocabs.len() == schema.num_sparse,
            "artifact has {} vocabulary columns, schema wants {}",
            vocabs.len(),
            schema.num_sparse
        );
        Ok(VocabArtifact { spec, schema, vocabs })
    }

    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    pub fn schema(&self) -> Schema {
        self.schema
    }

    /// Per-column keys in appearance order.
    pub fn vocabs(&self) -> &[Vec<u32>] {
        &self.vocabs
    }

    pub fn total_entries(&self) -> usize {
        self.vocabs.iter().map(|c| c.len()).sum()
    }

    /// Content hash of the spec (over its canonical display string) —
    /// what consumers compare a candidate plan's spec against.
    pub fn spec_hash(&self) -> u64 {
        spec_hash(&self.spec)
    }

    /// Content hash of the schema dimensions.
    pub fn schema_hash(&self) -> u64 {
        schema_hash(self.schema)
    }

    /// Serialize to the versioned, checksummed byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let spec = self.spec.to_string();
        let keys: usize = self.total_entries();
        let mut out = Vec::with_capacity(42 + spec.len() + 4 * self.vocabs.len() + 4 * keys);
        out.extend_from_slice(&ARTIFACT_MAGIC);
        out.extend_from_slice(&ARTIFACT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.schema.num_dense as u32).to_le_bytes());
        out.extend_from_slice(&(self.schema.num_sparse as u32).to_le_bytes());
        out.extend_from_slice(&self.spec_hash().to_le_bytes());
        out.extend_from_slice(&self.schema_hash().to_le_bytes());
        out.extend_from_slice(&(spec.len() as u32).to_le_bytes());
        out.extend_from_slice(spec.as_bytes());
        out.extend_from_slice(&(self.vocabs.len() as u32).to_le_bytes());
        for col in &self.vocabs {
            out.extend_from_slice(&(col.len() as u32).to_le_bytes());
            for &k in col {
                out.extend_from_slice(&k.to_le_bytes());
            }
        }
        let checksum = fnv1a(&out);
        out.extend_from_slice(&checksum.to_le_bytes());
        out
    }

    /// Decode and fully validate an artifact (see the module docs for
    /// the rejection list). Every length is bounds-checked before use,
    /// so a truncated or corrupt buffer is an error, never a panic.
    pub fn decode(buf: &[u8]) -> Result<VocabArtifact> {
        anyhow::ensure!(buf.len() >= 42 + 8, "artifact truncated: {} bytes", buf.len());
        // Checksum first: nothing past the length check is trusted
        // until the whole file is known intact.
        let body = &buf[..buf.len() - 8];
        let stored = rd_u64(buf, buf.len() - 8)?;
        anyhow::ensure!(
            fnv1a(body) == stored,
            "artifact checksum mismatch (corrupt or tampered file)"
        );
        anyhow::ensure!(buf[..4] == ARTIFACT_MAGIC, "not a vocabulary artifact (bad magic)");
        let version = u16::from_le_bytes([buf[4], buf[5]]);
        anyhow::ensure!(
            version == ARTIFACT_VERSION,
            "artifact version {version} is not supported (this build reads {ARTIFACT_VERSION})"
        );
        let num_dense = rd_u32(buf, 6)? as usize;
        let num_sparse = rd_u32(buf, 10)? as usize;
        let schema = Schema::new(num_dense, num_sparse);
        let stored_spec_hash = rd_u64(buf, 14)?;
        let stored_schema_hash = rd_u64(buf, 22)?;
        let spec_len = rd_u32(buf, 30)? as usize;
        let spec_end = 34usize
            .checked_add(spec_len)
            .ok_or_else(|| anyhow::anyhow!("artifact spec length overflows"))?;
        anyhow::ensure!(spec_end <= body.len(), "artifact truncated inside the spec");
        let spec_str = std::str::from_utf8(&buf[34..spec_end])
            .map_err(|e| anyhow::anyhow!("artifact spec is not UTF-8: {e}"))?;
        let spec = PipelineSpec::parse(spec_str)?;
        anyhow::ensure!(
            spec_hash(&spec) == stored_spec_hash,
            "artifact spec hash mismatch (stored {stored_spec_hash:#018x})"
        );
        anyhow::ensure!(
            schema_hash(schema) == stored_schema_hash,
            "artifact schema hash mismatch (stored {stored_schema_hash:#018x})"
        );

        let ncols = rd_u32(buf, spec_end)? as usize;
        anyhow::ensure!(
            ncols == num_sparse,
            "artifact has {ncols} vocabulary columns, its schema says {num_sparse}"
        );
        let mut at = spec_end + 4;
        let mut vocabs = Vec::with_capacity(ncols);
        for c in 0..ncols {
            let len = rd_u32(buf, at)? as usize;
            at += 4;
            // Bound the allocation by the bytes actually present —
            // a corrupt length must not force a huge reservation.
            anyhow::ensure!(
                at + 4 * len <= body.len(),
                "artifact truncated inside column {c}'s keys"
            );
            let mut col = Vec::with_capacity(len);
            for _ in 0..len {
                col.push(rd_u32(buf, at)?);
                at += 4;
            }
            vocabs.push(col);
        }
        anyhow::ensure!(at == body.len(), "trailing bytes in artifact");
        VocabArtifact::new(spec, schema, vocabs)
    }

    /// Write the artifact to a file (encode + single write).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.encode())
            .map_err(|e| anyhow::anyhow!("writing artifact {}: {e}", path.display()))
    }

    /// Read and validate an artifact file.
    pub fn load(path: &Path) -> Result<VocabArtifact> {
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading artifact {}: {e}", path.display()))?;
        Self::decode(&buf)
    }
}

/// FNV-1a 64 of a spec's canonical display string.
pub fn spec_hash(spec: &PipelineSpec) -> u64 {
    fnv1a(spec.to_string().as_bytes())
}

/// FNV-1a 64 of the schema dimensions (as two LE u64 words).
pub fn schema_hash(schema: Schema) -> u64 {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&(schema.num_dense as u64).to_le_bytes());
    bytes[8..].copy_from_slice(&(schema.num_sparse as u64).to_le_bytes());
    fnv1a(&bytes)
}

fn rd_u32(buf: &[u8], at: usize) -> Result<u32> {
    let s = buf
        .get(at..at + 4)
        .ok_or_else(|| anyhow::anyhow!("artifact truncated at byte {at}"))?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

fn rd_u64(buf: &[u8], at: usize) -> Result<u64> {
    let s = buf
        .get(at..at + 8)
        .ok_or_else(|| anyhow::anyhow!("artifact truncated at byte {at}"))?;
    Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> VocabArtifact {
        let spec = PipelineSpec::dlrm(997);
        let schema = Schema::new(2, 3);
        let vocabs = vec![vec![5, 1, 9], vec![], vec![42, 0]];
        VocabArtifact::new(spec, schema, vocabs).unwrap()
    }

    #[test]
    fn encode_decode_round_trips() {
        let a = sample();
        let b = VocabArtifact::decode(&a.encode()).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.total_entries(), 5);
        assert_eq!(b.spec_hash(), a.spec_hash());
        assert_eq!(b.schema_hash(), a.schema_hash());
    }

    #[test]
    fn fnv1a_is_the_reference_function() {
        // Reference vectors for 64-bit FNV-1a.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn every_truncation_is_rejected() {
        let buf = sample().encode();
        for cut in [0, 3, 5, 13, 33, buf.len() / 2, buf.len() - 1] {
            assert!(
                VocabArtifact::decode(&buf[..cut]).is_err(),
                "truncation to {cut} bytes must fail"
            );
        }
    }

    #[test]
    fn corruption_is_rejected_everywhere() {
        let clean = sample().encode();
        // Flip one bit at every byte position: either the checksum (body
        // flips) or the stored checksum itself (tail flips) must fail.
        for at in 0..clean.len() {
            let mut bad = clean.clone();
            bad[at] ^= 0x40;
            assert!(VocabArtifact::decode(&bad).is_err(), "flip at byte {at} must fail");
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut buf = sample().encode();
        buf.extend_from_slice(&[0u8; 4]);
        assert!(VocabArtifact::decode(&buf).is_err());
    }

    #[test]
    fn schema_column_count_mismatch_rejected_at_build() {
        let spec = PipelineSpec::dlrm(97);
        let schema = Schema::new(2, 3);
        assert!(VocabArtifact::new(spec, schema, vec![vec![]; 2]).is_err());
    }

    #[test]
    fn spec_that_cannot_compile_rejected_at_build() {
        let spec = PipelineSpec::parse("sparse[40]: modulus:7|genvocab|applyvocab").unwrap();
        assert!(VocabArtifact::new(spec, Schema::CRITEO, vec![vec![]; 26]).is_err());
    }

    #[test]
    fn hashes_are_content_hashes() {
        let a = sample();
        let other = VocabArtifact::new(
            PipelineSpec::dlrm(5000),
            Schema::new(2, 3),
            vec![vec![]; 3],
        )
        .unwrap();
        assert_ne!(a.spec_hash(), other.spec_hash(), "different specs, different hashes");
        assert_eq!(a.schema_hash(), other.schema_hash(), "same schema, same hash");
        assert_ne!(a.schema_hash(), schema_hash(Schema::CRITEO));
    }
}
