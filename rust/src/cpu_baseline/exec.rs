//! The CPU baseline as a streaming [`Executor`] — Meta's row-partitioned
//! multithreading applied per chunk.
//!
//! Pass 1 mirrors GV: each chunk is partitioned across `threads`, every
//! thread builds private per-column sub-dictionaries, and the shards are
//! merged in order at the chunk barrier (deterministically equivalent to
//! a sequential scan — the same argument as §2.3's merge). Pass 2
//! mirrors AV + CFR: threads map their row shards through the sealed
//! vocabularies and the shard blocks are concatenated in order.
//!
//! Compute is **measured** (it really runs on this machine's cores).
//! Config I's intermediate disk round-trips are still charged by the
//! calibrated [`SimDisk`] model over the stream totals — the same byte
//! volumes the staged [`super::run`] charges — so its end-to-end time
//! stays `meas+sim`-tagged and comparable to the paper. Config II's
//! shared locked dictionary remains a measurement artifact of the staged
//! baseline (Fig. 8); the streaming executor always uses private
//! sub-dictionaries, so its output is deterministic for all configs.

use std::time::{Duration, Instant};

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::RowBlock;
use crate::ops::HashVocab;
use crate::pipeline::{ChunkState, Executor, ExecutorReport, ExecutorRun, Plan, StreamStats};
use crate::report::TimeTag;
use crate::Result;

use super::pipeline::partition_rows;
use super::{ConfigKind, SimDisk};

/// The multithreaded CPU baseline, as a reusable streaming executor.
#[derive(Debug, Clone)]
pub struct CpuExecutor {
    pub kind: ConfigKind,
    pub threads: usize,
    /// Simulated-disk parameters (only Config I charges them).
    pub disk: SimDisk,
}

impl CpuExecutor {
    pub fn new(kind: ConfigKind, threads: usize) -> Self {
        CpuExecutor { kind, threads: threads.max(1), disk: SimDisk::default() }
    }
}

impl Executor for CpuExecutor {
    fn name(&self) -> String {
        format!("CPU-{} {}", self.threads, self.kind.name())
    }

    /// Paper Table 2: the UTF-8 configs (I/II) cannot take binary input
    /// and Config III consumes only the pre-decoded binary dataset.
    fn accepts(&self, input: InputFormat) -> bool {
        match input {
            InputFormat::Utf8 => !self.kind.binary_input(),
            InputFormat::Binary => self.kind.binary_input(),
        }
    }

    fn begin(&self, plan: &Plan) -> Result<Box<dyn ExecutorRun>> {
        Ok(Box::new(CpuRun {
            state: ChunkState::new(plan),
            kind: self.kind,
            threads: self.threads,
            disk: self.disk,
            observe_time: Duration::ZERO,
            process_time: Duration::ZERO,
        }))
    }
}

struct CpuRun {
    state: ChunkState,
    kind: ConfigKind,
    threads: usize,
    disk: SimDisk,
    observe_time: Duration,
    process_time: Duration,
}

impl ExecutorRun for CpuRun {
    fn observe(&mut self, block: &RowBlock) -> Result<()> {
        let t0 = Instant::now();
        let rows = block.num_rows();
        if self.threads <= 1 || rows < 2 * self.threads {
            self.state.observe(block);
        } else {
            // Sharding is range-slicing of the column-major block: each
            // thread scans its row range of every column slice.
            let parts = partition_rows(rows, self.threads);
            let mut subs: Vec<Vec<HashVocab>> = Vec::with_capacity(parts.len());
            let state = &self.state;
            std::thread::scope(|scope| {
                let handles: Vec<_> = parts
                    .iter()
                    .map(|range| {
                        let range = range.clone();
                        scope.spawn(move || state.observe_sub(block, range))
                    })
                    .collect();
                for h in handles {
                    subs.push(h.join().expect("GV worker panicked"));
                }
            });
            self.state.merge_subs(&subs);
        }
        self.observe_time += t0.elapsed();
        Ok(())
    }

    fn process(&mut self, block: &RowBlock) -> Result<ProcessedColumns> {
        let t0 = Instant::now();
        let rows = block.num_rows();
        let out = if self.threads <= 1 || rows < 2 * self.threads {
            self.state.process(block)
        } else {
            let parts = partition_rows(rows, self.threads);
            let mut shards: Vec<ProcessedColumns> = Vec::with_capacity(parts.len());
            let state = &self.state;
            std::thread::scope(|scope| {
                let handles: Vec<_> = parts
                    .iter()
                    .map(|range| {
                        let range = range.clone();
                        scope.spawn(move || state.process_range(block, range))
                    })
                    .collect();
                for h in handles {
                    shards.push(h.join().expect("AV worker panicked"));
                }
            });
            // CFR within the chunk: shard outputs back in row order.
            let mut out = shards.remove(0);
            for b in &shards {
                out.extend_from(b);
            }
            out
        };
        self.process_time += t0.elapsed();
        Ok(out)
    }

    fn finish(&mut self, stats: &StreamStats) -> Result<ExecutorReport> {
        // Config I round-trips intermediates through (simulated) disk —
        // the same byte volumes the staged baseline charges: SIF writes
        // the sub-files, GV reads them back and writes the partially
        // processed data, AV reads and rewrites it, CFR reads it again
        // (paper §4.2.1).
        let disk_sim = if self.kind == ConfigKind::I {
            let raw = stats.raw_bytes as usize;
            let part = stats.rows as usize * self.state.schema.binary_row_bytes();
            self.disk.write_cost(raw, self.threads)
                + self.disk.read_cost(raw, self.threads)
                + self.disk.write_cost(part, self.threads)
                + self.disk.read_cost(part, self.threads)
                + self.disk.write_cost(part, self.threads)
                + self.disk.read_cost(part, self.threads)
        } else {
            Duration::ZERO
        };
        let (tag, modeled_e2e) = if disk_sim > Duration::ZERO {
            (TimeTag::Mixed, Some(stats.wall + disk_sim))
        } else {
            (TimeTag::Measured, None) // the engine's measured wallclock is the e2e
        };
        Ok(ExecutorReport {
            tag,
            modeled_e2e,
            // GV+AV work actually executed here (Table 3 scope, measured).
            compute: Some(self.observe_time + self.process_time),
            vocab_entries: self.state.vocab_entries(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{utf8, SynthConfig, SynthDataset};
    use crate::ops::Modulus;
    use crate::pipeline::{MemorySource, PipelineBuilder};

    #[test]
    fn streaming_cpu_matches_staged_baseline() {
        let ds = SynthDataset::generate(SynthConfig::small(400));
        let raw = utf8::encode_dataset(&ds);
        let m = Modulus::new(997);

        let staged = super::super::run(
            &super::super::BaselineConfig::new(ConfigKind::I, 4, m),
            &raw,
        );

        for chunk_rows in [32usize, 1000] {
            let pipeline = PipelineBuilder::new()
                .spec(crate::ops::PipelineSpec::dlrm(m.range))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(chunk_rows)
                .executor(Box::new(CpuExecutor::new(ConfigKind::I, 4)))
                .build()
                .unwrap();
            let mut source = MemorySource::new(&raw, InputFormat::Utf8);
            let (cols, report) = pipeline.run_collect(&mut source).unwrap();
            assert_eq!(cols, staged.processed, "chunk_rows={chunk_rows}");
            assert_eq!(report.rows, 400);
            // Config I charges the simulated disk round-trips on top of
            // the measured wallclock.
            assert_eq!(report.tag, TimeTag::Mixed);
            assert!(report.e2e > report.wall, "disk sim must be charged");
            assert!(report.compute.unwrap() <= report.wall + Duration::from_millis(50));
        }
    }

    #[test]
    fn config_iii_is_purely_measured() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let raw = crate::data::binary::encode_dataset(&ds);
        let pipeline = PipelineBuilder::new()
            .spec(crate::ops::PipelineSpec::dlrm(499))
            .schema(ds.schema())
            .input(InputFormat::Binary)
            .chunk_rows(64)
            .executor(Box::new(CpuExecutor::new(ConfigKind::III, 2)))
            .build()
            .unwrap();
        let mut source = MemorySource::new(&raw, InputFormat::Binary);
        let (_, report) = pipeline.run_collect(&mut source).unwrap();
        assert_eq!(report.tag, TimeTag::Measured);
        assert_eq!(report.e2e, report.wall, "no sim component outside Config I");
    }

    #[test]
    fn capability_checks_match_paper_table2() {
        let i = CpuExecutor::new(ConfigKind::I, 2);
        let iii = CpuExecutor::new(ConfigKind::III, 2);
        assert!(i.accepts(InputFormat::Utf8) && !i.accepts(InputFormat::Binary));
        assert!(!iii.accepts(InputFormat::Utf8) && iii.accepts(InputFormat::Binary));
    }
}
