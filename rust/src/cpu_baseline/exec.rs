//! The CPU baseline as a streaming [`Executor`] — Meta's row-partitioned
//! multithreading applied per chunk.
//!
//! Two-pass: pass 1 mirrors GV — each chunk is partitioned across
//! `threads`, every thread builds private per-column sub-dictionaries,
//! and the shards are merged in order at the chunk barrier
//! (deterministically equivalent to a sequential scan — the same
//! argument as §2.3's merge). Pass 2 mirrors AV + CFR: threads map
//! their row shards through the sealed vocabularies and the shard
//! blocks are concatenated in order.
//!
//! Fused: the stateless ops (labels, dense finishing) stay sharded
//! across threads, but the vocabulary assignment becomes a *sequential
//! in-order stage* per chunk — on-the-fly appearance indices admit no
//! row partitioning, because a shard cannot know whether an earlier row
//! already named a value. This faithfully models why CPUs scale poorly
//! on the fused dataflow (the paper's argument for hardware): the fused
//! strategy deletes a whole decode+observe pass but serializes the
//! stateful stage, so CPU fused wins on decode-dominated input and the
//! win shrinks as threads grow.
//!
//! Compute is **measured** (it really runs on this machine's cores).
//! Config I's intermediate disk round-trips are still charged by the
//! calibrated [`SimDisk`] model over the stream totals — the same byte
//! volumes the staged [`super::run`] charges — so its end-to-end time
//! stays `meas+sim`-tagged and comparable to the paper. Config II's
//! shared locked dictionary remains a measurement artifact of the staged
//! baseline (Fig. 8); the streaming executor always uses private
//! sub-dictionaries, so its output is deterministic for all configs.

use std::ops::Range;
use std::time::{Duration, Instant};

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::RowBlock;
use crate::ops::HashVocab;
use crate::pipeline::executor::{fuse_sparse_into, stateless_range};
use crate::pipeline::{
    ChunkState, Executor, ExecutorReport, ExecutorRun, FusedStages, Plan, StreamStats,
};
use crate::report::TimeTag;
use crate::Result;

use super::pipeline::partition_rows;
use super::{ConfigKind, SimDisk};

/// The multithreaded CPU baseline, as a reusable streaming executor.
#[derive(Debug, Clone)]
pub struct CpuExecutor {
    pub kind: ConfigKind,
    pub threads: usize,
    /// Simulated-disk parameters (only Config I charges them).
    pub disk: SimDisk,
}

impl CpuExecutor {
    pub fn new(kind: ConfigKind, threads: usize) -> Self {
        CpuExecutor { kind, threads: threads.max(1), disk: SimDisk::default() }
    }
}

impl Executor for CpuExecutor {
    fn name(&self) -> String {
        format!("CPU-{} {}", self.threads, self.kind.name())
    }

    /// Paper Table 2: the UTF-8 configs (I/II) cannot take binary input
    /// and Config III consumes only the pre-decoded binary dataset.
    fn accepts(&self, input: InputFormat) -> bool {
        match input {
            InputFormat::Utf8 => !self.kind.binary_input(),
            InputFormat::Binary => self.kind.binary_input(),
        }
    }

    /// Any plan can fuse on the CPU — the vocab stage just degrades to
    /// sequential (see module docs).
    fn supports_fused(&self, _plan: &Plan) -> bool {
        true
    }

    fn begin(&self, plan: &Plan) -> Result<Box<dyn ExecutorRun>> {
        Ok(Box::new(CpuRun {
            state: ChunkState::new(plan),
            kind: self.kind,
            threads: self.threads,
            disk: self.disk,
            fused_gv: plan.strategy == crate::pipeline::ExecStrategy::Fused
                && plan.has_gen_vocab(),
            observe_time: Duration::ZERO,
            process_time: Duration::ZERO,
        }))
    }
}

struct CpuRun {
    state: ChunkState,
    kind: ConfigKind,
    threads: usize,
    disk: SimDisk,
    /// True when the plan actually fuses a GenVocab stage — Config I's
    /// disk charge drops the GV→AV intermediate round-trip only then (a
    /// vocabulary-free plan executes identically under both strategies
    /// and must model identically too).
    fused_gv: bool,
    observe_time: Duration,
    process_time: Duration,
}

impl CpuRun {
    /// The one shard-and-concatenate scaffold every emitting path uses:
    /// partition the chunk's rows across `threads`, run `f` per range on
    /// a scoped thread, glue the outputs back in row order (the CFR
    /// step). Small chunks take one direct call.
    fn sharded<F>(&self, block: &RowBlock, f: F) -> ProcessedColumns
    where
        F: Fn(&ChunkState, &RowBlock, Range<usize>) -> ProcessedColumns + Sync,
    {
        let rows = block.num_rows();
        if self.threads <= 1 || rows < 2 * self.threads {
            return f(&self.state, block, 0..rows);
        }
        let parts = partition_rows(rows, self.threads);
        let mut shards: Vec<ProcessedColumns> = Vec::with_capacity(parts.len());
        let state = &self.state;
        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = parts
                .iter()
                .map(|range| {
                    let range = range.clone();
                    scope.spawn(move || f(state, block, range))
                })
                .collect();
            for h in handles {
                shards.push(h.join().expect("CPU shard worker panicked"));
            }
        });
        let mut out = shards.remove(0);
        for b in &shards {
            out.extend_from(b);
        }
        out
    }
}

impl ExecutorRun for CpuRun {
    /// Fused single pass: the stateless stage (labels + dense finishing)
    /// is sharded exactly like pass 2, then the sparse columns run
    /// through the sequential in-order vocab-assign stage. The
    /// sequential stage is charged to `observe_time` (it *is* the
    /// GenVocab work, now inline), the sharded stage to `process_time`,
    /// so fused-vs-two-pass reports show where the saved pass went.
    ///
    /// A plan with no GenVocab has no stateful stage at all — there is
    /// nothing to fuse, so it keeps the fully sharded pass-2 path
    /// (sparse included) instead of paying a pointless sequential scan.
    fn process_observing(
        &mut self,
        block: &RowBlock,
        sink: &mut dyn crate::pipeline::Sink,
    ) -> Result<()> {
        if !self.state.has_gen_vocab() {
            let out = self.process(block)?;
            return sink.push(&out);
        }
        let t0 = Instant::now();
        let mut out = self.sharded(block, |s, b, r| s.process_stateless_range(b, r));
        self.process_time += t0.elapsed();

        // The stateful stage: one thread, row order — the CPU's fused
        // bottleneck.
        let t1 = Instant::now();
        self.state.fuse_sparse(block, &mut out);
        self.observe_time += t1.elapsed();
        sink.push(&out)
    }

    /// Stage-split for the pipelined fused scheduler: the stateless
    /// closure is the same shard-and-concatenate scaffold as
    /// [`CpuRun::sharded`] over [`stateless_range`] (callable from the
    /// engine's stage thread), the vocab closure is the sequential
    /// in-order [`fuse_sparse_into`] scan. The two borrow disjoint
    /// halves of the chunk state ([`ChunkState::stage_split`]), which is
    /// what lets chunk N+1's stateless shards run while chunk N is
    /// inside the vocab scan. A vocabulary-free plan has no sequential
    /// stage to overlap — it reports `None` and keeps the fully sharded
    /// sequential fused path.
    fn stages(&mut self) -> Option<FusedStages<'_>> {
        if !self.state.has_gen_vocab() {
            return None;
        }
        let threads = self.threads;
        let (programs, vocabs) = self.state.stage_split();
        let stateless = Box::new(move |block: &RowBlock| {
            let rows = block.num_rows();
            if threads <= 1 || rows < 2 * threads {
                return stateless_range(programs, block, 0..rows);
            }
            let parts = partition_rows(rows, threads);
            let mut shards: Vec<ProcessedColumns> = Vec::with_capacity(parts.len());
            std::thread::scope(|scope| {
                let handles: Vec<_> = parts
                    .iter()
                    .map(|range| {
                        let range = range.clone();
                        scope.spawn(move || stateless_range(programs, block, range))
                    })
                    .collect();
                for h in handles {
                    shards.push(h.join().expect("CPU shard worker panicked"));
                }
            });
            let mut out = shards.remove(0);
            for b in &shards {
                out.extend_from(b);
            }
            out
        });
        let vocab = Box::new(move |block: &RowBlock, out: &mut ProcessedColumns| {
            fuse_sparse_into(programs, vocabs, block, out);
        });
        Some(FusedStages { stateless, vocab })
    }

    fn observe(&mut self, block: &RowBlock) -> Result<()> {
        let t0 = Instant::now();
        let rows = block.num_rows();
        if self.threads <= 1 || rows < 2 * self.threads {
            self.state.observe(block);
        } else {
            // Sharding is range-slicing of the column-major block: each
            // thread scans its row range of every column slice.
            let parts = partition_rows(rows, self.threads);
            let mut subs: Vec<Vec<HashVocab>> = Vec::with_capacity(parts.len());
            let state = &self.state;
            std::thread::scope(|scope| {
                let handles: Vec<_> = parts
                    .iter()
                    .map(|range| {
                        let range = range.clone();
                        scope.spawn(move || state.observe_sub(block, range))
                    })
                    .collect();
                for h in handles {
                    subs.push(h.join().expect("GV worker panicked"));
                }
            });
            self.state.merge_subs(&subs);
        }
        self.observe_time += t0.elapsed();
        Ok(())
    }

    fn process(&mut self, block: &RowBlock) -> Result<ProcessedColumns> {
        let t0 = Instant::now();
        let out = self.sharded(block, |s, b, r| s.process_range(b, r));
        self.process_time += t0.elapsed();
        Ok(out)
    }

    fn finish(&mut self, stats: &StreamStats) -> Result<ExecutorReport> {
        // Under pipelined driving the engine measures the stage times
        // (this run's closures never see a clock); fold them into the
        // same observe/process split the sequential path times inline.
        self.process_time += stats.stateless_time;
        self.observe_time += stats.vocab_time;
        // Config I round-trips intermediates through (simulated) disk —
        // the same byte volumes the staged baseline charges: SIF writes
        // the sub-files, GV reads them back and writes the partially
        // processed data, AV reads and rewrites it, CFR reads it again
        // (paper §4.2.1). A fused run has one combined GV+AV stage, so
        // the GV→AV intermediate round-trip disappears.
        let disk_sim = if self.kind == ConfigKind::I {
            let raw = stats.raw_bytes as usize;
            let part = stats.rows as usize * self.state.schema().binary_row_bytes();
            let mut d = self.disk.write_cost(raw, self.threads)
                + self.disk.read_cost(raw, self.threads)
                + self.disk.write_cost(part, self.threads)
                + self.disk.read_cost(part, self.threads);
            if !self.fused_gv {
                d += self.disk.write_cost(part, self.threads)
                    + self.disk.read_cost(part, self.threads);
            }
            d
        } else {
            Duration::ZERO
        };
        let (tag, modeled_e2e) = if disk_sim > Duration::ZERO {
            (TimeTag::Mixed, Some(stats.wall + disk_sim))
        } else {
            (TimeTag::Measured, None) // the engine's measured wallclock is the e2e
        };
        Ok(ExecutorReport {
            tag,
            modeled_e2e,
            // GV+AV work actually executed here (Table 3 scope, measured).
            compute: Some(self.observe_time + self.process_time),
            observe_time: self.observe_time,
            process_time: self.process_time,
            vocab_entries: self.state.vocab_entries(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{utf8, SynthConfig, SynthDataset};
    use crate::ops::Modulus;
    use crate::pipeline::{MemorySource, PipelineBuilder};

    #[test]
    fn streaming_cpu_matches_staged_baseline() {
        let ds = SynthDataset::generate(SynthConfig::small(400));
        let raw = utf8::encode_dataset(&ds);
        let m = Modulus::new(997);

        let staged = super::super::run(
            &super::super::BaselineConfig::new(ConfigKind::I, 4, m),
            &raw,
        );

        for chunk_rows in [32usize, 1000] {
            let pipeline = PipelineBuilder::new()
                .spec(crate::ops::PipelineSpec::dlrm(m.range))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(chunk_rows)
                .executor(Box::new(CpuExecutor::new(ConfigKind::I, 4)))
                .build()
                .unwrap();
            let mut source = MemorySource::new(&raw, InputFormat::Utf8);
            let (cols, report) = pipeline.run_collect(&mut source).unwrap();
            assert_eq!(cols, staged.processed, "chunk_rows={chunk_rows}");
            assert_eq!(report.rows, 400);
            // Config I charges the simulated disk round-trips on top of
            // the measured wallclock.
            assert_eq!(report.tag, TimeTag::Mixed);
            assert!(report.e2e > report.wall, "disk sim must be charged");
            assert!(report.compute.unwrap() <= report.wall + Duration::from_millis(50));
        }
    }

    /// The fused strategy must be bit-identical to two-pass, charge a
    /// smaller Config I disk sim (one intermediate round-trip fewer) and
    /// populate the per-stage timing split.
    #[test]
    fn fused_matches_two_pass_and_splits_timing() {
        use crate::pipeline::ExecStrategy;
        let ds = SynthDataset::generate(SynthConfig::small(600));
        let raw = utf8::encode_dataset(&ds);
        let build = |strategy: ExecStrategy| {
            PipelineBuilder::new()
                .spec(crate::ops::PipelineSpec::dlrm(997))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(64)
                .strategy(strategy)
                .executor(Box::new(CpuExecutor::new(ConfigKind::I, 4)))
                .build()
                .unwrap()
        };
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (fused_cols, fused) = build(ExecStrategy::Fused).run_collect(&mut src).unwrap();
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (two_cols, two) = build(ExecStrategy::TwoPass).run_collect(&mut src).unwrap();

        assert_eq!(fused_cols, two_cols, "fused output must be bit-identical");
        assert_eq!(fused.strategy, ExecStrategy::Fused);
        assert_eq!(fused.decode_passes, 1);
        assert_eq!(two.decode_passes, 2);
        // Both strategies separate the vocab stage from the stateless one.
        assert!(fused.observe_time > Duration::ZERO, "fused vocab stage must be timed");
        assert!(fused.process_time > Duration::ZERO);
        assert!(two.observe_time > Duration::ZERO);
        // Fused Config I charges one intermediate disk round-trip fewer.
        let fused_sim = fused.e2e.saturating_sub(fused.wall);
        let two_sim = two.e2e.saturating_sub(two.wall);
        assert!(
            fused_sim < two_sim,
            "fused disk charge {fused_sim:?} must undercut two-pass {two_sim:?}"
        );
    }

    #[test]
    fn config_iii_is_purely_measured() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let raw = crate::data::binary::encode_dataset(&ds);
        let pipeline = PipelineBuilder::new()
            .spec(crate::ops::PipelineSpec::dlrm(499))
            .schema(ds.schema())
            .input(InputFormat::Binary)
            .chunk_rows(64)
            .executor(Box::new(CpuExecutor::new(ConfigKind::III, 2)))
            .build()
            .unwrap();
        let mut source = MemorySource::new(&raw, InputFormat::Binary);
        let (_, report) = pipeline.run_collect(&mut source).unwrap();
        assert_eq!(report.tag, TimeTag::Measured);
        assert_eq!(report.e2e, report.wall, "no sim component outside Config I");
    }

    #[test]
    fn capability_checks_match_paper_table2() {
        let i = CpuExecutor::new(ConfigKind::I, 2);
        let iii = CpuExecutor::new(ConfigKind::III, 2);
        assert!(i.accepts(InputFormat::Utf8) && !i.accepts(InputFormat::Binary));
        assert!(!iii.accepts(InputFormat::Utf8) && iii.accepts(InputFormat::Binary));
    }
}
