//! The four-stage row-partitioned pipeline (paper Fig. 3).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::data::row::ProcessedColumns;
use crate::data::{binary, Schema};
use crate::ops::{log1p, Vocab, VocabSet};

use super::disk::DiskLedger;
use super::{BaselineConfig, ConfigKind};

/// Measured vs simulated split of one stage's time. `measured` really
/// elapsed on this machine; `sim` is charged by the disk model
/// (DESIGN.md §5 — the two are never silently summed in reports).
#[derive(Debug, Default, Clone, Copy)]
pub struct StagePair {
    pub measured: Duration,
    pub sim: Duration,
}

impl StagePair {
    pub fn total(&self) -> Duration {
        self.measured + self.sim
    }
}

/// Per-stage times of one baseline run.
#[derive(Debug, Default, Clone, Copy)]
pub struct StageTimes {
    pub sif: StagePair,
    pub gen_vocab: StagePair,
    pub apply_vocab: StagePair,
    pub concat: StagePair,
}

impl StageTimes {
    pub fn total(&self) -> Duration {
        self.sif.total()
            + self.gen_vocab.total()
            + self.apply_vocab.total()
            + self.concat.total()
    }

    /// GV + AV only — the paper's Table 3 "pure computation" scope.
    pub fn compute(&self) -> Duration {
        self.gen_vocab.total() + self.apply_vocab.total()
    }
}

/// Result of a baseline run.
#[derive(Debug)]
pub struct BaselineRun {
    pub times: StageTimes,
    pub processed: ProcessedColumns,
    pub vocab: VocabSet,
    pub rows: usize,
    pub threads: usize,
    pub disk: DiskLedger,
}

impl BaselineRun {
    /// Rows/second over GV+AV (Table 3 protocol).
    pub fn compute_rows_per_sec(&self) -> f64 {
        crate::report::rows_per_sec(self.rows, self.times.compute())
    }

    /// Rows/second end-to-end.
    pub fn e2e_rows_per_sec(&self) -> f64 {
        crate::report::rows_per_sec(self.rows, self.times.total())
    }
}

/// Per-thread decoded block after GV's scan: column-major, Modulus
/// already applied to sparse values — the "partially processed data"
/// the paper's GV step stores for AV.
#[derive(Debug, Default, Clone)]
pub(crate) struct DecodedBlock {
    pub(crate) labels: Vec<i32>,
    pub(crate) dense: Vec<Vec<i32>>,
    pub(crate) sparse: Vec<Vec<u32>>,
}

impl DecodedBlock {
    fn with_schema(schema: Schema) -> Self {
        DecodedBlock {
            labels: Vec::new(),
            dense: vec![Vec::new(); schema.num_dense],
            sparse: vec![Vec::new(); schema.num_sparse],
        }
    }

    fn rows(&self) -> usize {
        self.labels.len()
    }

    fn byte_size(&self, schema: Schema) -> usize {
        self.rows() * schema.binary_row_bytes()
    }
}

/// Run the baseline over a raw buffer (UTF-8 for Configs I/II, binary for
/// Config III — enforced).
pub fn run(cfg: &BaselineConfig, raw: &[u8]) -> BaselineRun {
    let mut times = StageTimes::default();
    let mut disk = DiskLedger::default();
    let schema = cfg.schema;

    // ---------------- Stage 1: Split Input File -----------------------
    let t0 = Instant::now();
    let partitions: Vec<std::ops::Range<usize>> = if cfg.kind.binary_input() {
        // Binary: row count is file_size / row_bytes (paper §4.2.1,
        // Config III: "we simply obtain the file size and calculate it").
        let rows = binary::count_rows(raw, schema);
        partition_rows(rows, cfg.threads)
            .into_iter()
            .map(|r| r.start * schema.binary_row_bytes()..r.end * schema.binary_row_bytes())
            .collect()
    } else {
        // UTF-8: scan for line boundaries (the costly row count loop).
        let line_starts = line_offsets(raw);
        let rows = line_starts.len();
        partition_rows(rows, cfg.threads)
            .into_iter()
            .map(|r| {
                // Threads beyond the row count get empty byte ranges.
                let start =
                    if r.start < rows { line_starts[r.start] } else { raw.len() };
                let end = if r.end < rows { line_starts[r.end] } else { raw.len() };
                start..end
            })
            .collect()
    };
    if !cfg.pure_compute {
        times.sif.measured = t0.elapsed();
        if cfg.kind == ConfigKind::I {
            // Sub-files written to disk (intermediates).
            times.sif.sim = {
                let before = disk.total;
                disk.charge_write(&cfg.disk, raw.len(), cfg.threads);
                disk.total - before
            };
        }
    }

    // ---------------- Stage 2: Generate Vocabulary --------------------
    let t0 = Instant::now();
    let blocks: Vec<DecodedBlock>;
    let mut vocab = VocabSet::new(schema.num_sparse);

    match cfg.kind {
        ConfigKind::I | ConfigKind::III => {
            // Private sub-dictionaries; merge at the barrier.
            let mut results: Vec<(DecodedBlock, VocabSet)> = Vec::new();
            std::thread::scope(|scope| {
                let handles: Vec<_> = partitions
                    .iter()
                    .map(|range| {
                        let chunk = &raw[range.clone()];
                        scope.spawn(move || {
                            let mut block = DecodedBlock::with_schema(schema);
                            let mut sub = VocabSet::new(schema.num_sparse);
                            if cfg.kind.binary_input() {
                                unpack_binary(chunk, schema, cfg, &mut block);
                            } else {
                                parse_utf8(chunk, schema, cfg, &mut block);
                            }
                            for (col, v) in block.sparse.iter().zip(sub.vocabs.iter_mut()) {
                                v.observe_slice(col);
                            }
                            (block, sub)
                        })
                    })
                    .collect();
                for h in handles {
                    results.push(h.join().expect("GV worker panicked"));
                }
            });
            // The synchronization step: serial merge of sub-dictionaries
            // in thread order (paper §2.3 step 7).
            let subs: Vec<VocabSet> = results.iter().map(|(_, s)| s.clone()).collect();
            vocab.merge_all(&subs);
            blocks = results.into_iter().map(|(b, _)| b).collect();
        }
        ConfigKind::II => {
            // Shared locked dictionary — the design the paper blames for
            // Config II's degradation beyond 32 threads (§4.2.1).
            let shared: Vec<Mutex<crate::ops::HashVocab>> =
                (0..schema.num_sparse).map(|_| Mutex::new(Default::default())).collect();
            let mut results: Vec<DecodedBlock> = Vec::new();
            std::thread::scope(|scope| {
                let shared = &shared;
                let handles: Vec<_> = partitions
                    .iter()
                    .map(|range| {
                        let chunk = &raw[range.clone()];
                        scope.spawn(move || {
                            let mut block = DecodedBlock::with_schema(schema);
                            parse_utf8(chunk, schema, cfg, &mut block);
                            // Row-wise shared-dict updates: lock each
                            // column's dict per row (contention grows
                            // with thread count — the paper's point).
                            let rows = block.rows();
                            for r in 0..rows {
                                for (c, col) in block.sparse.iter().enumerate() {
                                    shared[c].lock().unwrap().observe(col[r]);
                                }
                            }
                            block
                        })
                    })
                    .collect();
                for h in handles {
                    results.push(h.join().expect("GV worker panicked"));
                }
            });
            vocab = VocabSet {
                vocabs: shared.into_iter().map(|m| m.into_inner().unwrap()).collect(),
            };
            blocks = results;
        }
    }
    times.gen_vocab.measured = t0.elapsed();
    if cfg.kind == ConfigKind::I && !cfg.pure_compute {
        // Read sub-files + write partially-processed data.
        let part_bytes: usize = blocks.iter().map(|b| b.byte_size(schema)).sum();
        let before = disk.total;
        disk.charge_read(&cfg.disk, raw.len(), cfg.threads);
        disk.charge_write(&cfg.disk, part_bytes, cfg.threads);
        times.gen_vocab.sim = disk.total - before;
    }

    // ---------------- Stage 3: Apply Vocabulary -----------------------
    let t0 = Instant::now();
    let mut outputs: Vec<ProcessedColumns> = Vec::new();
    std::thread::scope(|scope| {
        let vocab = &vocab;
        let handles: Vec<_> = blocks
            .iter()
            .map(|block| {
                scope.spawn(move || {
                    let mut out = ProcessedColumns::with_schema(schema);
                    out.labels = block.labels.clone();
                    for (c, col) in block.dense.iter().enumerate() {
                        let dst = &mut out.dense[c];
                        dst.reserve(col.len());
                        for &x in col {
                            dst.push(log1p(x)); // Neg2Zero fused into log1p
                        }
                    }
                    for (c, col) in block.sparse.iter().enumerate() {
                        let dst = &mut out.sparse[c];
                        dst.resize(col.len(), 0);
                        vocab.vocabs[c].apply_slice(col, dst);
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            outputs.push(h.join().expect("AV worker panicked"));
        }
    });
    times.apply_vocab.measured = t0.elapsed();
    if cfg.kind == ConfigKind::I && !cfg.pure_compute {
        let part_bytes: usize = blocks.iter().map(|b| b.byte_size(schema)).sum();
        let before = disk.total;
        disk.charge_read(&cfg.disk, part_bytes, cfg.threads);
        disk.charge_write(&cfg.disk, part_bytes, cfg.threads);
        times.apply_vocab.sim = disk.total - before;
    }

    // ---------------- Stage 4: Concatenate Final Results --------------
    let t0 = Instant::now();
    let mut processed = ProcessedColumns::with_schema(schema);
    for out in &outputs {
        processed.extend_from(out);
    }
    if !cfg.pure_compute {
        times.concat.measured = t0.elapsed();
        if cfg.kind == ConfigKind::I {
            // "Dominated by the calls to read each sub-file" (§4.2.1).
            let bytes: usize = blocks.iter().map(|b| b.byte_size(schema)).sum();
            let before = disk.total;
            disk.charge_read(&cfg.disk, bytes, cfg.threads);
            times.concat.sim = disk.total - before;
        } else {
            // In-memory sub-buffers still pay a per-buffer dispatch call
            // (the paper sees CFR grow with threads in Configs II/III
            // too, just smaller). Charged via the same call model at
            // 1/4 the per-call cost, tagged sim.
            times.concat.sim = cfg.disk.per_call / 4 * cfg.threads as u32;
        }
    }

    let rows = processed.num_rows();
    BaselineRun { times, processed, vocab, rows, threads: cfg.threads, disk }
}

/// Split `rows` into `threads` near-equal contiguous ranges.
pub fn partition_rows(rows: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    let threads = threads.max(1);
    let base = rows / threads;
    let extra = rows % threads;
    let mut out = Vec::with_capacity(threads);
    let mut start = 0;
    for t in 0..threads {
        let len = base + usize::from(t < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Byte offsets where each line starts. SIF's costly row-count scan,
/// now a SWAR newline hop (one wide-word compare per 8 bytes instead of
/// one per byte) — SIF is plumbing, not the paper's measured GV/AV
/// compute scope, so speeding it up keeps the baseline faithful.
fn line_offsets(raw: &[u8]) -> Vec<usize> {
    let mut offs = Vec::with_capacity(crate::decode::swar::count_newlines(raw) + 1);
    let mut start = 0usize;
    while start < raw.len() {
        offs.push(start);
        match crate::decode::swar::find_newline(raw, start) {
            Some(nl) => start = nl + 1,
            None => break,
        }
    }
    offs
}

/// Software UTF-8 parse of one chunk: label+dense parsed as decimal,
/// sparse as hex + Modulus, missing → 0. This is the Decode +
/// FillMissing + Hex2Int + Modulus cost the CPU pays per row.
///
/// Manual single-pass byte scan (no field splitting/iterators) — 2.5×
/// faster than the `split`-based version it replaced (§Perf); the
/// field semantics are identical and covered by the agreement tests
/// against the decoder-based backends.
#[allow(unused_assignments)] // macro-generated trailing resets
pub(crate) fn parse_utf8(
    chunk: &[u8],
    schema: Schema,
    cfg: &BaselineConfig,
    block: &mut DecodedBlock,
) {
    let nd = schema.num_dense;
    let ncols = schema.num_columns();
    let mut col = 0usize;
    let mut reg: u32 = 0;
    let mut neg = false;
    let mut row_has_bytes = false;

    macro_rules! finish_field {
        () => {{
            let value = if neg { (reg as i32).wrapping_neg() as u32 } else { reg };
            if col == 0 {
                block.labels.push(value as i32);
            } else if col <= nd {
                block.dense[col - 1].push(value as i32);
            } else if col < ncols {
                block.sparse[col - 1 - nd].push(cfg.modulus.apply(value));
            }
            reg = 0;
            neg = false;
            col += 1;
        }};
    }
    macro_rules! finish_row {
        () => {{
            finish_field!();
            // short rows: fill remaining columns with the default 0
            while col < ncols {
                finish_field!();
            }
            col = 0;
            row_has_bytes = false;
        }};
    }

    for &b in chunk {
        match b {
            b'0'..=b'9' => {
                let d = (b - b'0') as u32;
                reg = if col > nd {
                    (reg << 4) | d
                } else {
                    reg.wrapping_mul(10).wrapping_add(d)
                };
                row_has_bytes = true;
            }
            b'a'..=b'f' => {
                let d = (b - b'a' + 10) as u32;
                reg = if col > nd { (reg << 4) | d } else { reg };
                row_has_bytes = true;
            }
            b'\t' => {
                finish_field!();
                row_has_bytes = true;
            }
            b'\n' => {
                if row_has_bytes {
                    finish_row!();
                }
            }
            b'-' => {
                neg = true;
                row_has_bytes = true;
            }
            _ => {}
        }
    }
    if row_has_bytes {
        finish_row!();
    }
}

/// Config III's "Binary Unpack": split the packed words into tuples
/// (paper Table 4 row 2 — cheaper than Decode but not free).
fn unpack_binary(chunk: &[u8], schema: Schema, cfg: &BaselineConfig, block: &mut DecodedBlock) {
    for row in chunk.chunks_exact(schema.binary_row_bytes()) {
        let word = |i: usize| {
            u32::from_le_bytes([row[4 * i], row[4 * i + 1], row[4 * i + 2], row[4 * i + 3]])
        };
        block.labels.push(word(0) as i32);
        for c in 0..schema.num_dense {
            block.dense[c].push(word(1 + c) as i32);
        }
        for c in 0..schema.num_sparse {
            block.sparse[c].push(cfg.modulus.apply(word(1 + schema.num_dense + c)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::ops::Modulus;

    fn dataset(rows: usize) -> SynthDataset {
        SynthDataset::generate(SynthConfig::small(rows))
    }

    fn run_cfg(kind: ConfigKind, threads: usize, ds: &SynthDataset) -> BaselineRun {
        let cfg = BaselineConfig::new(kind, threads, Modulus::new(997));
        let raw = if kind.binary_input() {
            binary::encode_dataset(ds)
        } else {
            utf8::encode_dataset(ds)
        };
        run(&cfg, &raw)
    }

    #[test]
    fn partition_covers_all_rows() {
        for (rows, threads) in [(10, 3), (0, 4), (7, 7), (100, 1), (5, 8)] {
            let parts = partition_rows(rows, threads);
            assert_eq!(parts.len(), threads.max(1));
            let total: usize = parts.iter().map(|r| r.len()).sum();
            assert_eq!(total, rows);
            // contiguous
            for w in parts.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn single_thread_config_i_processes_all_rows() {
        let ds = dataset(200);
        let run = run_cfg(ConfigKind::I, 1, &ds);
        assert_eq!(run.rows, 200);
        assert!(run.times.sif.sim > Duration::ZERO, "Config I charges disk");
    }

    #[test]
    fn thread_counts_agree_config_i() {
        let ds = dataset(300);
        let a = run_cfg(ConfigKind::I, 1, &ds);
        let b = run_cfg(ConfigKind::I, 7, &ds);
        assert_eq!(a.processed, b.processed, "row partitioning must not change results");
        assert_eq!(a.vocab.total_entries(), b.vocab.total_entries());
    }

    #[test]
    fn binary_and_utf8_paths_agree() {
        let ds = dataset(250);
        let i = run_cfg(ConfigKind::I, 4, &ds);
        let iii = run_cfg(ConfigKind::III, 4, &ds);
        assert_eq!(i.processed, iii.processed, "Config III must match Config I output");
    }

    #[test]
    fn config_ii_output_is_equivalent_up_to_relabeling() {
        // Shared-dict GV assigns indices in nondeterministic order; the
        // *mapping* must still be a bijection consistent with its vocab.
        let ds = dataset(300);
        let i = run_cfg(ConfigKind::I, 4, &ds);
        let ii = run_cfg(ConfigKind::II, 4, &ds);
        assert_eq!(ii.rows, i.rows);
        assert_eq!(ii.vocab.total_entries(), i.vocab.total_entries());
        // dense outputs are deterministic
        assert_eq!(ii.processed.dense, i.processed.dense);
        assert_eq!(ii.processed.labels, i.processed.labels);
        // per-column index sets must be a permutation of config I's
        for c in 0..ii.processed.sparse.len() {
            let mut a: Vec<u32> = i.processed.sparse[c].clone();
            let mut b: Vec<u32> = ii.processed.sparse[c].clone();
            // same multiset size; same number of distinct indices
            a.sort_unstable();
            b.sort_unstable();
            a.dedup();
            b.dedup();
            assert_eq!(a.len(), b.len(), "column {c} distinct index count");
        }
    }

    #[test]
    fn vocab_indices_are_appearance_order() {
        let ds = dataset(150);
        let run = run_cfg(ConfigKind::I, 3, &ds);
        // Recompute expected indices with a sequential scan.
        let m = Modulus::new(997);
        let mut expected = VocabSet::new(ds.schema().num_sparse);
        for row in &ds.rows {
            for (c, &s) in row.sparse.iter().enumerate() {
                expected.vocabs[c].observe(m.apply(s));
            }
        }
        for (c, v) in expected.vocabs.iter().enumerate() {
            for r in 0..ds.num_rows() {
                let want = v.apply(m.apply(ds.rows[r].sparse[c])).unwrap();
                assert_eq!(run.processed.sparse[c][r], want, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn dense_pipeline_neg2zero_log() {
        let ds = dataset(100);
        let run = run_cfg(ConfigKind::I, 2, &ds);
        for r in 0..100 {
            for c in 0..13 {
                let x = ds.rows[r].dense[c];
                let want = crate::ops::log1p(x);
                assert_eq!(run.processed.dense[c][r], want);
            }
        }
    }

    #[test]
    fn pure_compute_skips_sif_cfr() {
        let ds = dataset(100);
        let mut cfg = BaselineConfig::new(ConfigKind::I, 2, Modulus::new(997));
        cfg.pure_compute = true;
        let raw = utf8::encode_dataset(&ds);
        let run = run(&cfg, &raw);
        assert_eq!(run.times.sif.total(), Duration::ZERO);
        assert_eq!(run.times.concat.total(), Duration::ZERO);
        assert!(run.times.compute() > Duration::ZERO);
        assert_eq!(run.times.gen_vocab.sim, Duration::ZERO, "pure compute has no disk");
    }

    #[test]
    fn more_threads_do_not_change_row_order() {
        let ds = dataset(97);
        let a = run_cfg(ConfigKind::III, 1, &ds);
        let b = run_cfg(ConfigKind::III, 13, &ds);
        assert_eq!(a.processed.labels, b.processed.labels);
    }
}
