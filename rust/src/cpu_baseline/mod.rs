//! Meta's CPU preprocessing pipeline — the paper's primary baseline.
//!
//! Row-partitioned multithreading over four sequential stages (paper
//! §2.3):
//!
//! 1. **Split Input File (SIF)** — count rows, partition into per-thread
//!    sub-buffers;
//! 2. **Generate Vocab (GV)** — each thread decodes its rows
//!    (UTF-8 parse + Hex2Int, or Binary Unpack in Config III), applies
//!    Modulus, and builds vocabulary state; threads then synchronize and
//!    the sub-dictionaries are merged (serially — the overhead the paper
//!    targets);
//! 3. **Apply Vocab (AV)** — each thread maps its sparse values through
//!    the unified vocabulary and finishes dense features
//!    (Neg2Zero + Logarithm);
//! 4. **Concatenate Final Results (CFR)** — per-thread outputs are
//!    stitched back into one row-ordered dataset.
//!
//! The three configurations of paper §4.2.1:
//!
//! * **Config I** — intermediate results round-trip through *disk*
//!   (simulated: [`disk::SimDisk`], so results don't depend on this
//!   box's SSD); private per-thread sub-dictionaries, serial merge.
//! * **Config II** — intermediate results stay in memory, but GV uses a
//!   **shared, locked dictionary** (the paper observes Config II's GV/AV
//!   degrade beyond 32 threads and attributes it to shared-dictionary
//!   synchronization — we reproduce that design faithfully).
//! * **Config III** — input is the pre-decoded binary dataset; SIF is a
//!   size division; GV pays Binary Unpack instead of Decode+Hex2Int;
//!   private sub-dictionaries as in Config I, no disk round-trips.
//!
//! This baseline is **measured** (it really runs on this machine's
//! cores), except the Config I disk component which is tagged simulated.

pub mod disk;
pub mod exec;
pub mod pipeline;
pub mod scaling;

pub use disk::SimDisk;
pub use exec::CpuExecutor;
pub use pipeline::{run, BaselineRun, StageTimes};
pub use scaling::{profile_single_thread, project, ServerModel, WorkProfile};

use crate::data::Schema;
use crate::ops::Modulus;

/// Which of the paper's §4.2.1 baseline configurations to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigKind {
    /// UTF-8 input, intermediates to (simulated) disk, private sub-dicts.
    I,
    /// UTF-8 input, intermediates in memory, shared locked dict in GV.
    II,
    /// Binary input, intermediates in memory, private sub-dicts.
    III,
}

impl ConfigKind {
    pub fn name(&self) -> &'static str {
        match self {
            ConfigKind::I => "Config I",
            ConfigKind::II => "Config II",
            ConfigKind::III => "Config III",
        }
    }

    /// Does this config consume the binary (pre-decoded) dataset?
    pub fn binary_input(&self) -> bool {
        matches!(self, ConfigKind::III)
    }
}

/// Full parameterization of one baseline run.
#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub kind: ConfigKind,
    pub threads: usize,
    pub schema: Schema,
    pub modulus: Modulus,
    /// Simulated-disk parameters (only Config I charges them).
    pub disk: SimDisk,
    /// When true, SIF and CFR are skipped and only GV+AV compute is timed
    /// (the paper's Table 3 "pure computation" protocol).
    pub pure_compute: bool,
}

impl BaselineConfig {
    pub fn new(kind: ConfigKind, threads: usize, modulus: Modulus) -> Self {
        BaselineConfig {
            kind,
            threads: threads.max(1),
            schema: Schema::CRITEO,
            modulus,
            disk: SimDisk::default(),
            pure_compute: false,
        }
    }
}
