//! Thread-scaling projection for the CPU baseline.
//!
//! The paper's Fig. 8 / Table 3 curves come from a 128-core EPYC server.
//! This repo may run on far fewer cores (the CI box has one), so the
//! multi-thread points cannot always be *measured*. Instead we measure
//! the single-thread **work components** (parse, vocabulary observe,
//! sub-dictionary merge, apply, concat) on this machine and project them
//! onto a modeled server with the paper's core count — Amdahl plus the
//! three serialization effects the paper identifies:
//!
//! * the **sub-dictionary merge** after GV is serial and its cost grows
//!   with the number of threads (every thread contributes a sub-dict);
//! * Config II's **shared locked dictionary** serializes observe traffic
//!   and degrades beyond ~32 threads;
//! * **Concatenate** is a serial pass whose per-sub-file call cost grows
//!   linearly with thread count.
//!
//! All projected numbers are tagged `sim` by the benches; the T=1 column
//! stays fully measured.

use std::time::{Duration, Instant};

use crate::ops::{HashVocab, VocabSet};

use super::disk::SimDisk;
use super::pipeline::StageTimes;
use super::{BaselineConfig, ConfigKind};

/// Single-thread work components, measured on this machine.
#[derive(Debug, Clone, Copy)]
pub struct WorkProfile {
    /// Rows in the profiled run.
    pub rows: usize,
    /// Raw input bytes.
    pub raw_bytes: usize,
    /// SIF: line scan (UTF-8) or size division (binary).
    pub sif_scan: Duration,
    /// GV: decode/unpack + modulus (embarrassingly parallel).
    pub gv_parse: Duration,
    /// GV: sub-dictionary observe (parallel for I/III, locked for II).
    pub gv_observe: Duration,
    /// GV: merging ONE sub-dictionary into the global one (serial; the
    /// total merge cost is ≈ this × threads).
    pub gv_merge_one: Duration,
    /// AV: vocabulary apply + dense finish (parallel).
    pub av: Duration,
    /// CFR: the in-memory concatenation pass (serial).
    pub cfr_memcpy: Duration,
}

impl WorkProfile {
    /// Scale the row-proportional components to a different row count
    /// (streaming stages scale linearly; `gv_merge_one` is bounded by
    /// the vocabulary size, not the row count, so it stays put).
    pub fn scaled_to(&self, rows: usize) -> WorkProfile {
        let f = rows as f64 / self.rows.max(1) as f64;
        WorkProfile {
            rows,
            raw_bytes: (self.raw_bytes as f64 * f) as usize,
            sif_scan: self.sif_scan.mul_f64(f),
            gv_parse: self.gv_parse.mul_f64(f),
            gv_observe: self.gv_observe.mul_f64(f),
            gv_merge_one: self.gv_merge_one,
            av: self.av.mul_f64(f),
            cfr_memcpy: self.cfr_memcpy.mul_f64(f),
        }
    }
}

/// Measure the work profile with a dedicated single-thread run.
pub fn profile_single_thread(cfg: &BaselineConfig, raw: &[u8]) -> WorkProfile {
    let schema = cfg.schema;

    // SIF
    let t0 = Instant::now();
    let rows = if cfg.kind.binary_input() {
        crate::data::binary::count_rows(raw, schema)
    } else {
        raw.iter().filter(|&&b| b == b'\n').count()
    };
    let sif_scan = t0.elapsed();

    // GV parse (decode + modulus), through the pipeline's own hot loop so
    // the profile measures exactly what the stage costs.
    let t0 = Instant::now();
    let mut block = super::pipeline::DecodedBlock::default();
    block.dense = vec![Vec::with_capacity(rows); schema.num_dense];
    block.sparse = vec![Vec::with_capacity(rows); schema.num_sparse];
    if cfg.kind.binary_input() {
        for row in raw.chunks_exact(schema.binary_row_bytes()) {
            let word = |i: usize| {
                u32::from_le_bytes([row[4 * i], row[4 * i + 1], row[4 * i + 2], row[4 * i + 3]])
            };
            block.labels.push(word(0) as i32);
            for c in 0..schema.num_dense {
                block.dense[c].push(word(1 + c) as i32);
            }
            for c in 0..schema.num_sparse {
                block.sparse[c].push(cfg.modulus.apply(word(1 + schema.num_dense + c)));
            }
        }
    } else {
        super::pipeline::parse_utf8(raw, schema, cfg, &mut block);
    }
    let gv_parse = t0.elapsed();
    let (sparse, dense) = (block.sparse, block.dense);

    // GV observe
    let t0 = Instant::now();
    let mut vocab = VocabSet::new(schema.num_sparse);
    vocab.observe_columns(&sparse);
    let gv_observe = t0.elapsed();

    // GV merge of one sub-dictionary of that size
    let t0 = Instant::now();
    let mut merged: Vec<HashVocab> = (0..schema.num_sparse).map(|_| HashVocab::new()).collect();
    for (dst, src) in merged.iter_mut().zip(&vocab.vocabs) {
        dst.merge_from(src);
    }
    let gv_merge_one = t0.elapsed();

    // AV
    let t0 = Instant::now();
    let applied = vocab.apply_columns(&sparse);
    let mut logs: Vec<Vec<f32>> = Vec::with_capacity(schema.num_dense);
    for col in &dense {
        let mut out = Vec::new();
        crate::ops::dense_finish_slice(col, &mut out);
        logs.push(out);
    }
    let av = t0.elapsed();

    // CFR: one serial concatenation of the column blocks.
    let t0 = Instant::now();
    let mut cat: Vec<u32> = Vec::with_capacity(rows * schema.num_sparse);
    for col in &applied {
        cat.extend_from_slice(col);
    }
    std::hint::black_box(&cat);
    let cfr_memcpy = t0.elapsed();
    std::hint::black_box((&logs, &applied));

    WorkProfile {
        rows,
        raw_bytes: raw.len(),
        sif_scan,
        gv_parse,
        gv_observe,
        gv_merge_one,
        av,
        cfr_memcpy,
    }
}

/// The modeled server (defaults = the paper's two-socket EPYC 7V13).
#[derive(Debug, Clone, Copy)]
pub struct ServerModel {
    /// Physical cores.
    pub cores: usize,
    /// Effective maximum parallel speedup (memory-bandwidth ceiling —
    /// the paper's curves saturate near 48–64×).
    pub max_speedup: f64,
    /// Per-thread spawn/teardown overhead.
    pub spawn: Duration,
    /// Config II lock serialization: fraction of observe work that
    /// serializes per thread (drives the ≥64-thread degradation).
    pub lock_serial_base: f64,
    pub lock_serial_per_thread: f64,
}

impl ServerModel {
    /// The paper's 128-core baseline server.
    pub fn paper_epyc() -> Self {
        ServerModel {
            cores: 128,
            max_speedup: 52.0,
            spawn: Duration::from_micros(80),
            lock_serial_base: 0.25,
            lock_serial_per_thread: 0.012,
        }
    }

    /// Parallel time of `work` over `t` threads on this server.
    fn par(&self, work: Duration, t: usize) -> Duration {
        let speedup = (t.min(self.cores) as f64).min(self.max_speedup).max(1.0);
        work.div_f64(speedup)
    }
}

/// Project the measured profile to `threads` on the modeled server.
pub fn project(
    profile: &WorkProfile,
    kind: ConfigKind,
    threads: usize,
    disk: &SimDisk,
    server: &ServerModel,
    pure_compute: bool,
) -> StageTimes {
    let t = threads.max(1);
    let spawn = server.spawn * t as u32;
    let mut times = StageTimes::default();

    // --- SIF: serial scan; Config I also writes sub-files (one
    //     sequential streaming pass — bandwidth, not calls).
    if !pure_compute {
        times.sif.measured = Duration::ZERO;
        times.sif.sim = profile.sif_scan
            + if kind == ConfigKind::I {
                disk.write_cost(profile.raw_bytes, 1)
            } else {
                Duration::ZERO
            };
    }

    // --- GV
    let parse = server.par(profile.gv_parse, t) + spawn;
    let observe = match kind {
        ConfigKind::II => {
            // shared locked dictionary: parallel floor vs serialized
            // lock traffic that grows with contention
            let serial_frac =
                server.lock_serial_base + server.lock_serial_per_thread * t as f64;
            let locked = profile.gv_observe.mul_f64(serial_frac.max(1.0 / t as f64));
            server.par(profile.gv_observe, t).max(locked)
        }
        _ => server.par(profile.gv_observe, t),
    };
    // serial merge of t sub-dictionaries (Configs I/III only)
    let merge = match kind {
        ConfigKind::II => Duration::ZERO,
        _ => profile.gv_merge_one * t as u32,
    };
    times.gen_vocab.sim = parse + observe + merge;
    if kind == ConfigKind::I && !pure_compute {
        // read sub-files + write partial data: parallel streams — charge
        // bandwidth once plus one call (they overlap across threads).
        let part_bytes = profile.rows * 40 * 4;
        times.gen_vocab.sim += disk.read_cost(profile.raw_bytes, 1).div_f64(
            (t.min(server.cores) as f64).min(4.0), // few parallel disk streams
        ) + disk.write_cost(part_bytes, 1);
    }

    // --- AV: fully parallel
    times.apply_vocab.sim = server.par(profile.av, t) + spawn;
    if kind == ConfigKind::I && !pure_compute {
        let part_bytes = profile.rows * 40 * 4;
        times.apply_vocab.sim +=
            disk.read_cost(part_bytes, 1) + disk.write_cost(part_bytes, 1);
    }

    // --- CFR: serial concat; per-sub-file call cost × t (the paper's
    //     doubling-with-threads effect).
    if !pure_compute {
        times.concat.sim = profile.cfr_memcpy
            + match kind {
                ConfigKind::I => disk.per_call * t as u32,
                _ => disk.per_call / 4 * t as u32,
            };
    }

    times
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, utf8, SynthDataset};
    use crate::ops::Modulus;

    fn profile() -> WorkProfile {
        let ds = SynthDataset::generate(SynthConfig::small(5_000));
        let raw = utf8::encode_dataset(&ds);
        let cfg = BaselineConfig::new(ConfigKind::I, 1, Modulus::VOCAB_5K);
        profile_single_thread(&cfg, &raw)
    }

    #[test]
    fn profile_measures_everything() {
        let p = profile();
        assert_eq!(p.rows, 5_000);
        assert!(p.gv_parse > Duration::ZERO);
        assert!(p.gv_observe > Duration::ZERO);
        assert!(p.av > Duration::ZERO);
    }

    #[test]
    fn compute_scales_then_saturates() {
        // project at paper scale: merge cost is vocab-bound, so it only
        // shows up as saturation once the parallel work has shrunk.
        let p = profile().scaled_to(46_000_000);
        let s = ServerModel::paper_epyc();
        let d = SimDisk::default();
        let t1 = project(&p, ConfigKind::I, 1, &d, &s, true).compute();
        let t32 = project(&p, ConfigKind::I, 32, &d, &s, true).compute();
        let t64 = project(&p, ConfigKind::I, 64, &d, &s, true).compute();
        let t128 = project(&p, ConfigKind::I, 128, &d, &s, true).compute();
        assert!(t32 < t1.div_f64(8.0), "should scale well to 32t");
        // saturation: 64→128 gains little or degrades (merge grows)
        let gain = t64.as_secs_f64() / t128.as_secs_f64();
        assert!(gain < 1.5, "64→128 must saturate, gain {gain}");
    }

    #[test]
    fn config_ii_degrades_at_high_threads() {
        let p = profile();
        let s = ServerModel::paper_epyc();
        let d = SimDisk::default();
        let t16 = project(&p, ConfigKind::II, 16, &d, &s, true).compute();
        let t128 = project(&p, ConfigKind::II, 128, &d, &s, true).compute();
        assert!(
            t128 > t16,
            "shared-dict contention must degrade beyond saturation: 16t {t16:?} vs 128t {t128:?}"
        );
    }

    #[test]
    fn concat_grows_with_threads() {
        let p = profile();
        let s = ServerModel::paper_epyc();
        let d = SimDisk::default();
        let c8 = project(&p, ConfigKind::I, 8, &d, &s, false).concat.total();
        let c64 = project(&p, ConfigKind::I, 64, &d, &s, false).concat.total();
        assert!(c64 > c8 * 4, "CFR should grow ~linearly with sub-file count");
    }

    #[test]
    fn sif_stays_roughly_constant() {
        let p = profile();
        let s = ServerModel::paper_epyc();
        let d = SimDisk::default();
        let s1 = project(&p, ConfigKind::I, 1, &d, &s, false).sif.total();
        let s128 = project(&p, ConfigKind::I, 128, &d, &s, false).sif.total();
        let ratio = s128.as_secs_f64() / s1.as_secs_f64();
        assert!((0.8..1.3).contains(&ratio), "SIF must not grow with threads ({ratio})");
    }
}
