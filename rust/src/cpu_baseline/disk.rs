//! Calibrated simulated disk for Config I's intermediate round-trips.
//!
//! The paper's Config I writes GV's partially-processed data to disk and
//! reads it back in AV, and CFR's cost is "dominated by the calls to read
//! each sub-file rather than the reading process itself" (§4.2.1). Using
//! this box's SSD would make those numbers an artifact of our hardware,
//! so disk time is *simulated* from byte volumes and call counts with
//! fixed parameters (DESIGN.md §6) — and reported tagged `sim`.

use std::time::Duration;

/// Disk timing model: sequential bandwidth + per-call (open/close,
/// syscall, allocator) fixed cost.
#[derive(Debug, Clone, Copy)]
pub struct SimDisk {
    /// Sequential read bandwidth, bytes/second.
    pub read_bps: f64,
    /// Sequential write bandwidth, bytes/second.
    pub write_bps: f64,
    /// Fixed overhead per file operation (open+close+dispatch).
    pub per_call: Duration,
}

impl Default for SimDisk {
    /// A data-center SATA/NFS-class store: 2 GB/s read, 1.5 GB/s write,
    /// 20 ms per file call (matches the paper's observation that CFR time
    /// doubles with sub-file count while SIF stays constant).
    fn default() -> Self {
        SimDisk {
            read_bps: 2.0e9,
            write_bps: 1.5e9,
            per_call: Duration::from_millis(20),
        }
    }
}

impl SimDisk {
    pub fn read_cost(&self, bytes: usize, calls: usize) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.read_bps)
            + self.per_call * calls as u32
    }

    pub fn write_cost(&self, bytes: usize, calls: usize) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.write_bps)
            + self.per_call * calls as u32
    }
}

/// Accumulator of simulated disk time, kept per stage.
#[derive(Debug, Default, Clone)]
pub struct DiskLedger {
    pub total: Duration,
    pub bytes_read: usize,
    pub bytes_written: usize,
    pub calls: usize,
}

impl DiskLedger {
    pub fn charge_read(&mut self, disk: &SimDisk, bytes: usize, calls: usize) {
        self.total += disk.read_cost(bytes, calls);
        self.bytes_read += bytes;
        self.calls += calls;
    }

    pub fn charge_write(&mut self, disk: &SimDisk, bytes: usize, calls: usize) {
        self.total += disk.write_cost(bytes, calls);
        self.bytes_written += bytes;
        self.calls += calls;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_term_scales_linearly() {
        let d = SimDisk::default();
        let one = d.read_cost(1_000_000_000, 0);
        let two = d.read_cost(2_000_000_000, 0);
        assert!((two.as_secs_f64() - 2.0 * one.as_secs_f64()).abs() < 1e-9);
    }

    #[test]
    fn per_call_term_dominates_many_small_files() {
        let d = SimDisk::default();
        // 128 sub-files of 1 KB: call overhead ≫ transfer time.
        let c = d.read_cost(128 * 1024, 128);
        assert!(c >= Duration::from_millis(20) * 128);
        let transfer = Duration::from_secs_f64((128.0 * 1024.0) / d.read_bps);
        assert!(transfer < c / 100);
    }

    #[test]
    fn ledger_accumulates() {
        let d = SimDisk::default();
        let mut l = DiskLedger::default();
        l.charge_write(&d, 1000, 1);
        l.charge_read(&d, 1000, 2);
        assert_eq!(l.calls, 3);
        assert_eq!(l.bytes_read, 1000);
        assert_eq!(l.bytes_written, 1000);
        assert!(l.total > Duration::from_millis(59));
    }
}
