//! The dispatcher's event loop: a work queue of splits, a registry of
//! worker links, and a vocabulary mirror, advanced by session events.
//!
//! Scheduling rules:
//!
//! * at most one split in flight per worker — a split parked behind a
//!   higher sequence number on the same session could deadlock the
//!   owners waiting to fold the lower one, so the FIFO session never
//!   holds more than one;
//! * the lowest queued sequence number dispatches first, to the next
//!   idle worker in rotation (a retried split starts the rotation one
//!   step later, landing on a *different* worker);
//! * a global window bounds splits in flight across the cluster — the
//!   per-job backpressure knob.
//!
//! Failure handling mirrors the old two-pass cluster: every failure
//! event counts a fault, every recovery action a retry; a worker whose
//! session dies is rejoined (its sequencer state survives worker-side),
//! and one that stays gone is struck — ownership of its columns moves
//! to survivors, seeded with the mirror's contiguously-folded prefix,
//! and completed splits at or above the fold point replay so the new
//! owners see every key batch they missed. Replayed work re-derives
//! identical indices (the determinism rule), so duplicate deltas and
//! rows are verified and dropped, never double-counted.

use std::collections::BTreeSet;
use std::io::Write;
use std::ops::Range;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::data::row::ProcessedRow;
use crate::Result;

use crate::net::protocol::{
    self, Job, NetError, RunStats, ServiceHello, SplitAssign, SplitDone, SplitStatus, Tag,
};
use crate::net::JobClock;

use super::merge::Mirror;
use super::registry::{join, Ev, InFlight, JoinError, Link};
use super::router::{assign_owners, moved_columns};
use super::{ServiceConfig, ServiceRun, WorkerStats};

pub(crate) fn run(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    splits: &[Range<usize>],
    expected: &[u64],
    cfg: &ServiceConfig,
    job_id: u64,
) -> Result<ServiceRun> {
    let start = Instant::now();
    anyhow::ensure!(!addrs.is_empty(), "service needs at least one worker");
    anyhow::ensure!(splits.len() == expected.len(), "one expected-row count per split");
    let mut sched = Sched::new(addrs, job, raw, splits, expected, cfg, job_id);
    let result = sched.run();
    sched.teardown(result.is_ok());
    let processed = result?;
    let mut stats = RunStats::default();
    let mut per_worker = Vec::with_capacity(sched.links.len());
    for link in &sched.links {
        stats.merge(&link.stats);
        per_worker.push(WorkerStats {
            addr: link.addr.clone(),
            splits: link.splits_done,
            stats: link.stats.clone(),
        });
    }
    stats.vocab_entries = sched.mirror.entries();
    Ok(ServiceRun {
        processed,
        stats,
        workers: addrs.len(),
        wallclock: start.elapsed(),
        retries: sched.retries,
        faults: sched.faults,
        max_inflight: sched.max_inflight,
        per_worker,
    })
}

struct Sched<'a> {
    job: &'a Job,
    raw: &'a [u8],
    splits: &'a [Range<usize>],
    expected: &'a [u64],
    cfg: &'a ServiceConfig,
    clock: JobClock,
    job_id: u64,
    /// Sparse columns that build a vocabulary — the only ones that get
    /// owners, seeds, and deltas. Empty when the spec does not compile
    /// (the join's `ErrorReply` then carries the real diagnosis).
    gen_cols: Vec<usize>,
    links: Vec<Link>,
    tx: Sender<Ev>,
    rx: Receiver<Ev>,
    queue: BTreeSet<u64>,
    /// Failed attempts per split *this epoch*; an ownership change
    /// resets the budget (those failures blame the topology, not the
    /// split).
    failures: Vec<u32>,
    completed: Vec<Option<Vec<ProcessedRow>>>,
    done_count: usize,
    /// Per-worker row buffer for the split it is streaming back.
    partial: Vec<Vec<ProcessedRow>>,
    mirror: Mirror,
    epoch: u32,
    owners: Vec<u16>,
    window: usize,
    retries: u64,
    faults: u64,
    inflight: usize,
    max_inflight: usize,
}

impl<'a> Sched<'a> {
    fn new(
        addrs: &'a [String],
        job: &'a Job,
        raw: &'a [u8],
        splits: &'a [Range<usize>],
        expected: &'a [u64],
        cfg: &'a ServiceConfig,
        job_id: u64,
    ) -> Sched<'a> {
        let (tx, rx) = std::sync::mpsc::channel();
        let gen_cols = job
            .spec
            .compile(job.schema)
            .map(|p| {
                p.sparse.iter().enumerate().filter(|(_, s)| s.gen_vocab).map(|(c, _)| c).collect()
            })
            .unwrap_or_default();
        Sched {
            job,
            raw,
            splits,
            expected,
            cfg,
            clock: cfg.net.clock(),
            job_id,
            gen_cols,
            links: addrs
                .iter()
                .enumerate()
                .map(|(i, a)| Link::new(a.clone(), i as u16))
                .collect(),
            tx,
            rx,
            queue: (0..splits.len() as u64).collect(),
            failures: vec![0; splits.len()],
            completed: vec![None; splits.len()],
            done_count: 0,
            partial: vec![Vec::new(); addrs.len()],
            mirror: Mirror::new(job.schema.num_sparse),
            epoch: 0,
            owners: Vec::new(),
            window: 0,
            retries: 0,
            faults: 0,
            inflight: 0,
            max_inflight: 0,
        }
    }

    fn hello(&self) -> ServiceHello {
        ServiceHello {
            job_id: self.job_id,
            worker_id: 0, // per-link field, filled at the join site
            epoch: self.epoch,
            owners: self.owners.clone(),
            peers: self.links.iter().map(|l| l.addr.clone()).collect(),
            decode_threads: self.cfg.decode_threads,
            job: self.job.clone(),
        }
    }

    fn live_ids(&self) -> Vec<u16> {
        self.links.iter().filter(|l| l.live()).map(|l| l.id).collect()
    }

    fn run(&mut self) -> Result<crate::data::row::ProcessedColumns> {
        if !self.splits.is_empty() {
            self.join_all()?;
            let live = self.live_ids();
            self.owners = assign_owners(self.job.schema.num_sparse, &live);
            self.window = match self.cfg.window {
                0 => live.len(),
                w => w,
            };
            while self.done_count < self.splits.len() {
                self.clock.check("service scheduling")?;
                self.pump()?;
                self.sweep_deadlines()?;
                if self.done_count == self.splits.len() {
                    break;
                }
                match self.rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(ev) => self.handle(ev)?,
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => unreachable!("scheduler holds a sender"),
                }
            }
        }
        let mut out = crate::data::row::ProcessedColumns::with_schema(self.job.schema);
        for rows in &self.completed {
            for row in rows.as_deref().unwrap_or_default() {
                out.push_row(row);
            }
        }
        Ok(out)
    }

    /// Join every configured worker, sequentially, each with its own
    /// retry budget. A refused connect strikes the worker outright; a
    /// worker-side `ErrorReply` to the hello (the spec failed its
    /// compile) fails the job with that worker's verbatim reason.
    fn join_all(&mut self) -> Result<()> {
        let mut refused: Option<anyhow::Error> = None;
        let mut exhausted: Option<anyhow::Error> = None;
        for w in 0..self.links.len() {
            let mut hello = self.hello();
            hello.worker_id = w as u16;
            let mut attempt = 0u32;
            loop {
                self.clock.check("joining workers")?;
                match join(&mut self.links[w], &hello, &self.cfg.net, &self.clock, &self.tx) {
                    Ok(()) => break,
                    Err(JoinError::Fatal(e)) => return Err(e),
                    Err(JoinError::Refused(e)) => {
                        self.links[w].struck = true;
                        refused = Some(e);
                        break;
                    }
                    Err(JoinError::Retryable(e)) => {
                        self.faults += 1;
                        if attempt >= self.cfg.net.retries {
                            self.links[w].struck = true;
                            exhausted = Some(e);
                            break;
                        }
                        attempt += 1;
                        self.retries += 1;
                        self.clock.sleep(self.cfg.net.backoff_for(attempt));
                    }
                }
            }
        }
        if self.live_ids().is_empty() {
            return Err(match (exhausted, refused) {
                (Some(e), _) => e.context("worker join: retries exhausted"),
                (None, Some(e)) => e.context(anyhow::Error::new(NetError::PeerGone {
                    what: "no surviving workers for the service job".into(),
                })),
                (None, None) => anyhow::Error::new(NetError::PeerGone {
                    what: "no surviving workers for the service job".into(),
                }),
            });
        }
        Ok(())
    }

    /// Assign queued splits (lowest seq first) to idle live workers,
    /// up to the window.
    fn pump(&mut self) -> Result<()> {
        loop {
            if self.inflight >= self.window.max(1) {
                return Ok(());
            }
            let Some(&seq) = self.queue.iter().next() else { return Ok(()) };
            let n = self.links.len();
            let start = (seq as usize + self.failures[seq as usize] as usize) % n;
            let Some(w) = (0..n)
                .map(|k| (start + k) % n)
                .find(|&w| self.links[w].live() && self.links[w].current.is_none())
            else {
                return Ok(());
            };
            self.queue.remove(&seq);
            self.dispatch(w, seq)?;
        }
    }

    /// Stream one split to one worker: assignment metadata, then the
    /// raw bytes as fused chunks (the worker decodes as they arrive).
    fn dispatch(&mut self, w: usize, seq: u64) -> Result<()> {
        self.links[w].current = Some(InFlight { seq, epoch: self.epoch, deadline: None });
        self.partial[w].clear();
        self.inflight += 1;
        self.max_inflight = self.max_inflight.max(self.inflight);
        let assign = SplitAssign {
            seq,
            epoch: self.epoch,
            expected_rows: self.expected[seq as usize],
            owners: self.owners.clone(),
        };
        let bytes = &self.raw[self.splits[seq as usize].clone()];
        let chunk = self.cfg.chunk_bytes.max(1);
        let sent = (|| -> Result<()> {
            let writer = self.links[w].writer.as_mut().expect("live worker has a writer");
            protocol::write_frame(writer, Tag::SplitAssign, &assign.encode())?;
            for part in bytes.chunks(chunk) {
                self.clock.check("streaming a split")?;
                protocol::write_frame(writer, Tag::FusedChunk, part)?;
            }
            protocol::write_frame(writer, Tag::FusedEnd, &[])?;
            writer.flush()?;
            Ok(())
        })();
        match sent {
            Ok(()) => {
                // Armed only once the split is fully streamed: from here
                // the worker owes results within 2x the I/O timeout
                // (decode overlaps the stream; what remains is the tail
                // of the pass and the key exchange, each of which is
                // itself bounded by the I/O timeout).
                if let Some(inf) = self.links[w].current.as_mut() {
                    inf.deadline = self.cfg.net.io_timeout.map(|io| Instant::now() + 2 * io);
                }
                Ok(())
            }
            Err(e) => {
                let gen = self.links[w].gen;
                self.down(w, gen, format!("{e:#}"))
            }
        }
    }

    fn handle(&mut self, ev: Ev) -> Result<()> {
        match ev {
            Ev::Delta { w, gen, delta } => {
                if self.links[w].gen == gen {
                    self.mirror.fold(delta)?;
                }
                Ok(())
            }
            Ev::Rows { w, gen, payload } => {
                if self.links[w].gen != gen {
                    return Ok(());
                }
                let (seq, rows) = protocol::unpack_service_rows(&payload, self.job.schema)?;
                if self.links[w].current.as_ref().is_some_and(|inf| inf.seq == seq) {
                    self.partial[w].extend(rows);
                }
                Ok(())
            }
            Ev::Done { w, gen, done } => {
                if self.links[w].gen == gen {
                    self.done(w, done)?;
                }
                Ok(())
            }
            Ev::Down { w, gen, what } => self.down(w, gen, what),
        }
    }

    fn done(&mut self, w: usize, done: SplitDone) -> Result<()> {
        let seq = done.seq;
        if !self.links[w].current.as_ref().is_some_and(|inf| inf.seq == seq) {
            return Ok(()); // not the split this worker owes — ignore
        }
        let inf = self.links[w].current.take().expect("checked above");
        self.inflight -= 1;
        let rows = std::mem::take(&mut self.partial[w]);
        if self.completed[seq as usize].is_some() {
            return Ok(()); // a re-dispatch raced it; first completion won
        }
        if inf.epoch != self.epoch {
            // Dispatched under a stale owner table: its key batches were
            // routed to the *old* owners, so a moved column's new owner
            // never folded them — accepting this completion would stall
            // the new owner's sequencer forever. Redo the split under
            // the current table (its deltas, if any, verified as
            // duplicates against the mirror; the redo re-derives
            // identical indices).
            self.queue.insert(seq);
            return Ok(());
        }
        match done.status {
            SplitStatus::Ok(stats) => {
                let accounted = stats.rows + stats.rows_skipped + stats.rows_quarantined;
                let complete = rows.len() as u64 == stats.rows
                    && accounted == self.expected[seq as usize]
                    && self.gen_cols.iter().all(|&c| self.mirror.has(c, seq));
                if !complete {
                    self.faults += 1;
                    let what = format!(
                        "worker {} returned {} rows (reported {} emitted + {} skipped + {} \
                         quarantined) of a {}-row split — frames were lost",
                        self.links[w].addr,
                        rows.len(),
                        stats.rows,
                        stats.rows_skipped,
                        stats.rows_quarantined,
                        self.expected[seq as usize]
                    );
                    self.fail_split(seq, anyhow::Error::new(NetError::Malformed { what }))?;
                    // The retry must not ride the same wire: a session
                    // that lost frames once is suspect, so rejoin before
                    // giving this worker more work.
                    let gen = self.links[w].gen;
                    return self.down(w, gen, "session lost result frames".into());
                }
                self.links[w].splits_done += 1;
                self.links[w].stats.merge(&stats);
                self.completed[seq as usize] = Some(rows);
                self.done_count += 1;
                Ok(())
            }
            SplitStatus::Failed(reason) => {
                self.faults += 1;
                let err = anyhow::Error::new(NetError::JobFailed {
                    worker: self.links[w].addr.clone(),
                    reason: reason.clone(),
                });
                self.fail_split(seq, err)?;
                // Same posture as a lost-frame split: the fault may live
                // in either half of this session's wire, so the retry
                // goes out on a fresh one.
                let gen = self.links[w].gen;
                self.down(w, gen, format!("split {seq} failed on the worker: {reason}"))
            }
        }
    }

    /// Count a failed attempt against the split's per-epoch budget and
    /// requeue it, or fail the job when the budget is spent.
    fn fail_split(&mut self, seq: u64, err: anyhow::Error) -> Result<()> {
        self.failures[seq as usize] += 1;
        if self.failures[seq as usize] > self.cfg.net.retries {
            if matches!(NetError::of(&err), Some(NetError::JobFailed { .. })) {
                return Err(err);
            }
            return Err(err.context(format!("split {seq}: retries exhausted")));
        }
        self.retries += 1;
        self.queue.insert(seq);
        Ok(())
    }

    /// A worker's session died (reader event or send-side error).
    /// Requeue whatever it owed, then rejoin it — or strike it and
    /// move its columns if it stays gone.
    fn down(&mut self, w: usize, gen: u64, what: String) -> Result<()> {
        if self.links[w].gen != gen || self.links[w].struck {
            return Ok(()); // stale session noise
        }
        if self.done_count == self.splits.len() {
            return Ok(()); // job already complete; teardown will close
        }
        self.faults += 1;
        self.links[w].gen += 1; // invalidate anything else this session says
        self.links[w].close();
        self.partial[w].clear();
        if let Some(inf) = self.links[w].current.take() {
            self.inflight -= 1;
            if self.completed[inf.seq as usize].is_none() {
                let err = anyhow::Error::new(NetError::PeerGone {
                    what: format!("worker {} session died: {what}", self.links[w].addr),
                });
                self.fail_split(inf.seq, err)?;
            }
        }
        self.rejoin(w)
    }

    fn rejoin(&mut self, w: usize) -> Result<()> {
        let mut hello = self.hello();
        hello.worker_id = w as u16;
        for attempt in 0..=self.cfg.net.retries {
            self.clock.check("rejoining a worker")?;
            if attempt > 0 {
                self.clock.sleep(self.cfg.net.backoff_for(attempt));
            }
            match join(&mut self.links[w], &hello, &self.cfg.net, &self.clock, &self.tx) {
                Ok(()) => {
                    self.retries += 1;
                    return Ok(());
                }
                Err(JoinError::Refused(_) | JoinError::Fatal(_)) => break,
                Err(JoinError::Retryable(_)) => {
                    self.faults += 1;
                    self.retries += 1;
                }
            }
        }
        self.strike(w)
    }

    /// Remove a worker from the rotation for good and transfer its
    /// column ownership: bump the epoch, reassign owners over the
    /// survivors, seed each moved column's new owner with the mirror's
    /// folded prefix, and replay completed splits at or above the
    /// lowest moved fold point so new owners see every key batch they
    /// missed.
    fn strike(&mut self, w: usize) -> Result<()> {
        self.links[w].struck = true;
        self.links[w].close();
        let live = self.live_ids();
        if live.is_empty() {
            anyhow::bail!(NetError::PeerGone {
                what: "no surviving workers for the service job".into(),
            });
        }
        let new_owners = assign_owners(self.job.schema.num_sparse, &live);
        let moved = moved_columns(&self.owners, &new_owners);
        self.owners = new_owners;
        let moved_gen: Vec<usize> =
            moved.into_iter().filter(|c| self.gen_cols.contains(c)).collect();
        if moved_gen.is_empty() {
            // No vocabulary column changed hands, so the old routing
            // table is still valid — in-flight splits stay acceptable
            // and the epoch (which stamps them) need not move.
            return Ok(());
        }
        self.epoch += 1;
        self.failures.iter_mut().for_each(|f| *f = 0);
        let mut min_watermark = u64::MAX;
        for &col in &moved_gen {
            let (next, keys) = self.mirror.seed_for(col);
            min_watermark = min_watermark.min(next);
            loop {
                let owner = self.owners[col] as usize;
                let seed =
                    protocol::OwnerSeed { col: col as u16, next_seq: next, keys: keys.clone() };
                let sent = (|| -> Result<()> {
                    let writer =
                        self.links[owner].writer.as_mut().expect("live owner has a writer");
                    protocol::write_frame(writer, Tag::OwnerSeed, &seed.encode())?;
                    writer.flush()?;
                    Ok(())
                })();
                match sent {
                    Ok(()) => break,
                    Err(e) => {
                        let gen = self.links[owner].gen;
                        self.down(owner, gen, format!("seeding column {col}: {e:#}"))?;
                        if self.owners[col] as usize != owner {
                            break; // re-struck recursively; the nested strike re-seeded it
                        }
                        // Same owner on a fresh session (the rejoin
                        // succeeded): the seed never arrived — resend.
                    }
                }
            }
        }
        // Replay completed splits the new owners never folded.
        for seq in min_watermark..self.splits.len() as u64 {
            if self.completed[seq as usize].take().is_some() {
                self.done_count -= 1;
                self.queue.insert(seq);
            }
        }
        Ok(())
    }

    /// Liveness backstop for a worker that keeps its socket open but
    /// stops progressing (joined sessions read with no timeout): a
    /// worker that blows its split deadline has the session torn down,
    /// which requeues the split and rejoins — or strikes — the worker.
    fn sweep_deadlines(&mut self) -> Result<()> {
        let now = Instant::now();
        for w in 0..self.links.len() {
            let expired = self.links[w]
                .current
                .as_ref()
                .and_then(|inf| inf.deadline)
                .is_some_and(|d| now >= d);
            if !expired {
                continue;
            }
            let seq = self.links[w].current.as_ref().expect("checked above").seq;
            let gen = self.links[w].gen;
            self.down(w, gen, format!("split {seq} passed its dispatch deadline"))?;
        }
        Ok(())
    }

    /// Close every link; on a clean finish, send the end-of-job marker
    /// first so workers deregister their job state.
    fn teardown(&mut self, clean: bool) {
        for link in &mut self.links {
            if clean && link.live() {
                if let Some(writer) = link.writer.as_mut() {
                    let _ = protocol::write_frame(
                        writer,
                        Tag::SplitDone,
                        &SplitDone::end_marker().encode(),
                    );
                    let _ = writer.flush();
                }
            }
            link.close();
        }
    }
}
