//! Disaggregated preprocessing service with shard-owned vocabularies.
//!
//! The two-pass cluster ([`crate::net::cluster`]) pays a global
//! barrier: no worker emits a row until *every* worker has observed
//! its whole shard, because the vocabulary merge sits between the
//! passes. This subsystem removes the barrier by making vocabulary
//! state *owned*: each vocabulary column is assigned to exactly one
//! worker by hash partition ([`router`]), and index assignment happens
//! at the owner as key batches arrive — ordered by split sequence
//! number, so the assignment is bit-identical to a single sequential
//! scan no matter how splits interleave across the cluster.
//!
//! ```text
//!            dispatcher (scheduler + registry + mirror)
//!           /      |       \            split queue, join/strike,
//!   splits /       |        \ splits    vocab mirror + seeds
//!         v        v         v
//!      worker0   worker1   worker2      fused single-pass decode
//!         \      ^   |      ^           per split; owners fold key
//!          \____/    |_____/            batches -> global indices
//!        key batches / index batches    (worker-to-worker, no barrier)
//! ```
//!
//! Every worker runs the whole fused pipeline on each split it is
//! assigned; for a vocabulary column it does not own it forwards the
//! split's unique keys (appearance-ordered, one batch per column) to
//! the owner and rewrites its rows with the returned global indices.
//! The dispatcher never relays vocabulary traffic — it only mirrors
//! the owners' delta stream ([`merge`]) so it can seed a replacement
//! owner after a worker is struck.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::data::row::ProcessedColumns;
use crate::net::protocol::{Job, RunStats};
use crate::net::NetConfig;
use crate::Result;

pub(crate) mod merge;
pub(crate) mod registry;
pub(crate) mod router;
mod scheduler;
pub(crate) mod session;

/// Knobs for a service run. `Default` matches the cluster defaults:
/// 30 s I/O deadline, 2 retries per split, no job deadline.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Transport and fault-tolerance knobs (shared with the two-pass
    /// cluster path).
    pub net: NetConfig,
    /// Maximum splits in flight across the cluster (per-job
    /// backpressure). `0` = one per live worker.
    pub window: usize,
    /// Decode threads per worker split; `0` = the worker's default.
    pub decode_threads: u16,
    /// Bytes per data frame when streaming a split.
    pub chunk_bytes: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            net: NetConfig::default(),
            window: 0,
            decode_threads: 0,
            chunk_bytes: 64 << 10,
        }
    }
}

/// Per-worker contribution to a service run.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub addr: String,
    /// Splits whose completion this worker won.
    pub splits: u64,
    /// Merged stats over those splits, including the per-stage
    /// decode/stateless/vocab nanosecond breakdown.
    pub stats: RunStats,
}

/// Result of a service run.
#[derive(Debug)]
pub struct ServiceRun {
    pub processed: ProcessedColumns,
    /// Totals across accepted splits; `vocab_entries` comes from the
    /// dispatcher's mirror (authoritative — split-local counts would
    /// double-count keys shared between splits).
    pub stats: RunStats,
    pub workers: usize,
    pub wallclock: Duration,
    /// Recovery actions performed (0 on a clean run).
    pub retries: u64,
    /// Failure events observed (0 on a clean run).
    pub faults: u64,
    /// High-water mark of splits concurrently in flight — bounded by
    /// [`ServiceConfig::window`].
    pub max_inflight: usize,
    pub per_worker: Vec<WorkerStats>,
}

/// A process-unique job id: worker-side state is keyed by it, so
/// concurrent jobs from one dispatcher (or several dispatchers that
/// happen to share a worker pool) never collide.
fn next_job_id() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let c = COUNTER.fetch_add(1, Ordering::Relaxed);
    ((std::process::id() as u64) << 32) ^ c
}

/// Run `job` over `raw` against the `addrs` worker pool, one fused
/// single-pass scan per split, with the default [`ServiceConfig`].
pub fn run_service(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    splits: &[Range<usize>],
) -> Result<ServiceRun> {
    run_service_cfg(addrs, job, raw, splits, &ServiceConfig::default())
}

/// Run `job` over `raw` against the `addrs` worker pool.
///
/// `splits` are byte ranges of `raw` on row boundaries (see
/// [`crate::net::cluster::shard_rows`]); their order defines the
/// global vocabulary order and the output row order, both bit-identical
/// to a single sequential scan over `raw`.
pub fn run_service_cfg(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    splits: &[Range<usize>],
    cfg: &ServiceConfig,
) -> Result<ServiceRun> {
    let binary = matches!(job.format, crate::net::stream::WireFormat::Binary);
    let expected: Vec<u64> = splits
        .iter()
        .map(|s| crate::net::cluster::expected_rows(&raw[s.clone()], job.schema, binary))
        .collect();
    scheduler::run(addrs, job, raw, splits, &expected, cfg, next_job_id())
}

/// Spawn `n` loopback workers, run a service job against them (one
/// split per worker by default), and shut the pool down.
pub fn run_service_loopback(
    n: usize,
    job: &Job,
    raw: &[u8],
    cfg: &ServiceConfig,
) -> Result<ServiceRun> {
    let binary = matches!(job.format, crate::net::stream::WireFormat::Binary);
    let splits = crate::net::cluster::shard_rows(raw, job.schema, binary, n.max(1));
    let mut addrs = Vec::new();
    let mut shutdowns = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n.max(1) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        let shutdown = crate::net::worker::ShutdownHandle::new(&listener)?;
        shutdowns.push(shutdown.clone());
        handles.push(std::thread::spawn(move || {
            crate::net::worker::serve_until(
                &listener,
                &shutdown,
                &crate::net::worker::WorkerOptions::default(),
            )
        }));
    }
    let run = run_service_cfg(&addrs, job, raw, &splits, cfg);
    for s in &shutdowns {
        s.shutdown();
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    }
    run
}
