//! Worker-side service state: per-job column sequencers and the two
//! session handlers ([`dispatch_session`] for the dispatcher link,
//! [`key_session`] for peer key-forwarding links).
//!
//! The registry is process-global, keyed by `(job_id, worker_id)`, so
//! concurrent jobs multiplex one worker pool and a key session that
//! races the dispatch hello can wait briefly for the job to appear.
//! State survives an abnormal dispatch-session end on purpose — a
//! dispatcher that reconnects after a transient fault finds its column
//! sequencers (and therefore its index assignments) intact. Only the
//! clean end-of-job marker deregisters; a job whose dispatcher vanishes
//! for good leaks its (small) vocabulary state until process exit —
//! the accepted cost of crash-safe rejoin.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::data::row::ProcessedRow;
use crate::ops::{HashVocab, Vocab};
use crate::pipeline::{ChunkState, VocabSlot};
use crate::Result;

use crate::net::protocol::{
    self, IndexBatch, KeyBatch, KeyHello, NetError, RunStats, ServiceHello, ServiceOpen,
    SplitAssign, SplitDone, SplitStatus, Tag, VocabDelta,
};
use crate::net::worker::WorkerOptions;
use crate::net::JobClock;

/// Rows per service-path ResultChunk frame.
const RESULT_ROWS_PER_FRAME: usize = 8192;

/// One column's global index sequencer on its owning worker. Batches
/// carry the split sequence number; `submit` blocks until every lower
/// seq has been folded, so indices depend only on `(seq, in-split
/// appearance)` — the determinism rule that makes the disaggregated
/// run bit-identical to the single-node fused scan.
pub(crate) struct ColSeq {
    m: Mutex<SeqState>,
    cv: Condvar,
}

struct SeqState {
    vocab: HashVocab,
    next_seq: u64,
}

impl ColSeq {
    fn new() -> ColSeq {
        ColSeq { m: Mutex::new(SeqState { vocab: HashVocab::new(), next_seq: 0 }), cv: Condvar::new() }
    }

    /// Fold one split's appearance-ordered keys, returning their global
    /// indices. A batch below the fold point is a replay (re-dispatched
    /// split): apply-only, and every key must already be present —
    /// determinism guarantees the first fold saw the same keys.
    pub(crate) fn submit(&self, seq: u64, keys: &[u32], wait: Duration) -> Result<Vec<u32>> {
        let deadline = Instant::now() + wait;
        let mut g = self.m.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if seq < g.next_seq {
                return keys
                    .iter()
                    .map(|&k| {
                        g.vocab.apply(k).ok_or_else(|| {
                            anyhow::Error::new(NetError::Malformed {
                                what: format!("replayed key batch (seq {seq}) has an unknown key"),
                            })
                        })
                    })
                    .collect();
            }
            if seq == g.next_seq {
                let out = keys.iter().map(|&k| g.vocab.observe_apply(k)).collect();
                g.next_seq += 1;
                self.cv.notify_all();
                return Ok(out);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                anyhow::bail!(NetError::Timeout {
                    what: format!(
                        "column sequencer stalled: waiting for split {} to fold split {seq}",
                        g.next_seq
                    ),
                });
            }
            let (g2, _) = self.cv.wait_timeout(g, left).unwrap_or_else(|e| e.into_inner());
            g = g2;
        }
    }

    /// Seed the fold after an ownership transfer: adopt the mirror's
    /// contiguously-folded prefix if (and only if) it is ahead of the
    /// local fold. Behind-or-equal seeds are ignored — the local state
    /// already *is* that fold (determinism), possibly further along.
    pub(crate) fn seed(&self, next_seq: u64, keys: &[u32]) {
        let mut g = self.m.lock().unwrap_or_else(|e| e.into_inner());
        if next_seq > g.next_seq {
            let mut vocab = HashVocab::with_capacity(keys.len());
            for &k in keys {
                vocab.observe(k);
            }
            g.vocab = vocab;
            g.next_seq = next_seq;
            self.cv.notify_all();
        }
    }
}

/// Per-job worker state: the hello that created it plus the lazily-
/// created column sequencers (only columns this worker owns get one).
pub(crate) struct JobState {
    seqs: Mutex<HashMap<u16, Arc<ColSeq>>>,
}

impl JobState {
    pub(crate) fn seq(&self, col: u16) -> Arc<ColSeq> {
        let mut g = self.seqs.lock().unwrap_or_else(|e| e.into_inner());
        g.entry(col).or_insert_with(|| Arc::new(ColSeq::new())).clone()
    }
}

type Registry = Mutex<HashMap<(u64, u16), Arc<JobState>>>;

fn registry() -> &'static Registry {
    static JOBS: OnceLock<Registry> = OnceLock::new();
    JOBS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Get-or-create the job state — reuse on a dispatcher rejoin keeps
/// the column sequencers (and their index assignments) intact.
fn register(job_id: u64, worker_id: u16) -> Arc<JobState> {
    let mut g = registry().lock().unwrap_or_else(|e| e.into_inner());
    g.entry((job_id, worker_id))
        .or_insert_with(|| Arc::new(JobState { seqs: Mutex::new(HashMap::new()) }))
        .clone()
}

fn deregister(job_id: u64, worker_id: u16) {
    let mut g = registry().lock().unwrap_or_else(|e| e.into_inner());
    g.remove(&(job_id, worker_id));
}

/// Look a job up, polling briefly — a peer's key session can race the
/// dispatch hello that registers the job.
fn lookup_wait(job_id: u64, worker_id: u16, wait: Duration) -> Result<Arc<JobState>> {
    let deadline = Instant::now() + wait;
    loop {
        {
            let g = registry().lock().unwrap_or_else(|e| e.into_inner());
            if let Some(state) = g.get(&(job_id, worker_id)) {
                return Ok(state.clone());
            }
        }
        if Instant::now() >= deadline {
            anyhow::bail!(NetError::Malformed {
                what: format!("key session for unknown job {job_id:#x} on worker {worker_id}"),
            });
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// An open key-forwarding connection to one column owner.
struct KeyClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl KeyClient {
    fn open(addr: &str, hello: KeyHello, io: Option<Duration>) -> Result<KeyClient> {
        let stream = crate::net::connect(addr, io, &JobClock::unbounded())?;
        let mut writer = BufWriter::with_capacity(1 << 16, stream.try_clone()?);
        let mut reader = BufReader::with_capacity(1 << 16, stream);
        protocol::write_frame(
            &mut writer,
            Tag::ServiceHello,
            &ServiceOpen::Keys(hello).encode(),
        )?;
        writer.flush()?;
        let (tag, payload) = protocol::read_frame(&mut reader)?;
        match tag {
            Tag::ServiceHello => match ServiceOpen::decode(&payload)? {
                ServiceOpen::Ack { .. } => Ok(KeyClient { reader, writer }),
                other => anyhow::bail!(NetError::Malformed {
                    what: format!("key session expected an ack, got {other:?}"),
                }),
            },
            Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                worker: addr.to_string(),
                reason: String::from_utf8_lossy(&payload).into_owned(),
            }),
            other => anyhow::bail!(NetError::Malformed {
                what: format!("key session expected an ack frame, got {other:?}"),
            }),
        }
    }
}

/// The split currently streaming in on a dispatch session.
struct ActiveSplit {
    assign: SplitAssign,
    sp: crate::net::StreamingPreprocessor,
    rows: Vec<ProcessedRow>,
    /// First error while feeding chunks; the rest of the split's frames
    /// are drained so the session stays usable, then the failure is
    /// reported in `SplitDone`.
    failed: Option<String>,
}

/// Run a worker's dispatch session: accept split assignments, process
/// each split single-pass fused with split-local vocabularies, resolve
/// global indices (locally for owned columns, via key forwarding for
/// remote ones), and stream deltas + rows + status back. Returns the
/// aggregate stats across completed splits.
pub(crate) fn dispatch_session<R, W>(
    reader: &mut R,
    writer: &mut W,
    hello: ServiceHello,
    opts: &WorkerOptions,
) -> Result<RunStats>
where
    R: Read,
    W: Write,
{
    // Worker-side planning: compile the spec before acking, so a bad
    // job fails the join with an ErrorReply, not a mid-split surprise.
    let programs = hello.job.spec.compile(hello.job.schema)?;
    let threads = match hello.decode_threads {
        0 => crate::decode::shard::default_threads(),
        t => t as usize,
    };
    let decode = crate::pipeline::DecodeOptions { threads, swar: true, errors: hello.job.errors };
    let state = register(hello.job_id, hello.worker_id);
    protocol::write_frame(
        writer,
        Tag::ServiceHello,
        &ServiceOpen::Ack { worker_id: hello.worker_id }.encode(),
    )?;
    writer.flush()?;

    let io = opts.io_timeout.unwrap_or(Duration::from_secs(30));
    let route = ChunkState::with_programs(programs);
    let mut clients: HashMap<u16, KeyClient> = HashMap::new();
    let mut current: Option<ActiveSplit> = None;
    let mut agg = RunStats::default();

    loop {
        let (tag, payload) = protocol::read_frame(reader)?;
        match tag {
            Tag::SplitAssign => {
                anyhow::ensure!(current.is_none(), "split assigned while another is streaming");
                let assign = SplitAssign::decode(&payload)?;
                anyhow::ensure!(
                    assign.owners.len() == hello.job.schema.num_sparse,
                    "owner table has {} columns, schema wants {}",
                    assign.owners.len(),
                    hello.job.schema.num_sparse
                );
                let sp = crate::net::StreamingPreprocessor::with_decode_options(
                    &hello.job.spec,
                    hello.job.schema,
                    hello.job.format,
                    decode,
                )?;
                current = Some(ActiveSplit { assign, sp, rows: Vec::new(), failed: None });
            }
            Tag::FusedChunk => {
                let split = current
                    .as_mut()
                    .ok_or_else(|| NetError::Malformed { what: "chunk without a split".into() })?;
                if split.failed.is_none() {
                    match split.sp.fused_chunk(&payload) {
                        Ok(rows) => split.rows.extend(rows),
                        Err(e) => split.failed = Some(format!("{e:#}")),
                    }
                }
            }
            Tag::FusedEnd => {
                let mut split = current
                    .take()
                    .ok_or_else(|| NetError::Malformed { what: "end without a split".into() })?;
                let seq = split.assign.seq;
                let status = match split.failed.take() {
                    Some(reason) => SplitStatus::Failed(reason),
                    None => match finish_split(
                        &mut split, &route, &state, &hello, &mut clients, writer, io,
                    ) {
                        Ok(stats) => {
                            agg.merge(&stats);
                            SplitStatus::Ok(stats)
                        }
                        Err(e) => {
                            // A failed split may have sent key batches
                            // whose replies were never read; those would
                            // surface as stale frames on the next split.
                            // Drop every key client — reconnect clean.
                            clients.clear();
                            SplitStatus::Failed(format!("{e:#}"))
                        }
                    },
                };
                protocol::write_frame(
                    writer,
                    Tag::SplitDone,
                    &SplitDone { seq, status }.encode(),
                )?;
                writer.flush()?;
            }
            Tag::OwnerSeed => {
                let seed = protocol::OwnerSeed::decode(&payload)?;
                state.seq(seed.col).seed(seed.next_seq, &seed.keys);
            }
            Tag::SplitDone => {
                let done = SplitDone::decode(&payload)?;
                anyhow::ensure!(
                    done.seq == SplitDone::END,
                    "unexpected SplitDone (seq {}) from the dispatcher",
                    done.seq
                );
                deregister(hello.job_id, hello.worker_id);
                return Ok(agg);
            }
            Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                worker: "dispatcher".into(),
                reason: String::from_utf8_lossy(&payload).into_owned(),
            }),
            other => anyhow::bail!(NetError::Malformed {
                what: format!("unexpected frame {other:?} on a dispatch session"),
            }),
        }
    }
}

/// Complete one split: flush the decoder, resolve every vocabulary
/// column's global indices, rewrite the rows, and stream deltas + rows
/// back. Key batches for every remote owner go out *before* any
/// blocking wait (local fold or reply read), so wait-for edges only
/// point at lower split seqs — the no-deadlock invariant.
#[allow(clippy::too_many_arguments)]
fn finish_split<W: Write>(
    split: &mut ActiveSplit,
    route: &ChunkState,
    state: &JobState,
    hello: &ServiceHello,
    clients: &mut HashMap<u16, KeyClient>,
    writer: &mut W,
    io: Duration,
) -> Result<RunStats> {
    let trailing = split.sp.fused_end()?;
    split.rows.extend(trailing);
    let seq = split.assign.seq;
    let me = hello.worker_id;
    let t0 = Instant::now();

    let exported = split.sp.export_vocabs();
    let slots = route.vocab_slots(|c| split.assign.owners[c] == me);
    // Owner → columns, ascending — both sides walk batches in the same
    // order, so replies pair up without per-request bookkeeping.
    let mut remote: BTreeMap<u16, Vec<u16>> = BTreeMap::new();
    for (c, slot) in slots.iter().enumerate() {
        if matches!(slot, VocabSlot::Remote { .. }) {
            remote.entry(split.assign.owners[c]).or_default().push(c as u16);
        }
    }

    // 1. All remote key batches out first.
    for (&owner, cols) in &remote {
        if let std::collections::hash_map::Entry::Vacant(slot) = clients.entry(owner) {
            let addr = hello.peers.get(owner as usize).ok_or_else(|| NetError::Malformed {
                what: format!("owner {owner} not in the peer table"),
            })?;
            let kh = KeyHello { job_id: hello.job_id, owner_id: owner, requester_id: me };
            slot.insert(KeyClient::open(addr, kh, Some(io))?);
        }
        let client = clients.get_mut(&owner).expect("just inserted");
        let sent = (|| -> Result<()> {
            for &c in cols {
                let kb = KeyBatch { col: c, seq, keys: exported[c as usize].clone() };
                protocol::write_frame(&mut client.writer, Tag::KeyBatch, &kb.encode())?;
            }
            client.writer.flush()?;
            Ok(())
        })();
        if let Err(e) = sent {
            clients.remove(&owner); // half-written session: reconnect next split
            return Err(e);
        }
    }

    // 2. Local folds (may block on predecessor splits, bounded by io).
    let ncols = slots.len();
    let mut tables: Vec<Option<Vec<u32>>> = vec![None; ncols];
    for (c, slot) in slots.iter().enumerate() {
        if matches!(slot, VocabSlot::Resident { .. }) {
            tables[c] = Some(state.seq(c as u16).submit(seq, &exported[c], io)?);
        }
    }

    // 3. Collect remote replies in send order.
    for (&owner, cols) in &remote {
        let client = clients.get_mut(&owner).expect("opened above");
        for &c in cols {
            let got = (|| -> Result<IndexBatch> {
                let (tag, payload) = protocol::read_frame(&mut client.reader)?;
                let ib = match tag {
                    Tag::IndexBatch => IndexBatch::decode(&payload)?,
                    Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                        worker: format!("owner {owner}"),
                        reason: String::from_utf8_lossy(&payload).into_owned(),
                    }),
                    other => anyhow::bail!(NetError::Malformed {
                        what: format!("key session expected indices, got {other:?}"),
                    }),
                };
                anyhow::ensure!(
                    ib.col == c && ib.seq == seq && ib.indices.len() == exported[c as usize].len(),
                    "index batch mismatch: got (col {}, seq {}, {} indices), want (col {c}, seq \
                     {seq}, {} keys)",
                    ib.col,
                    ib.seq,
                    ib.indices.len(),
                    exported[c as usize].len()
                );
                Ok(ib)
            })();
            let ib = match got {
                Ok(ib) => ib,
                Err(e) => {
                    clients.remove(&owner);
                    return Err(e);
                }
            };
            tables[c as usize] = Some(ib.indices);
        }
    }

    // 4. Rewrite apply-vocab columns from split-local appearance
    // indices to the owner-assigned global ones. Build-only columns
    // already emitted their raw mapped values — nothing to rewrite.
    for (c, slot) in slots.iter().enumerate() {
        let apply = matches!(
            slot,
            VocabSlot::Resident { apply: true } | VocabSlot::Remote { apply: true }
        );
        if !apply {
            continue;
        }
        let table = tables[c].as_ref().expect("apply column has a table");
        for row in &mut split.rows {
            row.sparse[c] = table[row.sparse[c] as usize];
        }
    }
    let vocab_extra = t0.elapsed().as_nanos() as u64;
    split.sp.add_vocab_ns(vocab_extra);

    // 5. Deltas out (before SplitDone, same session: the dispatcher's
    // mirror fold can never miss a delta of a completed split).
    for (c, slot) in slots.iter().enumerate() {
        if matches!(slot, VocabSlot::Stateless) {
            continue;
        }
        let delta = VocabDelta {
            col: c as u16,
            seq,
            keys: exported[c].clone(),
            indices: tables[c].clone().expect("vocab column has a table"),
        };
        protocol::write_frame(writer, Tag::VocabDelta, &delta.encode())?;
    }

    // 6. Rows, seq-prefixed for attribution on the multiplexed session.
    for chunk in split.rows.chunks(RESULT_ROWS_PER_FRAME) {
        let packed = protocol::pack_service_rows(seq, chunk, hello.job.schema);
        protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
    }

    let (rows_skipped, rows_quarantined, illegal_bytes) = split.sp.containment();
    let (decode_ns, stateless_ns, vocab_ns) = split.sp.stage_ns();
    Ok(RunStats {
        rows: split.rows.len() as u64,
        vocab_entries: 0, // the dispatcher's mirror is authoritative
        rows_skipped,
        rows_quarantined,
        illegal_bytes,
        decode_ns,
        stateless_ns,
        vocab_ns,
    })
}

/// Serve one key-forwarding session: fold incoming key batches through
/// the owned column's sequencer and reply with global indices. The
/// requester closing the connection at end of job is the clean exit.
pub(crate) fn key_session<R, W>(
    reader: &mut R,
    writer: &mut W,
    hello: KeyHello,
    opts: &WorkerOptions,
) -> Result<RunStats>
where
    R: Read,
    W: Write,
{
    let io = opts.io_timeout.unwrap_or(Duration::from_secs(30));
    let state = lookup_wait(hello.job_id, hello.owner_id, io)?;
    protocol::write_frame(
        writer,
        Tag::ServiceHello,
        &ServiceOpen::Ack { worker_id: hello.owner_id }.encode(),
    )?;
    writer.flush()?;
    let mut batches = 0u64;
    loop {
        let (tag, payload) = match protocol::read_frame(reader) {
            Ok(frame) => frame,
            Err(e) if matches!(NetError::of(&e), Some(NetError::PeerGone { .. })) => {
                // Requester hung up — the normal end of a key session.
                return Ok(RunStats { rows: batches, ..RunStats::default() });
            }
            Err(e) => return Err(e),
        };
        match tag {
            Tag::KeyBatch => {
                let kb = KeyBatch::decode(&payload)?;
                let indices = state.seq(kb.col).submit(kb.seq, &kb.keys, io)?;
                let ib = IndexBatch { col: kb.col, seq: kb.seq, indices };
                protocol::write_frame(writer, Tag::IndexBatch, &ib.encode())?;
                writer.flush()?;
                batches += 1;
            }
            other => anyhow::bail!(NetError::Malformed {
                what: format!("unexpected frame {other:?} on a key session"),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequencer_orders_and_replays() {
        let seq = ColSeq::new();
        let io = Duration::from_millis(200);
        // split 0 folds first, split 1 extends
        assert_eq!(seq.submit(0, &[10, 20], io).unwrap(), vec![0, 1]);
        assert_eq!(seq.submit(1, &[20, 30], io).unwrap(), vec![1, 2]);
        // replaying split 0 is apply-only and identical
        assert_eq!(seq.submit(0, &[10, 20], io).unwrap(), vec![0, 1]);
        // a gap times out with a typed error
        let err = seq.submit(5, &[1], Duration::from_millis(20)).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::Timeout { .. })), "{err:#}");
    }

    #[test]
    fn sequencer_unblocks_waiters_in_seq_order() {
        let seq = Arc::new(ColSeq::new());
        let s2 = seq.clone();
        let waiter = std::thread::spawn(move || s2.submit(1, &[7, 8], Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(seq.submit(0, &[8], Duration::from_secs(1)).unwrap(), vec![0]);
        // the waiter folds after split 0: 7 is new (idx 1), 8 seen (idx 0)
        assert_eq!(waiter.join().unwrap().unwrap(), vec![1, 0]);
    }

    #[test]
    fn seed_adopts_only_forward_state() {
        let seq = ColSeq::new();
        let io = Duration::from_millis(100);
        seq.seed(2, &[5, 6, 7]);
        // fold point moved to split 2; the seeded keys are appliable
        assert_eq!(seq.submit(0, &[5], io).unwrap(), vec![0]);
        assert_eq!(seq.submit(2, &[7, 9], io).unwrap(), vec![2, 3]);
        // a stale (behind) seed is ignored
        seq.seed(1, &[1]);
        assert_eq!(seq.submit(1, &[6], io).unwrap(), vec![1]);
    }
}
