//! Dispatcher-side worker registry: one [`Link`] per configured
//! worker, tracking join state, the split currently streaming on it,
//! and the reader thread that turns its session frames into events.
//!
//! Sessions carry a *generation* number that increments on every
//! successful (re)join; events stamped with a stale generation are
//! dropped by the scheduler, so a dying session's last gasps can never
//! be confused with its replacement's traffic.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::mpsc::Sender;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::net::protocol::{self, NetError, ServiceHello, ServiceOpen, SplitDone, Tag, VocabDelta};
use crate::net::{JobClock, NetConfig};

/// An event from one worker's reader thread, stamped with the session
/// generation it was read under.
pub(crate) enum Ev {
    Delta { w: usize, gen: u64, delta: VocabDelta },
    Rows { w: usize, gen: u64, payload: Vec<u8> },
    Done { w: usize, gen: u64, done: SplitDone },
    /// The session ended: EOF, I/O error, worker `ErrorReply`, or an
    /// unexpected frame. Always the reader thread's last event.
    Down { w: usize, gen: u64, what: String },
}

/// The split currently streaming on (or owed by) a worker.
pub(crate) struct InFlight {
    pub seq: u64,
    /// Ownership epoch the split was dispatched under. A completion
    /// from a stale epoch is requeued, not accepted: its key batches
    /// were routed by the old owner table, so a column's new owner may
    /// never have seen them.
    pub epoch: u32,
    /// Liveness backstop for a worker that keeps its socket open but
    /// stops making progress (dispatcher-side reads are unbounded once
    /// joined). Armed after the split is fully streamed; a worker that
    /// blows it has its session torn down and rejoined.
    pub deadline: Option<Instant>,
}

/// Dispatcher-side state for one configured worker.
pub(crate) struct Link {
    pub addr: String,
    pub id: u16,
    /// Write half of the live dispatch session (`None` when down).
    pub writer: Option<BufWriter<TcpStream>>,
    /// Socket handle kept for teardown: shutting it down unblocks the
    /// reader thread of a wedged session.
    pub sock: Option<TcpStream>,
    pub reader: Option<JoinHandle<()>>,
    /// Session generation; bumped on every successful (re)join.
    pub gen: u64,
    /// Permanently removed from the rotation (process dead or fatal).
    pub struck: bool,
    pub current: Option<InFlight>,
    /// Accepted split completions + merged stats for the run report.
    pub splits_done: u64,
    pub stats: protocol::RunStats,
}

impl Link {
    pub(crate) fn new(addr: String, id: u16) -> Link {
        Link {
            addr,
            id,
            writer: None,
            sock: None,
            reader: None,
            gen: 0,
            struck: false,
            current: None,
            splits_done: 0,
            stats: protocol::RunStats::default(),
        }
    }

    pub(crate) fn live(&self) -> bool {
        !self.struck && self.writer.is_some()
    }

    /// Tear the session state down (writer, socket, reader thread).
    /// Safe to call on an already-down link.
    pub(crate) fn close(&mut self) {
        if let Some(mut w) = self.writer.take() {
            let _ = w.flush();
        }
        if let Some(sock) = self.sock.take() {
            let _ = sock.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// How one join attempt failed — the scheduler's retry policy keys off
/// this, mirroring the old cluster rules: a refused connect strikes
/// the worker immediately (process dead), a worker `ErrorReply` to the
/// hello is fatal for the job (bad spec — retrying elsewhere hits the
/// same compile error on the same spec only when every worker agrees,
/// but *this* worker is done), anything else is retryable.
pub(crate) enum JoinError {
    Refused(anyhow::Error),
    Fatal(anyhow::Error),
    Retryable(anyhow::Error),
}

impl JoinError {
    pub(crate) fn into_inner(self) -> anyhow::Error {
        match self {
            JoinError::Refused(e) | JoinError::Fatal(e) | JoinError::Retryable(e) => e,
        }
    }
}

/// One join attempt: connect, send the dispatch hello, await the ack,
/// then hand the read half to a fresh reader thread. On success the
/// link is live under a new generation.
pub(crate) fn join(
    link: &mut Link,
    hello: &ServiceHello,
    cfg: &NetConfig,
    clock: &JobClock,
    tx: &Sender<Ev>,
) -> std::result::Result<(), JoinError> {
    link.close();
    let stream = match crate::net::connect(&link.addr, cfg.io_timeout, clock) {
        Ok(s) => s,
        Err(e) => {
            return Err(if matches!(NetError::of(&e), Some(NetError::PeerGone { .. })) {
                JoinError::Refused(e)
            } else {
                JoinError::Retryable(e)
            })
        }
    };
    let attempt = (|| -> crate::Result<(BufWriter<TcpStream>, BufReader<TcpStream>, TcpStream)> {
        let sock = stream.try_clone()?;
        let mut writer = BufWriter::with_capacity(1 << 20, stream.try_clone()?);
        let mut reader = BufReader::with_capacity(1 << 20, stream);
        protocol::write_frame(
            &mut writer,
            Tag::ServiceHello,
            &ServiceOpen::Dispatch(hello.clone()).encode(),
        )?;
        writer.flush()?;
        let (tag, payload) = protocol::read_frame(&mut reader)?;
        match tag {
            Tag::ServiceHello => match ServiceOpen::decode(&payload)? {
                ServiceOpen::Ack { .. } => Ok((writer, reader, sock)),
                other => anyhow::bail!(NetError::Malformed {
                    what: format!("dispatch hello expected an ack, got {other:?}"),
                }),
            },
            Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                worker: link.addr.clone(),
                reason: String::from_utf8_lossy(&payload).into_owned(),
            }),
            other => anyhow::bail!(NetError::Malformed {
                what: format!("dispatch hello expected an ack frame, got {other:?}"),
            }),
        }
    })();
    let (writer, mut reader, sock) = attempt.map_err(|e| {
        if matches!(NetError::of(&e), Some(NetError::JobFailed { .. })) {
            JoinError::Fatal(e)
        } else {
            JoinError::Retryable(e)
        }
    })?;
    // Joined: the session may idle while other workers stream (or a
    // worker folds keys), so reads are unbounded from here on — split
    // deadlines and the job clock provide liveness, a dead peer is an
    // EOF/reset, not a timeout.
    let _ = sock.set_read_timeout(None);
    link.gen += 1;
    let gen = link.gen;
    let w = link.id as usize;
    let tx = tx.clone();
    link.reader = Some(std::thread::spawn(move || reader_loop(&mut reader, w, gen, &tx)));
    link.writer = Some(writer);
    link.sock = Some(sock);
    link.current = None;
    Ok(())
}

fn reader_loop(reader: &mut BufReader<TcpStream>, w: usize, gen: u64, tx: &Sender<Ev>) {
    loop {
        let down = |what: String| Ev::Down { w, gen, what };
        let ev = match protocol::read_frame(reader) {
            Ok((Tag::VocabDelta, p)) => match VocabDelta::decode(&p) {
                Ok(delta) => Ev::Delta { w, gen, delta },
                Err(e) => down(format!("bad vocab delta: {e:#}")),
            },
            Ok((Tag::ResultChunk, p)) => Ev::Rows { w, gen, payload: p },
            Ok((Tag::SplitDone, p)) => match SplitDone::decode(&p) {
                Ok(done) => Ev::Done { w, gen, done },
                Err(e) => down(format!("bad split status: {e:#}")),
            },
            Ok((Tag::ErrorReply, p)) => down(String::from_utf8_lossy(&p).into_owned()),
            Ok((other, _)) => down(format!("unexpected frame {other:?} from worker")),
            Err(e) => down(format!("{e:#}")),
        };
        let is_down = matches!(ev, Ev::Down { .. });
        if tx.send(ev).is_err() || is_down {
            return; // scheduler gone, or the session is over
        }
    }
}
