//! Dispatcher-side vocabulary mirror.
//!
//! Owners stream [`VocabDelta`]s as they fold key batches; the mirror
//! re-folds them in split-sequence order and checks that the indices
//! the owner assigned match the deterministic fold. The contiguously-
//! folded prefix (the *watermark*) is exactly the state a replacement
//! owner must be seeded with after an ownership transfer — anything at
//! or above the watermark is re-derived by replaying splits.

use std::collections::BTreeMap;

use crate::net::protocol::{NetError, VocabDelta};
use crate::ops::{HashVocab, Vocab};
use crate::Result;

struct ColMirror {
    vocab: HashVocab,
    /// Next split seq to fold; deltas `< next` are verified replays.
    next: u64,
    /// Out-of-order deltas waiting for their predecessors.
    pending: BTreeMap<u64, (Vec<u32>, Vec<u32>)>,
}

/// One mirror per sparse column (stateless columns simply never
/// receive a delta and stay empty).
pub(crate) struct Mirror {
    cols: Vec<ColMirror>,
}

impl Mirror {
    pub(crate) fn new(num_sparse: usize) -> Mirror {
        Mirror {
            cols: (0..num_sparse)
                .map(|_| ColMirror { vocab: HashVocab::new(), next: 0, pending: BTreeMap::new() })
                .collect(),
        }
    }

    /// Fold one delta. Replayed deltas (a re-dispatched split re-sends
    /// identical ones — determinism) are verified against the existing
    /// fold and dropped; an index that disagrees with the deterministic
    /// fold is a protocol violation, not a retryable fault.
    pub(crate) fn fold(&mut self, delta: VocabDelta) -> Result<()> {
        let col = delta.col as usize;
        anyhow::ensure!(col < self.cols.len(), "vocab delta for out-of-range column {col}");
        let m = &mut self.cols[col];
        if delta.seq < m.next {
            for (&k, &i) in delta.keys.iter().zip(&delta.indices) {
                if m.vocab.apply(k) != Some(i) {
                    return diverged(delta.col, delta.seq);
                }
            }
            return Ok(());
        }
        if let Some((keys, indices)) = m.pending.get(&delta.seq) {
            if *keys != delta.keys || *indices != delta.indices {
                return diverged(delta.col, delta.seq);
            }
            return Ok(());
        }
        m.pending.insert(delta.seq, (delta.keys, delta.indices));
        while let Some((keys, indices)) = m.pending.remove(&m.next) {
            for (&k, &i) in keys.iter().zip(&indices) {
                if m.vocab.observe_apply(k) != i {
                    return diverged(delta.col, m.next);
                }
            }
            m.next += 1;
        }
        Ok(())
    }

    /// The contiguously-folded prefix for a column: every split below
    /// this seq has had its delta folded.
    pub(crate) fn watermark(&self, col: usize) -> u64 {
        self.cols[col].next
    }

    /// Whether `(col, seq)`'s delta has arrived (folded or parked).
    /// Checked before accepting a split completion — deltas precede
    /// `SplitDone` on the session, so a miss means the frame was lost.
    pub(crate) fn has(&self, col: usize, seq: u64) -> bool {
        let m = &self.cols[col];
        seq < m.next || m.pending.contains_key(&seq)
    }

    /// Seed payload for a replacement owner: the folded prefix's keys
    /// in index order plus the fold point. Pending (non-contiguous)
    /// deltas are dropped — the replay sweep re-derives them.
    pub(crate) fn seed_for(&mut self, col: usize) -> (u64, Vec<u32>) {
        let m = &mut self.cols[col];
        m.pending.clear();
        (m.next, m.vocab.export_keys())
    }

    /// Total distinct entries across all columns — the authoritative
    /// `vocab_entries` for the run (workers report 0; split-local
    /// counts would double-count shared keys).
    pub(crate) fn entries(&self) -> u64 {
        self.cols.iter().map(|m| m.vocab.len() as u64).sum()
    }
}

fn diverged(col: u16, seq: u64) -> Result<()> {
    anyhow::bail!(NetError::Malformed {
        what: format!("vocab delta for column {col}, split {seq} diverges from the mirror fold"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(col: u16, seq: u64, keys: &[u32], indices: &[u32]) -> VocabDelta {
        VocabDelta { col, seq, keys: keys.to_vec(), indices: indices.to_vec() }
    }

    #[test]
    fn folds_out_of_order_and_verifies() {
        let mut m = Mirror::new(2);
        // seq 1 arrives first — parked
        m.fold(delta(0, 1, &[30, 10], &[2, 0])).unwrap();
        assert_eq!(m.watermark(0), 0);
        m.fold(delta(0, 0, &[10, 20], &[0, 1])).unwrap();
        assert_eq!(m.watermark(0), 2);
        assert_eq!(m.entries(), 3);
        // replay of seq 0 verifies silently
        m.fold(delta(0, 0, &[10, 20], &[0, 1])).unwrap();
        assert_eq!(m.entries(), 3);
    }

    #[test]
    fn diverging_indices_are_rejected() {
        let mut m = Mirror::new(1);
        m.fold(delta(0, 0, &[10], &[0])).unwrap();
        let err = m.fold(delta(0, 0, &[10], &[7])).unwrap_err();
        assert!(err.to_string().contains("diverges"), "{err:#}");
        // out-of-order divergence is caught at fold time too
        let mut m = Mirror::new(1);
        m.fold(delta(0, 1, &[5], &[9])).unwrap();
        let err = m.fold(delta(0, 0, &[5], &[0])).unwrap_err();
        assert!(err.to_string().contains("diverges"), "{err:#}");
    }

    #[test]
    fn seed_carries_the_contiguous_prefix_only() {
        let mut m = Mirror::new(1);
        m.fold(delta(0, 0, &[10, 20], &[0, 1])).unwrap();
        m.fold(delta(0, 2, &[40], &[3])).unwrap(); // parked, non-contiguous
        let (next, keys) = m.seed_for(0);
        assert_eq!(next, 1);
        assert_eq!(keys, vec![10, 20]);
        // pending was dropped: folding seq 1 then 2 re-derives cleanly
        m.fold(delta(0, 1, &[30], &[2])).unwrap();
        m.fold(delta(0, 2, &[40], &[3])).unwrap();
        assert_eq!(m.watermark(0), 3);
        assert_eq!(m.entries(), 4);
    }
}
