//! Shard router: deterministic column → owning-worker assignment.
//!
//! Each vocabulary column is owned by exactly one live worker, chosen
//! by hashing the column id over the sorted live set. Dispatcher and
//! workers never negotiate — both sides can recompute the table from
//! `(column, live workers)` alone, and the dispatcher stamps the table
//! it used onto every split assignment so an epoch change mid-job can
//! never leave the two sides disagreeing about who folds a column.

use crate::ops::artifact::fnv1a;

/// Assign every sparse column an owner from the live set. `live` must
/// be sorted (callers keep worker ids ordered) so the table is a pure
/// function of membership, not of join order.
pub(crate) fn assign_owners(num_sparse: usize, live: &[u16]) -> Vec<u16> {
    debug_assert!(!live.is_empty(), "owner assignment needs at least one live worker");
    debug_assert!(live.windows(2).all(|w| w[0] < w[1]), "live set must be sorted");
    (0..num_sparse)
        .map(|c| live[(fnv1a(&(c as u64).to_le_bytes()) % live.len() as u64) as usize])
        .collect()
}

/// Columns whose owner changes between two tables — the set that needs
/// an [`crate::net::protocol::OwnerSeed`] and a replay sweep after a
/// worker is struck.
pub(crate) fn moved_columns(old: &[u16], new: &[u16]) -> Vec<usize> {
    old.iter().zip(new).enumerate().filter(|(_, (a, b))| a != b).map(|(c, _)| c).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_deterministic_and_total() {
        let a = assign_owners(26, &[0, 1, 2, 3]);
        let b = assign_owners(26, &[0, 1, 2, 3]);
        assert_eq!(a, b);
        assert_eq!(a.len(), 26);
        assert!(a.iter().all(|w| *w < 4));
        // with 26 columns over 4 workers, every worker should own some
        for w in 0..4u16 {
            assert!(a.contains(&w), "worker {w} owns no columns");
        }
    }

    #[test]
    fn single_worker_owns_everything() {
        assert!(assign_owners(26, &[3]).iter().all(|w| *w == 3));
    }

    #[test]
    fn moved_columns_tracks_ownership_changes() {
        let old = assign_owners(26, &[0, 1, 2, 3]);
        let new = assign_owners(26, &[0, 2, 3]);
        let moved = moved_columns(&old, &new);
        // every column that left worker 1 must be in the moved set
        for (c, &w) in old.iter().enumerate() {
            if w == 1 {
                assert!(moved.contains(&c), "column {c} left worker 1 but is not marked moved");
            }
        }
        for &c in &moved {
            assert_ne!(old[c], new[c]);
        }
    }
}
