//! GPU baseline — RAPIDS/nvtabular-style column-parallel preprocessing
//! (paper §2.5, §4.3) as a functional pipeline + V100-calibrated timing
//! model.
//!
//! The paper runs NVIDIA RAPIDS (`rmm`, `nvtabular`, `cudf`) on a 16 GB
//! V100: columns are processed independently across SMs ("a combination
//! of row-wise and column-wise multi-processing"), the input must first
//! be converted to a columnar binary format ("its acceleration highly
//! depends on the binary input format, like Parquet, so transforming the
//! original dataset is a non-trivial step"), and vocabulary generation
//! maps onto cudf's sort/hash-based `categorify`.
//!
//! We do not have a V100, so the *functional* path executes the same
//! column pipeline on the CPU (output must match the other backends) and
//! the *timing* is modeled from V100 parameters (DESIGN.md §5/§6):
//! memory-bound streaming per op, sort-rate-bound vocabulary build,
//! per-op/per-column framework dispatch, and PCIe transfers. All GPU
//! times are tagged `sim`.

use std::time::Duration;

use crate::data::row::ProcessedColumns;
use crate::data::{binary, DecodedRow, Schema};
use crate::decode::shard;
use crate::ops::{log1p, HashVocab, Modulus, Vocab};
use crate::Result;

/// V100 + RAPIDS timing parameters.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// HBM2 peak bandwidth (bytes/s).
    pub hbm_bps: f64,
    /// Achieved fraction of peak for streaming kernels.
    pub stream_efficiency: f64,
    /// Radix-sort throughput for categorify's key sort (keys/s).
    pub sort_keys_per_sec: f64,
    /// Gather/scatter effective random bandwidth (bytes/s).
    pub random_bps: f64,
    /// Framework dispatch per op per column (cudf/nvtabular/python).
    pub per_op_dispatch: Duration,
    /// PCIe gen3 ×16 effective (bytes/s).
    pub pcie_bps: f64,
    /// Host-side UTF-8 → columnar conversion throughput (bytes/s) —
    /// the Parquet-ification step the paper calls non-trivial.
    pub convert_bps: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            hbm_bps: 900.0e9,
            stream_efficiency: 0.6,
            // cudf categorify is sort+unique+join, not a single radix
            // pass — effective ~0.15G keys/s end to end (calibrated so
            // PIPER/GPU lands inside the paper's 4.8–20.3× band,
            // EXPERIMENTS.md §Calibration).
            sort_keys_per_sec: 0.15e9,
            random_bps: 60.0e9,
            per_op_dispatch: Duration::from_millis(25),
            pcie_bps: 12.0e9,
            convert_bps: 0.3e9,
        }
    }
}

impl GpuModel {
    /// The full V100 timing model over run totals for the paper's fixed
    /// DLRM pipeline — the one-shot [`run`]'s model. `utf8_bytes` is the
    /// raw text size when the input was UTF-8 (it prices the host-side
    /// columnar conversion); `None` for binary.
    pub fn breakdown(
        &self,
        schema: Schema,
        rows: usize,
        utf8_bytes: Option<usize>,
        unique_total: usize,
    ) -> GpuBreakdown {
        // The DLRM chain: every sparse column runs modulus + genvocab +
        // applyvocab + store, every dense column neg2zero + log + store,
        // and every sparse column builds a vocabulary.
        self.model(
            schema,
            rows,
            utf8_bytes,
            unique_total,
            4 * schema.num_sparse,
            3 * schema.num_dense,
            schema.num_sparse,
        )
    }

    /// The same model driven by compiled per-column programs: the
    /// **dispatch launches** (per physical op per column) and the
    /// **categorify volume** (values of vocabulary-building columns
    /// only) follow what each column actually runs. The streaming-
    /// kernel byte estimate stays a whole-table read+write per pass —
    /// kernel chains are memory-bound, so chain length barely moves
    /// bytes touched. For the uniform DLRM plan this reduces to
    /// [`Self::breakdown`] — the streaming executor and the one-shot
    /// model agree bit for bit.
    pub fn breakdown_programs(
        &self,
        plans: &crate::ops::ColumnPlans,
        rows: usize,
        utf8_bytes: Option<usize>,
        unique_total: usize,
    ) -> GpuBreakdown {
        let (ops_sparse, ops_dense) = plans.dispatch_ops();
        self.model(
            plans.schema,
            rows,
            utf8_bytes,
            unique_total,
            ops_sparse,
            ops_dense,
            plans.vocab_columns(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn model(
        &self,
        schema: Schema,
        rows: usize,
        utf8_bytes: Option<usize>,
        unique_total: usize,
        ops_sparse: usize,
        ops_dense: usize,
        vocab_columns: usize,
    ) -> GpuBreakdown {
        let bin_bytes = rows * schema.binary_row_bytes();
        let sparse_values = (rows * schema.num_sparse) as f64;
        let dense_values = (rows * schema.num_dense) as f64;
        let vocab_values = (rows * vocab_columns) as f64;

        let convert = match utf8_bytes {
            Some(bytes) => Duration::from_secs_f64(bytes as f64 / self.convert_bps),
            None => Duration::ZERO,
        };
        let transfer = Duration::from_secs_f64(2.0 * bin_bytes as f64 / self.pcie_bps);

        // Streaming kernels: each op reads+writes its column once.
        // Sparse: modulus + gather-write; dense: the kernel chain.
        let stream_bytes = (2.0 * sparse_values + 2.0 * dense_values) * 2.0 * 4.0;
        let stream_kernels =
            Duration::from_secs_f64(stream_bytes / (self.hbm_bps * self.stream_efficiency));

        // Vocabulary: sort-based categorify over the vocabulary-building
        // columns' values + random gathers for apply + hash-build
        // proportional to uniques.
        let vocab_secs = vocab_values / self.sort_keys_per_sec
            + vocab_values * 16.0 / self.random_bps
            + unique_total as f64 * 32.0 / self.random_bps;
        let vocab = Duration::from_secs_f64(vocab_secs);

        // Dispatch: nvtabular launches per op per column per pass.
        let dispatch = self.per_op_dispatch * (ops_sparse + ops_dense) as u32;

        GpuBreakdown { convert, transfer, stream_kernels, vocab, dispatch }
    }
}

/// Per-phase modeled times of a GPU run.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuBreakdown {
    /// UTF-8 → columnar conversion on the host (zero for binary input).
    pub convert: Duration,
    /// H2D + D2H transfers.
    pub transfer: Duration,
    /// Streaming op kernels (modulus, neg2zero, log, gather writes).
    pub stream_kernels: Duration,
    /// Vocabulary build (sort/hash categorify) + apply gathers.
    pub vocab: Duration,
    /// Framework dispatch overhead.
    pub dispatch: Duration,
}

impl GpuBreakdown {
    pub fn total(&self) -> Duration {
        self.convert + self.transfer + self.stream_kernels + self.vocab + self.dispatch
    }
}

/// Result of the GPU baseline.
#[derive(Debug)]
pub struct GpuRun {
    pub processed: ProcessedColumns,
    pub rows: usize,
    pub breakdown: GpuBreakdown,
}

impl GpuRun {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        crate::report::rows_per_sec(self.rows, self.breakdown.total())
    }
}

/// Input format accepted by the GPU path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuInput {
    /// Raw text — charged the host-side conversion first.
    Utf8,
    /// Pre-decoded binary — the format RAPIDS wants.
    Binary,
}

/// Run the GPU baseline functionally and model its time.
pub fn run(
    model: &GpuModel,
    schema: Schema,
    modulus: Modulus,
    input: GpuInput,
    raw: &[u8],
) -> Result<GpuRun> {
    // ---- functional column pipeline (executed on CPU) ------------------
    // Row-sharded SWAR decode: bit-identical to ParallelDecoder (the
    // timing below is the V100 model, not this decode's wallclock).
    let rows: Vec<DecodedRow> = match input {
        GpuInput::Utf8 => shard::decode_rows(schema, raw, shard::default_threads()),
        GpuInput::Binary => binary::decode_bytes(raw, schema)?,
    };
    let n = rows.len();

    // Column-major staging (what the columnar format gives the GPU).
    let mut sparse_cols: Vec<Vec<u32>> = vec![Vec::with_capacity(n); schema.num_sparse];
    let mut dense_cols: Vec<Vec<i32>> = vec![Vec::with_capacity(n); schema.num_dense];
    let mut labels = Vec::with_capacity(n);
    for r in &rows {
        labels.push(r.label);
        for (c, &v) in r.sparse.iter().enumerate() {
            sparse_cols[c].push(modulus.apply(v));
        }
        for (c, &v) in r.dense.iter().enumerate() {
            dense_cols[c].push(v);
        }
    }

    let mut processed = ProcessedColumns::with_schema(schema);
    processed.labels = labels;
    let mut unique_total = 0usize;
    for (c, col) in sparse_cols.iter().enumerate() {
        // categorify: build per-column vocab then gather indices.
        let mut v = HashVocab::new();
        v.observe_slice(col);
        unique_total += v.len();
        let dst = &mut processed.sparse[c];
        dst.resize(col.len(), 0);
        v.apply_slice(col, dst);
    }
    for (c, col) in dense_cols.iter().enumerate() {
        let dst = &mut processed.dense[c];
        dst.reserve(col.len());
        for &x in col {
            dst.push(log1p(x));
        }
    }

    // ---- timing model ---------------------------------------------------
    let utf8_bytes = match input {
        GpuInput::Utf8 => Some(raw.len()),
        GpuInput::Binary => None,
    };
    let breakdown = model.breakdown(schema, n, utf8_bytes, unique_total);

    Ok(GpuRun { processed, rows: n, breakdown })
}

// ---------------------------------------------------------------------
// Streaming executor
// ---------------------------------------------------------------------

use crate::pipeline::{
    ChunkState, Executor, ExecutorReport, ExecutorRun, Plan, StreamStats,
};
use crate::report::TimeTag;

/// The GPU baseline as a streaming [`Executor`]: the functional column
/// pipeline runs on the CPU chunk by chunk, and the V100 timing model is
/// evaluated once at the end of the submission over the stream totals —
/// exactly the quantities [`run`] derives from a one-shot buffer, so the
/// modeled time is identical. All times are tagged sim.
#[derive(Debug, Clone, Default)]
pub struct GpuExecutor {
    pub model: GpuModel,
}

impl GpuExecutor {
    pub fn new(model: GpuModel) -> Self {
        GpuExecutor { model }
    }
}

impl Executor for GpuExecutor {
    fn name(&self) -> String {
        "GPU (V100 model)".to_string()
    }

    fn accepts(&self, _input: crate::accel::InputFormat) -> bool {
        // RAPIDS wants binary/Parquet; UTF-8 is accepted but charged the
        // host-side conversion (the paper's non-trivial transform step).
        true
    }

    /// cudf's hash-based categorify can build and gather in one pass —
    /// the functional pipeline fuses without restriction. (The *timing*
    /// model is evaluated over stream totals either way, so the modeled
    /// V100 time is strategy-independent; what fusing changes is the
    /// host-side functional wallclock.)
    fn supports_fused(&self, _plan: &Plan) -> bool {
        true
    }

    fn begin(&self, plan: &Plan) -> Result<Box<dyn ExecutorRun>> {
        Ok(Box::new(GpuExecRun {
            model: self.model,
            input: plan.input,
            state: ChunkState::new(plan),
            observe_time: Duration::ZERO,
            process_time: Duration::ZERO,
        }))
    }
}

struct GpuExecRun {
    model: GpuModel,
    input: crate::accel::InputFormat,
    state: ChunkState,
    observe_time: Duration,
    process_time: Duration,
}

impl ExecutorRun for GpuExecRun {
    fn process_observing(
        &mut self,
        block: &crate::data::RowBlock,
        sink: &mut dyn crate::pipeline::Sink,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let out = self.state.process_fused(block);
        self.process_time += t0.elapsed();
        sink.push(&out)
    }

    /// Stage-split for the pipelined fused scheduler — the exact
    /// decomposition of [`ChunkState::process_fused`] (stateless full
    /// range, then the in-order sparse fuse), so pipelined output is
    /// bit-identical. The host-side work is single-threaded either way;
    /// the engine still overlaps it with decode of the next chunk.
    fn stages(&mut self) -> Option<crate::pipeline::FusedStages<'_>> {
        let (programs, vocabs) = self.state.stage_split();
        Some(crate::pipeline::FusedStages {
            stateless: Box::new(move |block: &crate::data::RowBlock| {
                crate::pipeline::executor::stateless_range(programs, block, 0..block.num_rows())
            }),
            vocab: Box::new(move |block: &crate::data::RowBlock, out: &mut ProcessedColumns| {
                crate::pipeline::executor::fuse_sparse_into(programs, vocabs, block, out);
            }),
        })
    }

    fn observe(&mut self, block: &crate::data::RowBlock) -> Result<()> {
        let t0 = std::time::Instant::now();
        self.state.observe(block);
        self.observe_time += t0.elapsed();
        Ok(())
    }

    fn process(&mut self, block: &crate::data::RowBlock) -> Result<ProcessedColumns> {
        let t0 = std::time::Instant::now();
        let out = self.state.process(block);
        self.process_time += t0.elapsed();
        Ok(out)
    }

    fn finish(&mut self, stats: &StreamStats) -> Result<ExecutorReport> {
        // Engine-measured stage times under pipelined driving; zero when
        // this run timed its own phases in `process_observing`.
        self.process_time += stats.stateless_time;
        self.observe_time += stats.vocab_time;
        let unique_total = self.state.vocab_entries();
        let utf8_bytes = match self.input {
            crate::accel::InputFormat::Utf8 => Some(stats.raw_bytes as usize),
            crate::accel::InputFormat::Binary => None,
        };
        // Priced per compiled program: for the uniform DLRM plan this is
        // exactly `breakdown` (the one-shot model), so the equivalence
        // test between the two paths pins the reduction.
        let breakdown = self.model.breakdown_programs(
            &self.state.programs,
            stats.rows as usize,
            utf8_bytes,
            unique_total,
        );
        Ok(ExecutorReport {
            tag: TimeTag::Sim,
            modeled_e2e: Some(breakdown.total()),
            compute: Some(breakdown.total() - breakdown.convert),
            observe_time: self.observe_time,
            process_time: self.process_time,
            vocab_entries: unique_total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, utf8, SynthDataset};

    fn ds(rows: usize) -> SynthDataset {
        SynthDataset::generate(SynthConfig::small(rows))
    }

    #[test]
    fn output_matches_cpu_baseline() {
        let ds = ds(250);
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let gpu = run(&GpuModel::default(), ds.schema(), m, GpuInput::Utf8, &raw).unwrap();

        let cfg = crate::cpu_baseline::BaselineConfig::new(
            crate::cpu_baseline::ConfigKind::I,
            3,
            m,
        );
        let cpu = crate::cpu_baseline::run(&cfg, &raw);
        assert_eq!(gpu.processed, cpu.processed);
    }

    #[test]
    fn binary_input_skips_conversion() {
        let ds = ds(100);
        let m = Modulus::new(101);
        let raw = binary::encode_dataset(&ds);
        let gpu = run(&GpuModel::default(), ds.schema(), m, GpuInput::Binary, &raw).unwrap();
        assert_eq!(gpu.breakdown.convert, Duration::ZERO);
        assert!(gpu.breakdown.total() > Duration::ZERO);
    }

    #[test]
    fn utf8_conversion_dominates_large_inputs() {
        // Model sanity at paper scale: 11 GB UTF-8.
        let model = GpuModel::default();
        let convert = Duration::from_secs_f64(11.0e9 / model.convert_bps);
        assert!(convert > Duration::from_secs(30), "conversion should dominate");
    }

    #[test]
    fn streaming_executor_matches_one_shot_run() {
        let ds = ds(220);
        let m = Modulus::new(499);
        let raw = utf8::encode_dataset(&ds);
        let one_shot =
            run(&GpuModel::default(), ds.schema(), m, GpuInput::Utf8, &raw).unwrap();

        let pipeline = crate::pipeline::PipelineBuilder::new()
            .spec(crate::ops::PipelineSpec::dlrm(m.range))
            .schema(ds.schema())
            .input(crate::accel::InputFormat::Utf8)
            .chunk_rows(64)
            .executor(Box::new(GpuExecutor::default()))
            .build()
            .unwrap();
        let mut src = crate::pipeline::MemorySource::new(&raw, crate::accel::InputFormat::Utf8);
        let (cols, report) = pipeline.run_collect(&mut src).unwrap();
        assert_eq!(cols, one_shot.processed);
        assert_eq!(report.tag, crate::report::TimeTag::Sim);
        // identical stream totals ⇒ identical modeled time
        let d = report.e2e.as_secs_f64() - one_shot.breakdown.total().as_secs_f64();
        assert!(d.abs() < 1e-9, "modeled e2e drifted by {d}");
    }

    #[test]
    fn utf8_and_binary_agree_functionally() {
        let ds = ds(150);
        let m = Modulus::new(499);
        let u = run(&GpuModel::default(), ds.schema(), m, GpuInput::Utf8,
                    &utf8::encode_dataset(&ds)).unwrap();
        let b = run(&GpuModel::default(), ds.schema(), m, GpuInput::Binary,
                    &binary::encode_dataset(&ds)).unwrap();
        assert_eq!(u.processed, b.processed);
    }
}
