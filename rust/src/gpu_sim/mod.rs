//! GPU baseline — RAPIDS/nvtabular-style column-parallel preprocessing
//! (paper §2.5, §4.3) as a functional pipeline + V100-calibrated timing
//! model.
//!
//! The paper runs NVIDIA RAPIDS (`rmm`, `nvtabular`, `cudf`) on a 16 GB
//! V100: columns are processed independently across SMs ("a combination
//! of row-wise and column-wise multi-processing"), the input must first
//! be converted to a columnar binary format ("its acceleration highly
//! depends on the binary input format, like Parquet, so transforming the
//! original dataset is a non-trivial step"), and vocabulary generation
//! maps onto cudf's sort/hash-based `categorify`.
//!
//! We do not have a V100, so the *functional* path executes the same
//! column pipeline on the CPU (output must match the other backends) and
//! the *timing* is modeled from V100 parameters (DESIGN.md §5/§6):
//! memory-bound streaming per op, sort-rate-bound vocabulary build,
//! per-op/per-column framework dispatch, and PCIe transfers. All GPU
//! times are tagged `sim`.

use std::time::Duration;

use crate::data::row::ProcessedColumns;
use crate::data::{binary, DecodedRow, Schema};
use crate::decode::ParallelDecoder;
use crate::ops::{log1p, HashVocab, Modulus, Vocab};
use crate::Result;

/// V100 + RAPIDS timing parameters.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// HBM2 peak bandwidth (bytes/s).
    pub hbm_bps: f64,
    /// Achieved fraction of peak for streaming kernels.
    pub stream_efficiency: f64,
    /// Radix-sort throughput for categorify's key sort (keys/s).
    pub sort_keys_per_sec: f64,
    /// Gather/scatter effective random bandwidth (bytes/s).
    pub random_bps: f64,
    /// Framework dispatch per op per column (cudf/nvtabular/python).
    pub per_op_dispatch: Duration,
    /// PCIe gen3 ×16 effective (bytes/s).
    pub pcie_bps: f64,
    /// Host-side UTF-8 → columnar conversion throughput (bytes/s) —
    /// the Parquet-ification step the paper calls non-trivial.
    pub convert_bps: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            hbm_bps: 900.0e9,
            stream_efficiency: 0.6,
            // cudf categorify is sort+unique+join, not a single radix
            // pass — effective ~0.15G keys/s end to end (calibrated so
            // PIPER/GPU lands inside the paper's 4.8–20.3× band,
            // EXPERIMENTS.md §Calibration).
            sort_keys_per_sec: 0.15e9,
            random_bps: 60.0e9,
            per_op_dispatch: Duration::from_millis(25),
            pcie_bps: 12.0e9,
            convert_bps: 0.3e9,
        }
    }
}

/// Per-phase modeled times of a GPU run.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuBreakdown {
    /// UTF-8 → columnar conversion on the host (zero for binary input).
    pub convert: Duration,
    /// H2D + D2H transfers.
    pub transfer: Duration,
    /// Streaming op kernels (modulus, neg2zero, log, gather writes).
    pub stream_kernels: Duration,
    /// Vocabulary build (sort/hash categorify) + apply gathers.
    pub vocab: Duration,
    /// Framework dispatch overhead.
    pub dispatch: Duration,
}

impl GpuBreakdown {
    pub fn total(&self) -> Duration {
        self.convert + self.transfer + self.stream_kernels + self.vocab + self.dispatch
    }
}

/// Result of the GPU baseline.
#[derive(Debug)]
pub struct GpuRun {
    pub processed: ProcessedColumns,
    pub rows: usize,
    pub breakdown: GpuBreakdown,
}

impl GpuRun {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        self.rows as f64 / self.breakdown.total().as_secs_f64().max(1e-12)
    }
}

/// Input format accepted by the GPU path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuInput {
    /// Raw text — charged the host-side conversion first.
    Utf8,
    /// Pre-decoded binary — the format RAPIDS wants.
    Binary,
}

/// Run the GPU baseline functionally and model its time.
pub fn run(
    model: &GpuModel,
    schema: Schema,
    modulus: Modulus,
    input: GpuInput,
    raw: &[u8],
) -> Result<GpuRun> {
    // ---- functional column pipeline (executed on CPU) ------------------
    let rows: Vec<DecodedRow> = match input {
        GpuInput::Utf8 => ParallelDecoder::new(schema).decode(raw).rows,
        GpuInput::Binary => binary::decode_bytes(raw, schema)?,
    };
    let n = rows.len();

    // Column-major staging (what the columnar format gives the GPU).
    let mut sparse_cols: Vec<Vec<u32>> = vec![Vec::with_capacity(n); schema.num_sparse];
    let mut dense_cols: Vec<Vec<i32>> = vec![Vec::with_capacity(n); schema.num_dense];
    let mut labels = Vec::with_capacity(n);
    for r in &rows {
        labels.push(r.label);
        for (c, &v) in r.sparse.iter().enumerate() {
            sparse_cols[c].push(modulus.apply(v));
        }
        for (c, &v) in r.dense.iter().enumerate() {
            dense_cols[c].push(v);
        }
    }

    let mut processed = ProcessedColumns::with_schema(schema);
    processed.labels = labels;
    let mut unique_total = 0usize;
    for (c, col) in sparse_cols.iter().enumerate() {
        // categorify: build per-column vocab then gather indices.
        let mut v = HashVocab::new();
        v.observe_slice(col);
        unique_total += v.len();
        v.apply_slice(col, &mut processed.sparse[c]);
    }
    for (c, col) in dense_cols.iter().enumerate() {
        let dst = &mut processed.dense[c];
        dst.reserve(col.len());
        for &x in col {
            dst.push(log1p(x));
        }
    }

    // ---- timing model ---------------------------------------------------
    let bin_bytes = n * schema.binary_row_bytes();
    let sparse_values = (n * schema.num_sparse) as f64;
    let dense_values = (n * schema.num_dense) as f64;

    let convert = match input {
        GpuInput::Utf8 => Duration::from_secs_f64(raw.len() as f64 / model.convert_bps),
        GpuInput::Binary => Duration::ZERO,
    };
    let transfer = Duration::from_secs_f64(2.0 * bin_bytes as f64 / model.pcie_bps);

    // Streaming kernels: each op reads+writes its column once.
    // Sparse: modulus + gather-write; dense: neg2zero + log.
    let stream_bytes = (2.0 * sparse_values + 2.0 * dense_values) * 2.0 * 4.0;
    let stream_kernels = Duration::from_secs_f64(
        stream_bytes / (model.hbm_bps * model.stream_efficiency),
    );

    // Vocabulary: sort-based categorify over every sparse value + random
    // gathers for apply + hash-build proportional to uniques.
    let vocab_secs = sparse_values / model.sort_keys_per_sec
        + sparse_values * 16.0 / model.random_bps
        + unique_total as f64 * 32.0 / model.random_bps;
    let vocab = Duration::from_secs_f64(vocab_secs);

    // Dispatch: nvtabular launches per op per column per pass.
    let ops_sparse = 4 * schema.num_sparse; // modulus, genvocab, applyvocab, store
    let ops_dense = 3 * schema.num_dense; // neg2zero, log, store
    let dispatch = model.per_op_dispatch * (ops_sparse + ops_dense) as u32;

    Ok(GpuRun {
        processed,
        rows: n,
        breakdown: GpuBreakdown { convert, transfer, stream_kernels, vocab, dispatch },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth::SynthConfig, utf8, SynthDataset};

    fn ds(rows: usize) -> SynthDataset {
        SynthDataset::generate(SynthConfig::small(rows))
    }

    #[test]
    fn output_matches_cpu_baseline() {
        let ds = ds(250);
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let gpu = run(&GpuModel::default(), ds.schema(), m, GpuInput::Utf8, &raw).unwrap();

        let cfg = crate::cpu_baseline::BaselineConfig::new(
            crate::cpu_baseline::ConfigKind::I,
            3,
            m,
        );
        let cpu = crate::cpu_baseline::run(&cfg, &raw);
        assert_eq!(gpu.processed, cpu.processed);
    }

    #[test]
    fn binary_input_skips_conversion() {
        let ds = ds(100);
        let m = Modulus::new(101);
        let raw = binary::encode_dataset(&ds);
        let gpu = run(&GpuModel::default(), ds.schema(), m, GpuInput::Binary, &raw).unwrap();
        assert_eq!(gpu.breakdown.convert, Duration::ZERO);
        assert!(gpu.breakdown.total() > Duration::ZERO);
    }

    #[test]
    fn utf8_conversion_dominates_large_inputs() {
        // Model sanity at paper scale: 11 GB UTF-8.
        let model = GpuModel::default();
        let convert = Duration::from_secs_f64(11.0e9 / model.convert_bps);
        assert!(convert > Duration::from_secs(30), "conversion should dominate");
    }

    #[test]
    fn utf8_and_binary_agree_functionally() {
        let ds = ds(150);
        let m = Modulus::new(499);
        let u = run(&GpuModel::default(), ds.schema(), m, GpuInput::Utf8,
                    &utf8::encode_dataset(&ds)).unwrap();
        let b = run(&GpuModel::default(), ds.schema(), m, GpuInput::Binary,
                    &binary::encode_dataset(&ds)).unwrap();
        assert_eq!(u.processed, b.processed);
    }
}
