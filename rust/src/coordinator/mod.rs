//! The coordinator: unified backend dispatch + the experiment driver the
//! CLI and the bench harness share.
//!
//! A [`Backend`] is one of the platforms the paper compares (Fig. 9 /
//! Table 3): the multithreaded CPU baseline, the GPU model, or PIPER in
//! its three modes. Since the pipeline-engine redesign this module is a
//! thin adapter: [`Backend::executor`] maps a backend onto its
//! [`Executor`], [`run_backend`] plans a one-shot [`Pipeline`] over an
//! in-memory buffer, and [`compare`] assembles the paper's comparison
//! rows. Plans built here inherit the engine's default execution
//! strategy — fused single-pass on every backend (all three support
//! it); use [`PipelineBuilder::strategy`] directly to pin the two-pass
//! baseline. Long-lived callers should build a pipeline once via
//! [`pipeline_for`] (or [`PipelineBuilder`] directly) and reuse it
//! across submissions.

use std::time::Duration;

use crate::accel::{InputFormat, Mode, PiperExecutor};
use crate::cpu_baseline::{ConfigKind, CpuExecutor};
use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::gpu_sim::GpuExecutor;
use crate::ops::{Modulus, PipelineSpec};
use crate::pipeline::{ExecStrategy, Executor, MemorySource, Pipeline, PipelineBuilder};
use crate::report::{self, TimeTag};
use crate::Result;

/// A platform under comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Meta's pipeline, `threads` threads, one of Configs I/II/III.
    Cpu { kind: ConfigKind, threads: usize },
    /// RAPIDS-style GPU model.
    Gpu,
    /// PIPER — local or network, decode placement per mode.
    Piper { mode: Mode },
}

impl Backend {
    pub fn name(&self) -> String {
        match self {
            Backend::Cpu { kind, threads } => format!("CPU-{threads} {}", kind.name()),
            Backend::Gpu => "GPU (V100 model)".to_string(),
            Backend::Piper { mode } => format!("PIPER {}", mode.name()),
        }
    }

    /// The streaming executor implementing this backend.
    pub fn executor(&self) -> Box<dyn Executor> {
        match self {
            Backend::Cpu { kind, threads } => Box::new(CpuExecutor::new(*kind, *threads)),
            Backend::Gpu => Box::new(GpuExecutor::default()),
            Backend::Piper { mode } => Box::new(PiperExecutor::new(*mode)),
        }
    }

    /// Which raw format this backend consumes for a given experiment
    /// input format. Delegates to the executor's planning-time
    /// capability check (paper Table 2: only Config III takes binary).
    pub fn accepts(&self, input: InputFormat) -> bool {
        self.executor().accepts(input)
    }
}

/// Uniform result of one backend run.
#[derive(Debug)]
pub struct RunSummary {
    pub backend: String,
    pub processed: ProcessedColumns,
    pub rows: usize,
    pub e2e: Duration,
    pub tag: TimeTag,
    /// Pure-computation time (Table 3 scope) where defined.
    pub compute: Option<Duration>,
    /// Execution strategy the plan ran under.
    pub strategy: ExecStrategy,
}

impl RunSummary {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        report::rows_per_sec(self.rows, self.e2e)
    }

    pub fn compute_rows_per_sec(&self) -> Option<f64> {
        self.compute.map(|c| report::rows_per_sec(self.rows, c))
    }
}

/// Shared experiment parameters.
#[derive(Debug, Clone, Copy)]
pub struct Experiment {
    pub schema: Schema,
    pub modulus: Modulus,
    pub input: InputFormat,
}

impl Experiment {
    pub fn new(modulus: Modulus, input: InputFormat) -> Self {
        Experiment { schema: Schema::CRITEO, modulus, input }
    }
}

/// Build a reusable [`Pipeline`] for a backend + experiment — planning
/// (spec validation, capability checks, accelerator capacity) happens
/// here, once.
pub fn pipeline_for(backend: &Backend, exp: &Experiment) -> Result<Pipeline> {
    pipeline_for_chunked(backend, exp, 64 * 1024)
}

/// [`pipeline_for`] with an explicit chunk size (rows per chunk).
pub fn pipeline_for_chunked(
    backend: &Backend,
    exp: &Experiment,
    chunk_rows: usize,
) -> Result<Pipeline> {
    pipeline_with(backend, exp, chunk_rows, None)
}

/// Build a pipeline with an optional strategy override (`None` = the
/// engine default, which is fused wherever the executor supports it).
fn pipeline_with(
    backend: &Backend,
    exp: &Experiment,
    chunk_rows: usize,
    strategy: Option<ExecStrategy>,
) -> Result<Pipeline> {
    let mut builder = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(exp.modulus.range))
        .schema(exp.schema)
        .input(exp.input)
        .chunk_rows(chunk_rows)
        .executor(backend.executor());
    if let Some(s) = strategy {
        builder = builder.strategy(s);
    }
    builder.build()
}

/// Execute one backend over a raw buffer — the one-shot adapter over the
/// streaming engine, kept for the CLI, benches and tests. Plans a fresh
/// pipeline per call; reuse [`pipeline_for`] when submitting repeatedly.
pub fn run_backend(backend: &Backend, exp: &Experiment, raw: &[u8]) -> Result<RunSummary> {
    run_backend_with(backend, exp, raw, None)
}

/// [`run_backend`] with an explicit strategy override (`None` = engine
/// default).
pub fn run_backend_with(
    backend: &Backend,
    exp: &Experiment,
    raw: &[u8],
    strategy: Option<ExecStrategy>,
) -> Result<RunSummary> {
    let pipeline = pipeline_with(backend, exp, 64 * 1024, strategy)?;
    let mut source = MemorySource::new(raw, exp.input);
    let (processed, run) = pipeline.run_collect(&mut source)?;
    Ok(RunSummary {
        backend: run.executor.clone(),
        rows: run.rows,
        e2e: run.e2e,
        tag: run.tag,
        compute: run.compute,
        strategy: run.strategy,
        processed,
    })
}

/// One comparison row: backend vs the chosen reference.
#[derive(Debug)]
pub struct CompareRow {
    pub backend: String,
    pub strategy: ExecStrategy,
    pub e2e: Duration,
    pub tag: TimeTag,
    pub rows_per_sec: f64,
    pub speedup_vs_ref: f64,
}

/// Run several backends over the same input and compute speedups against
/// the *best CPU* entry (the paper's convention).
///
/// The CPU rows are pinned to the two-pass strategy: they model the
/// paper's staged two-loop baseline, and Fig. 9's speedups are measured
/// against exactly that. Sim backends keep the engine default — their
/// modeled times are evaluated over stream totals and therefore
/// strategy-independent. Each row reports the strategy it ran.
pub fn compare(
    backends: &[Backend],
    exp: &Experiment,
    raw: &[u8],
) -> Result<Vec<CompareRow>> {
    let mut runs = Vec::new();
    for b in backends {
        let strategy = match b {
            Backend::Cpu { .. } => Some(ExecStrategy::TwoPass),
            _ => None,
        };
        runs.push(run_backend_with(b, exp, raw, strategy)?);
    }
    // Functional cross-check: deterministic backends must agree.
    let reference_output = runs
        .iter()
        .find(|r| !r.backend.contains("Config II"))
        .map(|r| r.processed.clone());
    if let Some(expect) = &reference_output {
        for r in &runs {
            if !r.backend.contains("Config II") {
                anyhow::ensure!(
                    &r.processed == expect,
                    "backend {} produced different output",
                    r.backend
                );
            }
        }
    }
    let best_cpu = runs
        .iter()
        .filter(|r| r.backend.starts_with("CPU"))
        .map(|r| r.e2e)
        .min()
        .unwrap_or_else(|| {
            runs.iter().map(|r| r.e2e).max().unwrap_or(Duration::from_secs(1))
        });
    Ok(runs
        .iter()
        .map(|r| CompareRow {
            backend: r.backend.clone(),
            strategy: r.strategy,
            e2e: r.e2e,
            tag: r.tag,
            rows_per_sec: r.e2e_rows_per_sec(),
            speedup_vs_ref: best_cpu.as_secs_f64() / r.e2e.as_secs_f64().max(1e-12),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};

    #[test]
    fn all_backends_agree_functionally() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let exp = Experiment { schema: ds.schema(), ..Experiment::new(Modulus::new(997), InputFormat::Utf8) };
        let raw = utf8::encode_dataset(&ds);
        let backends = vec![
            Backend::Cpu { kind: ConfigKind::I, threads: 2 },
            Backend::Gpu,
            Backend::Piper { mode: Mode::Network },
            Backend::Piper { mode: Mode::LocalDecodeInKernel },
        ];
        let rows = compare(&backends, &exp, &raw).unwrap();
        assert_eq!(rows.len(), 4);
    }

    #[test]
    fn binary_experiment_runs() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let exp = Experiment { schema: ds.schema(), ..Experiment::new(Modulus::new(499), InputFormat::Binary) };
        let raw = binary::encode_dataset(&ds);
        let backends = vec![
            Backend::Cpu { kind: ConfigKind::III, threads: 2 },
            Backend::Piper { mode: Mode::Network },
        ];
        let rows = compare(&backends, &exp, &raw).unwrap();
        // PIPER's sim speedup over a real measured CPU on tiny data is
        // not meaningful; just check plumbing.
        assert!(rows.iter().all(|r| r.rows_per_sec > 0.0));
    }

    #[test]
    fn format_mismatch_rejected() {
        let backend = Backend::Cpu { kind: ConfigKind::I, threads: 1 };
        assert!(!backend.accepts(InputFormat::Binary));
        let b3 = Backend::Cpu { kind: ConfigKind::III, threads: 1 };
        assert!(!b3.accepts(InputFormat::Utf8));
        assert!(b3.accepts(InputFormat::Binary));
    }
}
