//! The coordinator: unified backend dispatch + the experiment driver the
//! CLI and the bench harness share.
//!
//! A [`Backend`] is one of the platforms the paper compares (Fig. 9 /
//! Table 3): the multithreaded CPU baseline, the GPU model, or PIPER in
//! its three modes. [`run_backend`] executes any of them over the same
//! raw buffer and returns a [`RunSummary`] with uniformly-tagged timings,
//! which [`compare`] assembles into the paper's comparison rows.

use std::time::Duration;

use crate::accel::{self, InputFormat, Mode, PiperConfig};
use crate::cpu_baseline::{self, BaselineConfig, ConfigKind};
use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::gpu_sim::{self, GpuInput, GpuModel};
use crate::ops::Modulus;
use crate::report::TimeTag;
use crate::Result;

/// A platform under comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Meta's pipeline, `threads` threads, one of Configs I/II/III.
    Cpu { kind: ConfigKind, threads: usize },
    /// RAPIDS-style GPU model.
    Gpu,
    /// PIPER — local or network, decode placement per mode.
    Piper { mode: Mode },
}

impl Backend {
    pub fn name(&self) -> String {
        match self {
            Backend::Cpu { kind, threads } => format!("CPU-{threads} {}", kind.name()),
            Backend::Gpu => "GPU (V100 model)".to_string(),
            Backend::Piper { mode } => format!("PIPER {}", mode.name()),
        }
    }

    /// Which raw format this backend consumes for a given experiment
    /// input format.
    pub fn accepts(&self, input: InputFormat) -> bool {
        match self {
            // Google-cloud CPU config cannot take binary (paper Table 2) —
            // modeled by ConfigKind::III being the only binary consumer.
            Backend::Cpu { kind, .. } => match input {
                InputFormat::Utf8 => !kind.binary_input(),
                InputFormat::Binary => kind.binary_input(),
            },
            _ => true,
        }
    }
}

/// Uniform result of one backend run.
#[derive(Debug)]
pub struct RunSummary {
    pub backend: String,
    pub processed: ProcessedColumns,
    pub rows: usize,
    pub e2e: Duration,
    pub tag: TimeTag,
    /// Pure-computation time (Table 3 scope) where defined.
    pub compute: Option<Duration>,
}

impl RunSummary {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        self.rows as f64 / self.e2e.as_secs_f64().max(1e-12)
    }

    pub fn compute_rows_per_sec(&self) -> Option<f64> {
        self.compute
            .map(|c| self.rows as f64 / c.as_secs_f64().max(1e-12))
    }
}

/// Shared experiment parameters.
#[derive(Debug, Clone, Copy)]
pub struct Experiment {
    pub schema: Schema,
    pub modulus: Modulus,
    pub input: InputFormat,
}

impl Experiment {
    pub fn new(modulus: Modulus, input: InputFormat) -> Self {
        Experiment { schema: Schema::CRITEO, modulus, input }
    }
}

/// Execute one backend over a raw buffer.
pub fn run_backend(backend: &Backend, exp: &Experiment, raw: &[u8]) -> Result<RunSummary> {
    anyhow::ensure!(
        backend.accepts(exp.input),
        "{} does not accept {:?} input",
        backend.name(),
        exp.input
    );
    match backend {
        Backend::Cpu { kind, threads } => {
            let mut cfg = BaselineConfig::new(*kind, *threads, exp.modulus);
            cfg.schema = exp.schema;
            let run = cpu_baseline::run(&cfg, raw);
            let has_sim = run.times.total() > run.times.sif.measured
                + run.times.gen_vocab.measured
                + run.times.apply_vocab.measured
                + run.times.concat.measured;
            Ok(RunSummary {
                backend: backend.name(),
                rows: run.rows,
                e2e: run.times.total(),
                tag: if has_sim { TimeTag::Mixed } else { TimeTag::Measured },
                compute: Some(run.times.compute()),
                processed: run.processed,
            })
        }
        Backend::Gpu => {
            let input = match exp.input {
                InputFormat::Utf8 => GpuInput::Utf8,
                InputFormat::Binary => GpuInput::Binary,
            };
            let run = gpu_sim::run(&GpuModel::default(), exp.schema, exp.modulus, input, raw)?;
            Ok(RunSummary {
                backend: backend.name(),
                rows: run.rows,
                e2e: run.breakdown.total(),
                tag: TimeTag::Sim,
                compute: Some(run.breakdown.total() - run.breakdown.convert),
                processed: run.processed,
            })
        }
        Backend::Piper { mode } => {
            let mut cfg = PiperConfig::paper(*mode, exp.input, exp.modulus);
            cfg.schema = exp.schema;
            let run = accel::run(&cfg, raw)?;
            Ok(RunSummary {
                backend: backend.name(),
                rows: run.rows,
                e2e: run.e2e,
                tag: TimeTag::Sim,
                compute: Some(run.kernel.seconds()),
                processed: run.processed,
            })
        }
    }
}

/// One comparison row: backend vs the chosen reference.
#[derive(Debug)]
pub struct CompareRow {
    pub backend: String,
    pub e2e: Duration,
    pub tag: TimeTag,
    pub rows_per_sec: f64,
    pub speedup_vs_ref: f64,
}

/// Run several backends over the same input and compute speedups against
/// the *best CPU* entry (the paper's convention).
pub fn compare(
    backends: &[Backend],
    exp: &Experiment,
    raw: &[u8],
) -> Result<Vec<CompareRow>> {
    let mut runs = Vec::new();
    for b in backends {
        runs.push(run_backend(b, exp, raw)?);
    }
    // Functional cross-check: deterministic backends must agree.
    let reference_output = runs
        .iter()
        .find(|r| !r.backend.contains("Config II"))
        .map(|r| r.processed.clone());
    if let Some(expect) = &reference_output {
        for r in &runs {
            if !r.backend.contains("Config II") {
                anyhow::ensure!(
                    &r.processed == expect,
                    "backend {} produced different output",
                    r.backend
                );
            }
        }
    }
    let best_cpu = runs
        .iter()
        .filter(|r| r.backend.starts_with("CPU"))
        .map(|r| r.e2e)
        .min()
        .unwrap_or_else(|| {
            runs.iter().map(|r| r.e2e).max().unwrap_or(Duration::from_secs(1))
        });
    Ok(runs
        .iter()
        .map(|r| CompareRow {
            backend: r.backend.clone(),
            e2e: r.e2e,
            tag: r.tag,
            rows_per_sec: r.e2e_rows_per_sec(),
            speedup_vs_ref: best_cpu.as_secs_f64() / r.e2e.as_secs_f64().max(1e-12),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};

    #[test]
    fn all_backends_agree_functionally() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let exp = Experiment { schema: ds.schema(), ..Experiment::new(Modulus::new(997), InputFormat::Utf8) };
        let raw = utf8::encode_dataset(&ds);
        let backends = vec![
            Backend::Cpu { kind: ConfigKind::I, threads: 2 },
            Backend::Gpu,
            Backend::Piper { mode: Mode::Network },
            Backend::Piper { mode: Mode::LocalDecodeInKernel },
        ];
        let rows = compare(&backends, &exp, &raw).unwrap();
        assert_eq!(rows.len(), 4);
    }

    #[test]
    fn binary_experiment_runs() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let exp = Experiment { schema: ds.schema(), ..Experiment::new(Modulus::new(499), InputFormat::Binary) };
        let raw = binary::encode_dataset(&ds);
        let backends = vec![
            Backend::Cpu { kind: ConfigKind::III, threads: 2 },
            Backend::Piper { mode: Mode::Network },
        ];
        let rows = compare(&backends, &exp, &raw).unwrap();
        // PIPER's sim speedup over a real measured CPU on tiny data is
        // not meaningful; just check plumbing.
        assert!(rows.iter().all(|r| r.rows_per_sec > 0.0));
    }

    #[test]
    fn format_mismatch_rejected() {
        let backend = Backend::Cpu { kind: ConfigKind::I, threads: 1 };
        assert!(!backend.accepts(InputFormat::Binary));
        let b3 = Backend::Cpu { kind: ConfigKind::III, threads: 1 };
        assert!(!b3.accepts(InputFormat::Utf8));
        assert!(b3.accepts(InputFormat::Binary));
    }
}
