//! Minibatch assembly from preprocessed column storage — the boundary
//! where the preprocessing pipeline's output becomes training input
//! ("ML models require complete rows as the input", paper §2.3).

use crate::data::row::ProcessedColumns;
use crate::Result;

use super::Batch;

/// Cycling minibatch iterator over [`ProcessedColumns`] (wraps around —
/// an epoch boundary is `rows/batch` calls).
#[derive(Debug)]
pub struct BatchIter<'a> {
    data: &'a ProcessedColumns,
    batch: usize,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a ProcessedColumns, batch: usize, num_sparse: usize) -> Result<Self> {
        anyhow::ensure!(batch > 0, "batch size must be positive");
        anyhow::ensure!(
            data.num_rows() >= batch,
            "need at least one batch of rows ({} < {batch})",
            data.num_rows()
        );
        anyhow::ensure!(
            data.sparse.len() == num_sparse,
            "dataset has {} sparse columns, model wants {num_sparse}",
            data.sparse.len()
        );
        Ok(BatchIter { data, batch, cursor: 0 })
    }

    /// Assemble the next row-major batch (wrapping).
    pub fn next_batch(&mut self) -> Batch {
        let n = self.data.num_rows();
        let nd = self.data.dense.len();
        let ns = self.data.sparse.len();
        let mut dense = Vec::with_capacity(self.batch * nd);
        let mut sparse = Vec::with_capacity(self.batch * ns);
        let mut labels = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            let r = (self.cursor + i) % n;
            for c in 0..nd {
                dense.push(self.data.dense[c][r]);
            }
            for c in 0..ns {
                sparse.push(self.data.sparse[c][r] as i32);
            }
            labels.push(self.data.labels[r] as f32);
        }
        self.cursor = (self.cursor + self.batch) % n;
        Batch { dense, sparse, labels }
    }

    /// Batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.data.num_rows() / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::row::ProcessedRow;
    use crate::data::Schema;

    fn columns(rows: usize) -> ProcessedColumns {
        let mut c = ProcessedColumns::with_schema(Schema::new(2, 3));
        for r in 0..rows {
            c.push_row(&ProcessedRow {
                label: (r % 2) as i32,
                dense: vec![r as f32, r as f32 + 0.5],
                sparse: vec![r as u32, r as u32 + 1, r as u32 + 2],
            });
        }
        c
    }

    #[test]
    fn batch_is_row_major() {
        let cols = columns(10);
        let mut it = BatchIter::new(&cols, 4, 3).unwrap();
        let b = it.next_batch();
        assert_eq!(b.dense.len(), 8);
        assert_eq!(b.sparse.len(), 12);
        assert_eq!(b.labels.len(), 4);
        // row 1's dense features are at positions [2..4]
        assert_eq!(&b.dense[2..4], &[1.0, 1.5]);
        assert_eq!(&b.sparse[3..6], &[1, 2, 3]);
    }

    #[test]
    fn wraps_around() {
        let cols = columns(5);
        let mut it = BatchIter::new(&cols, 4, 3).unwrap();
        let _ = it.next_batch();
        let b = it.next_batch(); // rows 4,0,1,2
        assert_eq!(b.labels[0], 0.0); // row 4
        assert_eq!(b.dense[0], 4.0);
        assert_eq!(b.dense[2], 0.0); // row 0
    }

    #[test]
    fn validates_shapes() {
        let cols = columns(3);
        assert!(BatchIter::new(&cols, 4, 3).is_err(), "too few rows");
        assert!(BatchIter::new(&cols, 2, 5).is_err(), "wrong sparse count");
        assert!(BatchIter::new(&cols, 0, 3).is_err(), "zero batch");
    }

    #[test]
    fn batches_per_epoch() {
        let cols = columns(10);
        let it = BatchIter::new(&cols, 4, 3).unwrap();
        assert_eq!(it.batches_per_epoch(), 2);
    }
}
