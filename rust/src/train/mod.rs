//! The training consumer: a DLRM (paper §2.2) whose fwd/bwd + SGD step
//! was authored in JAX (with Pallas kernels for the interaction and MLP
//! hot-spots), AOT-lowered by `python/compile/aot.py`, and is executed
//! here through PJRT. This is the GPU-side of paper Fig. 1/2 — the
//! consumer the preprocessing pipeline must keep fed.
//!
//! Parameters are carried as ONE flat f32 vector across the rust↔XLA
//! boundary (the jax side unflattens with static shapes), so the rust
//! driver needs no knowledge of the model's pytree.

pub mod batch;

use std::path::Path;

use crate::config::Config;
use crate::data::row::ProcessedColumns;
use crate::runtime::{lit, LoadedFn, Runtime};
use crate::Result;

pub use batch::BatchIter;

/// Metadata written by aot.py next to the artifacts.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub batch: usize,
    pub num_dense: usize,
    pub num_sparse: usize,
    pub embed_dim: usize,
    pub vocab: usize,
    pub param_count: usize,
}

impl ModelMeta {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        Self::load_suffixed(artifacts_dir, "")
    }

    /// Load a batch-variant meta file (`meta_b128.txt` etc. — written by
    /// `aot.py --batch-variants`).
    pub fn load_suffixed(artifacts_dir: &Path, suffix: &str) -> Result<Self> {
        let cfg = Config::from_file(&artifacts_dir.join(format!("meta{suffix}.txt")))?;
        Ok(ModelMeta {
            batch: cfg.get_usize("batch", 0)?,
            num_dense: cfg.get_usize("num_dense", 0)?,
            num_sparse: cfg.get_usize("num_sparse", 0)?,
            embed_dim: cfg.get_usize("embed_dim", 0)?,
            vocab: cfg.get_usize("vocab", 0)?,
            param_count: cfg.get_usize("param_count", 0)?,
        })
    }
}

/// One minibatch in the layout the artifacts expect.
#[derive(Debug, Clone)]
pub struct Batch {
    /// `[B, num_dense]` row-major.
    pub dense: Vec<f32>,
    /// `[B, num_sparse]` row-major vocabulary indices.
    pub sparse: Vec<i32>,
    /// `[B]` click labels as f32.
    pub labels: Vec<f32>,
}

/// The training driver.
pub struct Trainer {
    pub meta: ModelMeta,
    step_fn: LoadedFn,
    forward_fn: Option<LoadedFn>,
    params: xla::Literal,
    steps_done: usize,
}

impl Trainer {
    /// Load artifacts and initialize parameters (by running the AOT
    /// `init` computation — deterministic, seeded at lowering time).
    pub fn new(runtime: &Runtime, artifacts_dir: &Path) -> Result<Self> {
        Self::with_suffix(runtime, artifacts_dir, "")
    }

    /// Load a batch-variant artifact set (suffix `_b128` etc.).
    pub fn with_suffix(runtime: &Runtime, artifacts_dir: &Path, suffix: &str) -> Result<Self> {
        let meta = ModelMeta::load_suffixed(artifacts_dir, suffix)?;
        let init_fn = runtime.load(&format!("init{suffix}.hlo.txt"))?;
        let step_fn = runtime.load(&format!("train_step{suffix}.hlo.txt"))?;
        let forward_fn = runtime.load(&format!("forward{suffix}.hlo.txt")).ok();
        let mut out = init_fn.call(&[])?;
        anyhow::ensure!(out.len() == 1, "init must return exactly the flat params");
        let params = out.remove(0);
        anyhow::ensure!(
            params.element_count() == meta.param_count,
            "init returned {} params, meta says {}",
            params.element_count(),
            meta.param_count
        );
        Ok(Trainer { meta, step_fn, forward_fn, params, steps_done: 0 })
    }

    /// Run one SGD step; returns the loss.
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let b = self.meta.batch as i64;
        anyhow::ensure!(
            batch.dense.len() == self.meta.batch * self.meta.num_dense
                && batch.sparse.len() == self.meta.batch * self.meta.num_sparse
                && batch.labels.len() == self.meta.batch,
            "batch shape mismatch (expected B={b})"
        );
        let dense = lit::f32_tensor(&batch.dense, &[b, self.meta.num_dense as i64])?;
        let sparse = lit::i32_tensor(&batch.sparse, &[b, self.meta.num_sparse as i64])?;
        let labels = lit::f32_tensor(&batch.labels, &[b])?;
        let mut out = self.step_fn.call(&[
            self.params.clone(),
            dense,
            sparse,
            labels,
        ])?;
        anyhow::ensure!(out.len() == 2, "train_step must return (params, loss)");
        let loss = lit::scalar_f32(&out[1])?;
        self.params = out.remove(0);
        self.steps_done += 1;
        Ok(loss)
    }

    /// Forward pass (inference) over one batch; returns probabilities.
    pub fn forward(&self, batch: &Batch) -> Result<Vec<f32>> {
        let f = self
            .forward_fn
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("forward artifact not built"))?;
        let b = self.meta.batch as i64;
        let dense = lit::f32_tensor(&batch.dense, &[b, self.meta.num_dense as i64])?;
        let sparse = lit::i32_tensor(&batch.sparse, &[b, self.meta.num_sparse as i64])?;
        let out = f.call(&[self.params.clone(), dense, sparse])?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading preds: {e:?}"))
    }

    pub fn steps_done(&self) -> usize {
        self.steps_done
    }
}

/// Train for `steps` steps cycling over the preprocessed dataset; returns
/// the loss curve.
pub fn train_loop(
    trainer: &mut Trainer,
    data: &ProcessedColumns,
    steps: usize,
) -> Result<Vec<f32>> {
    let mut iter = BatchIter::new(data, trainer.meta.batch, trainer.meta.num_sparse)?;
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let batch = iter.next_batch();
        losses.push(trainer.step(&batch)?);
    }
    Ok(losses)
}
