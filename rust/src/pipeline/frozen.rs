//! The frozen half of the freeze → serve lifecycle: ApplyVocab-only
//! execution against pinned vocabularies.
//!
//! A [`FrozenPlan`] is a [`ChunkState`] whose vocabularies were rebuilt
//! from a [`VocabArtifact`]'s appearance-ordered key lists and are never
//! observed again — [`FrozenPlan::apply_block`] takes `&self`, so the
//! GenVocab stage is gone by construction, not by convention. Because
//! it runs the *same* [`ChunkState::process`] hot loop the batch
//! two-pass path runs, a frozen apply is bit-identical to batch
//! ApplyVocab over the same vocabulary state; the serving equivalence
//! suite pins this for every wire format and miss policy.
//!
//! What batch execution never has to decide — what to do with a key the
//! training pass never saw — serving must: [`MissPolicy`] makes the
//! choice explicit per plan. [`MissPolicy::Sentinel`] keeps the engine's
//! [`VOCAB_MISS`] marker (the embedding layer owns the fallback),
//! [`MissPolicy::DefaultIndex`] rewrites misses to a pinned in-range
//! index (the classic "OOV bucket"), and [`MissPolicy::RejectRow`] drops
//! the whole row and reports it — for pipelines where a partial feature
//! vector is worse than no answer.

use std::fmt;
use std::path::Path;

use crate::data::row::ProcessedColumns;
use crate::data::{RowBlock, Schema};
use crate::ops::artifact::{schema_hash, spec_hash, VocabArtifact};
use crate::ops::{HashVocab, PipelineSpec, Vocab, VOCAB_MISS};
use crate::Result;

use super::{ChunkState, Plan};

/// What a frozen plan does with a sparse key outside its pinned
/// vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissPolicy {
    /// Emit [`VOCAB_MISS`] (`u32::MAX`) and count the miss — the
    /// consumer decides what the sentinel means.
    Sentinel,
    /// Rewrite every miss to this index (an out-of-vocabulary bucket
    /// the embedding table reserves).
    DefaultIndex(u32),
    /// Drop rows containing any miss from the response and count them.
    RejectRow,
}

impl MissPolicy {
    /// Parse the CLI/wire spelling: `sentinel`, `default:<index>`, or
    /// `reject`.
    pub fn parse(s: &str) -> Result<MissPolicy> {
        let s = s.trim().to_ascii_lowercase();
        if let Some(idx) = s.strip_prefix("default:") {
            let idx: u32 = idx
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("miss policy `default:` index: {e}"))?;
            anyhow::ensure!(idx != VOCAB_MISS, "default index collides with the miss sentinel");
            return Ok(MissPolicy::DefaultIndex(idx));
        }
        match s.as_str() {
            "sentinel" => Ok(MissPolicy::Sentinel),
            "reject" | "reject-row" => Ok(MissPolicy::RejectRow),
            other => anyhow::bail!(
                "unknown miss policy `{other}` (want sentinel | default:<index> | reject)"
            ),
        }
    }

    /// Wire form: a tag byte plus the default index (0 when unused).
    pub fn to_wire(self) -> (u8, u32) {
        match self {
            MissPolicy::Sentinel => (0, 0),
            MissPolicy::DefaultIndex(d) => (1, d),
            MissPolicy::RejectRow => (2, 0),
        }
    }

    pub fn from_wire(tag: u8, default: u32) -> Result<MissPolicy> {
        match tag {
            0 => Ok(MissPolicy::Sentinel),
            1 => Ok(MissPolicy::DefaultIndex(default)),
            2 => Ok(MissPolicy::RejectRow),
            other => anyhow::bail!("unknown miss policy wire tag {other}"),
        }
    }
}

/// `Display` is the inverse of [`MissPolicy::parse`].
impl fmt::Display for MissPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MissPolicy::Sentinel => write!(f, "sentinel"),
            MissPolicy::DefaultIndex(d) => write!(f, "default:{d}"),
            MissPolicy::RejectRow => write!(f, "reject"),
        }
    }
}

/// The result of one frozen apply: the transformed columns plus the
/// miss accounting the serving report aggregates.
#[derive(Debug, Clone, PartialEq)]
pub struct ApplyOutcome {
    pub columns: ProcessedColumns,
    /// Vocabulary misses seen in ApplyVocab columns (counted under
    /// every policy, including the rows RejectRow then dropped).
    pub misses: u64,
    /// Rows dropped by [`MissPolicy::RejectRow`]; 0 under the other
    /// policies.
    pub rejected_rows: u64,
}

/// An ApplyVocab-only execution plan over read-only vocabularies.
#[derive(Debug)]
pub struct FrozenPlan {
    state: ChunkState,
    spec: PipelineSpec,
    policy: MissPolicy,
}

impl FrozenPlan {
    /// Rebuild frozen per-column vocabularies from appearance-ordered
    /// key lists (the artifact's payload): observing key *k* as the
    /// *i*-th distinct value assigns it index *i* — exactly the
    /// assignment the original GenVocab pass made. Duplicate keys in a
    /// column mean the list is not a valid appearance order; refuse.
    pub fn new(
        spec: PipelineSpec,
        schema: Schema,
        keys: Vec<Vec<u32>>,
        policy: MissPolicy,
    ) -> Result<FrozenPlan> {
        let programs = spec.compile(schema)?;
        anyhow::ensure!(
            keys.len() == schema.num_sparse,
            "{} vocabulary columns for a schema with {} sparse columns",
            keys.len(),
            schema.num_sparse
        );
        let mut state = ChunkState::with_programs(programs);
        for (c, (vocab, col)) in state.vocabs.iter_mut().zip(keys.iter()).enumerate() {
            let mut v = HashVocab::with_capacity(col.len());
            for &k in col {
                v.observe(k);
            }
            anyhow::ensure!(
                v.len() == col.len(),
                "column {c}: duplicate keys in the frozen vocabulary"
            );
            *vocab = v;
        }
        Ok(FrozenPlan { state, spec, policy })
    }

    /// Freeze straight from a validated artifact (the hashes were
    /// checked when the artifact decoded).
    pub fn from_artifact(artifact: &VocabArtifact, policy: MissPolicy) -> Result<FrozenPlan> {
        let keys = artifact.vocabs().to_vec();
        FrozenPlan::new(artifact.spec().clone(), artifact.schema(), keys, policy)
    }

    /// Load an artifact file and freeze it.
    pub fn load(path: &Path, policy: MissPolicy) -> Result<FrozenPlan> {
        FrozenPlan::from_artifact(&VocabArtifact::load(path)?, policy)
    }

    pub fn schema(&self) -> Schema {
        self.state.schema()
    }

    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    pub fn policy(&self) -> MissPolicy {
        self.policy
    }

    pub fn vocab_entries(&self) -> usize {
        self.state.vocab_entries()
    }

    /// Content hashes for validating this plan against an artifact —
    /// the same functions the artifact layer stores.
    pub fn spec_hash(&self) -> u64 {
        spec_hash(&self.spec)
    }

    pub fn schema_hash(&self) -> u64 {
        schema_hash(self.schema())
    }

    /// ApplyVocab-only execution of one decoded chunk. Runs the exact
    /// batch pass-2 hot loop ([`ChunkState::process`], which emits
    /// [`VOCAB_MISS`] for unknown keys), then resolves misses per the
    /// plan's policy. `&self`: no vocabulary mutation is reachable.
    pub fn apply_block(&self, block: &RowBlock) -> ApplyOutcome {
        let mut columns = self.state.process(block);
        let mut misses = 0u64;
        let mut rejected_rows = 0u64;
        // Only ApplyVocab columns can carry the sentinel *as a marker* —
        // in passthrough/modulus-only columns u32::MAX is a legitimate
        // value and must not be touched.
        let vocab_cols: Vec<usize> = (0..self.schema().num_sparse)
            .filter(|&c| self.state.programs.sparse[c].apply_vocab)
            .collect();
        match self.policy {
            MissPolicy::Sentinel => {
                for &c in &vocab_cols {
                    misses += columns.sparse[c].iter().filter(|&&v| v == VOCAB_MISS).count() as u64;
                }
            }
            MissPolicy::DefaultIndex(d) => {
                for &c in &vocab_cols {
                    for v in &mut columns.sparse[c] {
                        if *v == VOCAB_MISS {
                            *v = d;
                            misses += 1;
                        }
                    }
                }
            }
            MissPolicy::RejectRow => {
                let mut reject = vec![false; columns.num_rows()];
                for &c in &vocab_cols {
                    for (r, &v) in columns.sparse[c].iter().enumerate() {
                        if v == VOCAB_MISS {
                            misses += 1;
                            reject[r] = true;
                        }
                    }
                }
                rejected_rows = reject.iter().filter(|&&r| r).count() as u64;
                if rejected_rows > 0 {
                    filter_rows(&mut columns.labels, &reject);
                    for col in &mut columns.dense {
                        filter_rows(col, &reject);
                    }
                    for col in &mut columns.sparse {
                        filter_rows(col, &reject);
                    }
                }
            }
        }
        ApplyOutcome { columns, misses, rejected_rows }
    }
}

/// Drop the marked rows from one column, preserving order.
fn filter_rows<T: Copy>(xs: &mut Vec<T>, reject: &[bool]) {
    let mut r = 0;
    xs.retain(|_| {
        let keep = !reject[r];
        r += 1;
        keep
    });
}

impl Plan {
    /// Freeze this plan's spec with explicit vocabulary keys (normally
    /// the [`crate::ops::Vocab`] `export_keys` of a finished GenVocab
    /// pass) into an ApplyVocab-only [`FrozenPlan`].
    pub fn freeze(&self, keys: Vec<Vec<u32>>, policy: MissPolicy) -> Result<FrozenPlan> {
        FrozenPlan::new(self.spec.clone(), self.schema(), keys, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::InputFormat;
    use crate::data::row::DecodedRow;
    use crate::data::{SynthConfig, SynthDataset};

    /// Two-pass batch state over the training set → export → freeze →
    /// apply must equal the batch pass-2 output exactly.
    #[test]
    fn frozen_apply_is_bit_identical_to_batch_pass2() {
        let ds = SynthDataset::generate(SynthConfig::small(260));
        let block = RowBlock::from_rows(&ds.rows, ds.schema());
        for spec in [
            "modulus:97|genvocab|applyvocab|neg2zero|logarithm",
            "sparse[*]: modulus:997|genvocab|applyvocab; sparse[1]: modulus:29; \
             dense[*]: neg2zero|log",
        ] {
            let plan = Plan::compile(
                PipelineSpec::parse(spec).unwrap(),
                ds.schema(),
                InputFormat::Utf8,
                4096,
            )
            .unwrap();
            let mut batch = ChunkState::new(&plan);
            batch.observe(&block);
            let want = batch.process(&block);

            let keys: Vec<Vec<u32>> = batch.vocabs.iter().map(|v| v.export_keys()).collect();
            let frozen = plan.freeze(keys, MissPolicy::Sentinel).unwrap();
            assert_eq!(frozen.vocab_entries(), batch.vocab_entries(), "{spec}");
            let got = frozen.apply_block(&block);
            assert_eq!(got.columns, want, "{spec}");
            assert_eq!(got.misses, 0, "{spec}: training keys cannot miss");
            assert_eq!(got.rejected_rows, 0, "{spec}");
        }
    }

    fn tiny_frozen(policy: MissPolicy) -> FrozenPlan {
        // Pinned vocabulary {5→0, 12→1} on a 1-dense/1-sparse schema.
        let spec = PipelineSpec::parse("modulus:97|genvocab|applyvocab").unwrap();
        FrozenPlan::new(spec, Schema::new(1, 1), vec![vec![5, 12]], policy).unwrap()
    }

    fn request_block() -> RowBlock {
        // Sparse keys 12 (hit), 40 (miss), 5 (hit).
        let rows: Vec<DecodedRow> = [(0, 12u32), (1, 40), (0, 5)]
            .iter()
            .map(|&(label, s)| DecodedRow { label, dense: vec![7], sparse: vec![s] })
            .collect();
        RowBlock::from_rows(&rows, Schema::new(1, 1))
    }

    #[test]
    fn sentinel_policy_marks_and_counts() {
        let out = tiny_frozen(MissPolicy::Sentinel).apply_block(&request_block());
        assert_eq!(out.columns.sparse[0], vec![1, VOCAB_MISS, 0]);
        assert_eq!((out.misses, out.rejected_rows), (1, 0));
    }

    #[test]
    fn default_index_policy_rewrites() {
        let out = tiny_frozen(MissPolicy::DefaultIndex(0)).apply_block(&request_block());
        assert_eq!(out.columns.sparse[0], vec![1, 0, 0]);
        assert_eq!((out.misses, out.rejected_rows), (1, 0));
    }

    #[test]
    fn reject_row_policy_drops_whole_rows() {
        let out = tiny_frozen(MissPolicy::RejectRow).apply_block(&request_block());
        assert_eq!(out.columns.num_rows(), 2);
        assert_eq!(out.columns.sparse[0], vec![1, 0]);
        assert_eq!(out.columns.labels, vec![0, 0]);
        assert_eq!((out.misses, out.rejected_rows), (1, 1));
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        let spec = PipelineSpec::parse("modulus:97|genvocab|applyvocab").unwrap();
        let err = FrozenPlan::new(spec, Schema::new(1, 1), vec![vec![3, 3]], MissPolicy::Sentinel);
        assert!(err.is_err());
    }

    #[test]
    fn column_count_mismatch_is_rejected() {
        let spec = PipelineSpec::parse("modulus:97|genvocab|applyvocab").unwrap();
        let err = FrozenPlan::new(spec, Schema::new(1, 2), vec![vec![]], MissPolicy::Sentinel);
        assert!(err.is_err());
    }

    #[test]
    fn policy_parse_display_round_trips() {
        for s in ["sentinel", "default:7", "reject"] {
            let p = MissPolicy::parse(s).unwrap();
            assert_eq!(p.to_string(), s);
            let (tag, d) = p.to_wire();
            assert_eq!(MissPolicy::from_wire(tag, d).unwrap(), p);
        }
        assert_eq!(MissPolicy::parse("reject-row").unwrap(), MissPolicy::RejectRow);
        assert!(MissPolicy::parse("default:").is_err());
        assert!(MissPolicy::parse(&format!("default:{}", u32::MAX)).is_err());
        assert!(MissPolicy::parse("banana").is_err());
        assert!(MissPolicy::from_wire(9, 0).is_err());
    }

    #[test]
    fn miss_sentinel_in_passthrough_columns_is_untouched() {
        // A modulus-free passthrough column can legitimately hold
        // u32::MAX — RejectRow must not drop those rows.
        let spec = PipelineSpec::parse(
            "sparse[0]: modulus:97|genvocab|applyvocab; sparse[1]: fillmissing",
        )
        .unwrap();
        let frozen =
            FrozenPlan::new(spec, Schema::new(1, 2), vec![vec![5], vec![]], MissPolicy::RejectRow)
                .unwrap();
        let rows = vec![DecodedRow { label: 1, dense: vec![0], sparse: vec![5, u32::MAX] }];
        let out = frozen.apply_block(&RowBlock::from_rows(&rows, Schema::new(1, 2)));
        assert_eq!(out.columns.num_rows(), 1);
        assert_eq!(out.columns.sparse[1], vec![u32::MAX]);
        assert_eq!((out.misses, out.rejected_rows), (0, 0));
    }
}
