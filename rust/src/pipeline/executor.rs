//! The executor side of the engine: the trait every backend implements,
//! plus the shared functional core all executors delegate to.
//!
//! Implementations live with their backends:
//!
//! * [`crate::cpu_baseline::CpuExecutor`] — Meta's row-partitioned
//!   multithreaded pipeline, really measured on this machine;
//! * [`crate::gpu_sim::GpuExecutor`] — RAPIDS-style column pipeline with
//!   the V100-calibrated timing model (tagged sim);
//! * [`crate::accel::PiperExecutor`] — the PIPER dataflow in its three
//!   modes (local decode-in-kernel, local decode-in-host, network), with
//!   the paper's cycle model (tagged sim).
//!
//! All executors share [`ChunkState`] for the operator semantics, so
//! their outputs are bit-identical by construction; what differs is
//! parallelism and the timing model. Chunks arrive as column-major
//! [`RowBlock`]s, so GenVocab/ApplyVocab run as tight loops over
//! contiguous column slices; row sharding (the CPU baseline) is range
//! slicing of the block, not row object shuffling.

use std::ops::Range;
use std::time::Duration;

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::RowBlock;
use crate::data::Schema;
use crate::ops::{log1p, neg2zero, HashVocab, Modulus, OpFlags, Vocab};
use crate::report::TimeTag;
use crate::Result;

use super::Plan;

/// A preprocessing backend that can execute a planned operator graph
/// over a stream of decoded chunks. Stateless and reusable: each
/// submission gets its own [`ExecutorRun`] from [`Executor::begin`].
pub trait Executor: Send + Sync {
    /// Display name (stable — reports and the comparison tables key on it).
    fn name(&self) -> String;

    /// Can this executor consume `input`? Checked at planning time.
    fn accepts(&self, input: InputFormat) -> bool;

    /// Executor-specific plan validation (e.g. PIPER's SRAM capacity
    /// check). Runs once, at [`super::PipelineBuilder::build`].
    fn plan_check(&self, _plan: &Plan) -> Result<()> {
        Ok(())
    }

    /// Start one submission over the given plan.
    fn begin(&self, plan: &Plan) -> Result<Box<dyn ExecutorRun>>;
}

/// Per-submission executor state, driven by the engine:
/// `observe`* (pass 1, only when the plan builds vocabularies) → `seal`
/// → `process`* (pass 2) → `finish`. Chunks are borrowed column-major
/// blocks — the engine reuses one scratch block per pass, so executors
/// must not hold on to them across calls.
pub trait ExecutorRun: Send {
    /// Pass 1: observe a decoded chunk (GenVocab).
    fn observe(&mut self, block: &RowBlock) -> Result<()>;

    /// Barrier between the passes (merge/freeze vocabulary state).
    fn seal(&mut self) -> Result<()> {
        Ok(())
    }

    /// Pass 2: process a decoded chunk into a column block.
    fn process(&mut self, block: &RowBlock) -> Result<ProcessedColumns>;

    /// End of submission; `stats` carries the engine's stream totals for
    /// the timing models.
    fn finish(&mut self, stats: &StreamStats) -> Result<ExecutorReport>;
}

/// Stream totals the engine accumulates over one submission.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    /// Raw bytes of one full pass over the source.
    pub raw_bytes: u64,
    pub rows: u64,
    pub chunks: u64,
    /// Wallclock of the whole submission, measured by the engine.
    pub wall: Duration,
}

/// What an executor reports at the end of a submission.
#[derive(Debug, Clone, Copy)]
pub struct ExecutorReport {
    pub tag: TimeTag,
    /// Modeled end-to-end time; `None` = use the engine's measured wall
    /// clock (measured executors).
    pub modeled_e2e: Option<Duration>,
    /// Pure-computation time (the paper's Table 3 scope) where defined.
    pub compute: Option<Duration>,
    pub vocab_entries: usize,
}

/// The shared functional core: the planned operator graph over decoded
/// column blocks. Semantics match [`crate::ops::PipelineSpec::execute`]
/// exactly — sparse: Modulus → (GenVocab → ApplyVocab) as configured,
/// dense: Neg2Zero / Logarithm as configured — applied streamingly with
/// insertion-ordered vocabularies. Every loop scans a contiguous column
/// slice; per-column vocabularies make the column visit order
/// irrelevant, so the columnar scan assigns exactly the indices the old
/// row-wise scan did.
#[derive(Debug)]
pub struct ChunkState {
    pub schema: Schema,
    pub flags: OpFlags,
    pub modulus: Option<Modulus>,
    pub vocabs: Vec<HashVocab>,
}

impl ChunkState {
    pub fn new(plan: &Plan) -> Self {
        ChunkState {
            schema: plan.schema,
            flags: plan.flags,
            modulus: plan.modulus,
            vocabs: (0..plan.schema.num_sparse).map(|_| HashVocab::new()).collect(),
        }
    }

    /// Pass-1 GenVocab over a chunk: one tight loop per sparse column.
    pub fn observe(&mut self, block: &RowBlock) {
        if !self.flags.gen_vocab {
            return;
        }
        for (c, vocab) in self.vocabs.iter_mut().enumerate() {
            let col = block.sparse_col(c);
            match self.modulus {
                Some(m) => {
                    for &s in col {
                        vocab.observe(m.apply(s));
                    }
                }
                None => vocab.observe_slice(col),
            }
        }
    }

    /// Build private per-column sub-dictionaries over a row range of the
    /// block — the threaded GV of the CPU baseline, per chunk shard.
    pub fn observe_sub(&self, block: &RowBlock, range: Range<usize>) -> Vec<HashVocab> {
        let mut subs: Vec<HashVocab> =
            (0..self.schema.num_sparse).map(|_| HashVocab::new()).collect();
        for (c, sub) in subs.iter_mut().enumerate() {
            let col = &block.sparse_col(c)[range.clone()];
            match self.modulus {
                Some(m) => {
                    for &s in col {
                        sub.observe(m.apply(s));
                    }
                }
                None => sub.observe_slice(col),
            }
        }
        subs
    }

    /// Merge sub-dictionaries in shard order — deterministically
    /// equivalent to a sequential scan (the same argument the CPU
    /// baseline's §2.3 merge relies on).
    pub fn merge_subs(&mut self, subs: &[Vec<HashVocab>]) {
        for set in subs {
            for (v, sub) in self.vocabs.iter_mut().zip(set.iter()) {
                v.merge_from(sub);
            }
        }
    }

    /// Pass-2: process a whole chunk into a column block (ApplyVocab +
    /// dense finishing).
    pub fn process(&self, block: &RowBlock) -> ProcessedColumns {
        self.process_range(block, 0..block.num_rows())
    }

    /// Pass-2 over a row range of the block — the shard form the CPU
    /// baseline's threads use. Slicing at any partition boundary and
    /// concatenating shard outputs in order equals [`Self::process`] of
    /// the whole block.
    pub fn process_range(&self, block: &RowBlock, range: Range<usize>) -> ProcessedColumns {
        let mut out = ProcessedColumns::with_schema(self.schema);
        out.labels.extend_from_slice(&block.labels()[range.clone()]);
        for (c, dst) in out.dense.iter_mut().enumerate() {
            let col = &block.dense_col(c)[range.clone()];
            dst.reserve(col.len());
            for &d in col {
                let v = if self.flags.neg2zero { neg2zero(d) } else { d };
                dst.push(if self.flags.logarithm { log1p(v) } else { v as f32 });
            }
        }
        for (c, dst) in out.sparse.iter_mut().enumerate() {
            let col = &block.sparse_col(c)[range.clone()];
            dst.reserve(col.len());
            let vocab = &self.vocabs[c];
            for &s in col {
                let v = self.modulus.map_or(s, |m| m.apply(s));
                dst.push(if self.flags.apply_vocab { vocab.apply(v).unwrap_or(0) } else { v });
            }
        }
        out
    }

    pub fn vocab_entries(&self) -> usize {
        self.vocabs.iter().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthConfig, SynthDataset};
    use crate::ops::PipelineSpec;

    fn plan(spec: &str) -> Plan {
        super::super::PipelineBuilder::plan_only(
            PipelineSpec::parse(spec).unwrap(),
            Schema::CRITEO,
            InputFormat::Utf8,
            4096,
        )
    }

    #[test]
    fn chunked_observe_equals_sub_merge() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let block = RowBlock::from_rows(&ds.rows, ds.schema());
        let p = plan("modulus:97|genvocab|applyvocab");
        let mut seq = ChunkState::new(&p);
        seq.observe(&block);

        let mut sharded = ChunkState::new(&p);
        let mut subs = Vec::new();
        let mut start = 0;
        while start < block.num_rows() {
            let end = (start + 77).min(block.num_rows());
            subs.push(sharded.observe_sub(&block, start..end));
            start = end;
        }
        sharded.merge_subs(&subs);

        assert_eq!(seq.vocab_entries(), sharded.vocab_entries());
        assert_eq!(seq.process(&block), sharded.process(&block));
    }

    #[test]
    fn process_matches_spec_execute() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let spec = PipelineSpec::dlrm(997);
        let reference = spec.execute(&ds.rows, ds.schema()).unwrap();

        let p = super::super::PipelineBuilder::plan_only(
            spec,
            ds.schema(),
            InputFormat::Utf8,
            4096,
        );
        let mut state = ChunkState::new(&p);
        let chunks: Vec<RowBlock> = ds
            .rows
            .chunks(31)
            .map(|c| RowBlock::from_rows(c, ds.schema()))
            .collect();
        for chunk in &chunks {
            state.observe(chunk);
        }
        let mut got = ProcessedColumns::with_schema(ds.schema());
        for chunk in &chunks {
            got.extend_from(&state.process(chunk));
        }
        assert_eq!(got, reference);
    }

    #[test]
    fn range_slicing_matches_whole_block() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let block = RowBlock::from_rows(&ds.rows, ds.schema());
        let p = plan("modulus:97|genvocab|applyvocab");
        let mut state = ChunkState::new(&p);
        state.observe(&block);
        let whole = state.process(&block);
        for parts in [1usize, 2, 3, 7] {
            let mut glued = ProcessedColumns::with_schema(ds.schema());
            for r in crate::cpu_baseline::pipeline::partition_rows(block.num_rows(), parts) {
                glued.extend_from(&state.process_range(&block, r));
            }
            assert_eq!(glued, whole, "{parts} shards");
        }
    }
}
