//! The executor side of the engine: the trait every backend implements,
//! plus the shared functional core all executors delegate to.
//!
//! Implementations live with their backends:
//!
//! * [`crate::cpu_baseline::CpuExecutor`] — Meta's row-partitioned
//!   multithreaded pipeline, really measured on this machine;
//! * [`crate::gpu_sim::GpuExecutor`] — RAPIDS-style column pipeline with
//!   the V100-calibrated timing model (tagged sim);
//! * [`crate::accel::PiperExecutor`] — the PIPER dataflow in its three
//!   modes (local decode-in-kernel, local decode-in-host, network), with
//!   the paper's cycle model (tagged sim).
//!
//! All executors share [`ChunkState`] for the operator semantics, so
//! their outputs are bit-identical by construction; what differs is
//! parallelism and the timing model.

use std::time::Duration;

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::DecodedRow;
use crate::data::Schema;
use crate::ops::{log1p, neg2zero, HashVocab, Modulus, OpFlags, Vocab};
use crate::report::TimeTag;
use crate::Result;

use super::Plan;

/// A preprocessing backend that can execute a planned operator graph
/// over a stream of decoded-row chunks. Stateless and reusable: each
/// submission gets its own [`ExecutorRun`] from [`Executor::begin`].
pub trait Executor: Send + Sync {
    /// Display name (stable — reports and the comparison tables key on it).
    fn name(&self) -> String;

    /// Can this executor consume `input`? Checked at planning time.
    fn accepts(&self, input: InputFormat) -> bool;

    /// Executor-specific plan validation (e.g. PIPER's SRAM capacity
    /// check). Runs once, at [`super::PipelineBuilder::build`].
    fn plan_check(&self, _plan: &Plan) -> Result<()> {
        Ok(())
    }

    /// Start one submission over the given plan.
    fn begin(&self, plan: &Plan) -> Result<Box<dyn ExecutorRun>>;
}

/// Per-submission executor state, driven by the engine:
/// `observe`* (pass 1, only when the plan builds vocabularies) → `seal`
/// → `process`* (pass 2) → `finish`.
pub trait ExecutorRun: Send {
    /// Pass 1: observe a chunk of decoded rows (GenVocab).
    fn observe(&mut self, rows: &[DecodedRow]) -> Result<()>;

    /// Barrier between the passes (merge/freeze vocabulary state).
    fn seal(&mut self) -> Result<()> {
        Ok(())
    }

    /// Pass 2: process a chunk into a column block.
    fn process(&mut self, rows: &[DecodedRow]) -> Result<ProcessedColumns>;

    /// End of submission; `stats` carries the engine's stream totals for
    /// the timing models.
    fn finish(&mut self, stats: &StreamStats) -> Result<ExecutorReport>;
}

/// Stream totals the engine accumulates over one submission.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    /// Raw bytes of one full pass over the source.
    pub raw_bytes: u64,
    pub rows: u64,
    pub chunks: u64,
    /// Wallclock of the whole submission, measured by the engine.
    pub wall: Duration,
}

/// What an executor reports at the end of a submission.
#[derive(Debug, Clone, Copy)]
pub struct ExecutorReport {
    pub tag: TimeTag,
    /// Modeled end-to-end time; `None` = use the engine's measured wall
    /// clock (measured executors).
    pub modeled_e2e: Option<Duration>,
    /// Pure-computation time (the paper's Table 3 scope) where defined.
    pub compute: Option<Duration>,
    pub vocab_entries: usize,
}

/// The shared functional core: the planned operator graph over decoded
/// rows. Semantics match [`crate::ops::PipelineSpec::execute`] exactly —
/// sparse: Modulus → (GenVocab → ApplyVocab) as configured, dense:
/// Neg2Zero / Logarithm as configured — applied streamingly with
/// insertion-ordered vocabularies.
#[derive(Debug)]
pub struct ChunkState {
    pub schema: Schema,
    pub flags: OpFlags,
    pub modulus: Option<Modulus>,
    pub vocabs: Vec<HashVocab>,
}

impl ChunkState {
    pub fn new(plan: &Plan) -> Self {
        ChunkState {
            schema: plan.schema,
            flags: plan.flags,
            modulus: plan.modulus,
            vocabs: (0..plan.schema.num_sparse).map(|_| HashVocab::new()).collect(),
        }
    }

    /// Pass-1 GenVocab over a chunk, in row order.
    pub fn observe(&mut self, rows: &[DecodedRow]) {
        if !self.flags.gen_vocab {
            return;
        }
        for row in rows {
            for (c, &s) in row.sparse.iter().enumerate() {
                let v = self.modulus.map_or(s, |m| m.apply(s));
                self.vocabs[c].observe(v);
            }
        }
    }

    /// Build private per-column sub-dictionaries over a row range — the
    /// threaded GV of the CPU baseline, per chunk.
    pub fn observe_sub(&self, rows: &[DecodedRow]) -> Vec<HashVocab> {
        let mut subs: Vec<HashVocab> =
            (0..self.schema.num_sparse).map(|_| HashVocab::new()).collect();
        for row in rows {
            for (c, &s) in row.sparse.iter().enumerate() {
                let v = self.modulus.map_or(s, |m| m.apply(s));
                subs[c].observe(v);
            }
        }
        subs
    }

    /// Merge sub-dictionaries in shard order — deterministically
    /// equivalent to a sequential scan (the same argument the CPU
    /// baseline's §2.3 merge relies on).
    pub fn merge_subs(&mut self, subs: &[Vec<HashVocab>]) {
        for set in subs {
            for (v, sub) in self.vocabs.iter_mut().zip(set.iter()) {
                v.merge_from(sub);
            }
        }
    }

    /// Pass-2: process a chunk into a column block (ApplyVocab + dense
    /// finishing).
    pub fn process(&self, rows: &[DecodedRow]) -> ProcessedColumns {
        let mut out = ProcessedColumns::with_schema(self.schema);
        out.labels.reserve(rows.len());
        for row in rows {
            out.labels.push(row.label);
            for (c, &d) in row.dense.iter().enumerate() {
                let v = if self.flags.neg2zero { neg2zero(d) } else { d };
                let v = if self.flags.logarithm { log1p(v) } else { v as f32 };
                out.dense[c].push(v);
            }
            for (c, &s) in row.sparse.iter().enumerate() {
                let v = self.modulus.map_or(s, |m| m.apply(s));
                let v = if self.flags.apply_vocab {
                    self.vocabs[c].apply(v).unwrap_or(0)
                } else {
                    v
                };
                out.sparse[c].push(v);
            }
        }
        out
    }

    pub fn vocab_entries(&self) -> usize {
        self.vocabs.iter().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthConfig, SynthDataset};
    use crate::ops::PipelineSpec;

    fn plan(spec: &str) -> Plan {
        super::super::PipelineBuilder::plan_only(
            PipelineSpec::parse(spec).unwrap(),
            Schema::CRITEO,
            InputFormat::Utf8,
            4096,
        )
    }

    #[test]
    fn chunked_observe_equals_sub_merge() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let p = plan("modulus:97|genvocab|applyvocab");
        let mut seq = ChunkState::new(&p);
        seq.observe(&ds.rows);

        let mut sharded = ChunkState::new(&p);
        let subs: Vec<Vec<HashVocab>> = ds
            .rows
            .chunks(77)
            .map(|c| sharded.observe_sub(c))
            .collect();
        sharded.merge_subs(&subs);

        assert_eq!(seq.vocab_entries(), sharded.vocab_entries());
        assert_eq!(seq.process(&ds.rows), sharded.process(&ds.rows));
    }

    #[test]
    fn process_matches_spec_execute() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let spec = PipelineSpec::dlrm(997);
        let reference = spec.execute(&ds.rows, ds.schema()).unwrap();

        let p = super::super::PipelineBuilder::plan_only(
            spec,
            ds.schema(),
            InputFormat::Utf8,
            4096,
        );
        let mut state = ChunkState::new(&p);
        for chunk in ds.rows.chunks(31) {
            state.observe(chunk);
        }
        let mut got = ProcessedColumns::with_schema(ds.schema());
        for chunk in ds.rows.chunks(31) {
            got.extend_from(&state.process(chunk));
        }
        assert_eq!(got, reference);
    }
}
