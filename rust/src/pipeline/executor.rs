//! The executor side of the engine: the trait every backend implements,
//! plus the shared functional core all executors delegate to.
//!
//! Implementations live with their backends:
//!
//! * [`crate::cpu_baseline::CpuExecutor`] — Meta's row-partitioned
//!   multithreaded pipeline, really measured on this machine;
//! * [`crate::gpu_sim::GpuExecutor`] — RAPIDS-style column pipeline with
//!   the V100-calibrated timing model (tagged sim);
//! * [`crate::accel::PiperExecutor`] — the PIPER dataflow in its three
//!   modes (local decode-in-kernel, local decode-in-host, network), with
//!   the paper's cycle model (tagged sim).
//!
//! All executors share [`ChunkState`] for the operator semantics, so
//! their outputs are bit-identical by construction; what differs is
//! parallelism and the timing model. Chunks arrive as column-major
//! [`RowBlock`]s, so GenVocab/ApplyVocab run as tight loops over
//! contiguous column slices; row sharding (the CPU baseline) is range
//! slicing of the block, not row object shuffling.
//!
//! ## The two execution strategies
//!
//! The trait is built around **fused** single-pass execution
//! ([`ExecutorRun::process_observing`]): every chunk is observed *and*
//! emitted in one scan, appearance indices assigned on the fly with
//! [`Vocab::observe_apply`] — exactly the bitmap+counter dataflow
//! PIPER's GenVocab-1/ApplyVocab-1 PEs implement in hardware. The
//! classic **two-pass** protocol (`observe`* → [`ExecutorRun::seal`] →
//! `process`*) remains for plans that need a global barrier before any
//! output is produced (the distributed leader-merge path) or for
//! executors that cannot fuse. Both strategies are bit-identical by
//! construction: an appearance index is fixed at first appearance, so
//! assigning it during the first scan or after it yields the same
//! value — [`super::PipelineBuilder::build`] picks the strategy from
//! [`Executor::supports_fused`] and the equivalence suite pins the
//! identity for every backend.
//!
//! ## The stage-split API (pipelined fused execution)
//!
//! A fused chunk decomposes into two phases with disjoint state: the
//! **stateless** phase (labels, dense finishing, vocab-free sparse
//! programs — reads only the immutable compiled programs) and the
//! **vocab** phase (the sequential in-order observe/apply scan — the
//! only writer of vocabulary state). [`ExecutorRun::stages`] surfaces
//! that split as a [`FusedStages`] pair of closures so the engine can
//! run chunk N+1's decode+stateless work *concurrently* with chunk N's
//! vocab scan ([`super::PipelineBuilder::pipeline_depth`]). Ordering:
//! the engine calls `vocab` strictly in chunk order from one thread —
//! appearance indices are fixed at first appearance, so the pipelined
//! schedule stays bit-identical to the sequential fused pass.

use std::ops::Range;
use std::time::Duration;

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::RowBlock;
use crate::data::Schema;
use crate::ops::{ColumnPlans, HashVocab, Vocab, VOCAB_MISS};
use crate::report::TimeTag;
use crate::Result;

use super::{Plan, Sink};

/// A preprocessing backend that can execute a planned operator graph
/// over a stream of decoded chunks. Stateless and reusable: each
/// submission gets its own [`ExecutorRun`] from [`Executor::begin`].
pub trait Executor: Send + Sync {
    /// Display name (stable — reports and the comparison tables key on it).
    fn name(&self) -> String;

    /// Can this executor consume `input`? Checked at planning time.
    fn accepts(&self, input: InputFormat) -> bool;

    /// Can this executor run `plan` in the fused single-pass mode
    /// ([`ExecutorRun::process_observing`])? Checked at planning time:
    /// [`super::PipelineBuilder::build`] picks
    /// [`super::ExecStrategy::Fused`] when it can, and refuses a forced
    /// fused build when it can't. Default: no (two-pass only).
    fn supports_fused(&self, _plan: &Plan) -> bool {
        false
    }

    /// Executor-specific plan validation (e.g. PIPER's SRAM capacity
    /// check). Runs once, at [`super::PipelineBuilder::build`].
    fn plan_check(&self, _plan: &Plan) -> Result<()> {
        Ok(())
    }

    /// Start one submission over the given plan.
    fn begin(&self, plan: &Plan) -> Result<Box<dyn ExecutorRun>>;
}

/// Per-submission executor state, driven by the engine in one of two
/// call patterns chosen by the plan's [`super::ExecStrategy`]:
///
/// * **fused** — `process_observing`* → `finish`: one decode pass, no
///   barrier, output streams to the sink while vocabularies build;
/// * **two-pass** — `observe`* (only when the plan builds
///   vocabularies) → `seal` → `process`* → `finish`.
///
/// Chunks are borrowed column-major blocks — the engine reuses one
/// scratch block per pass, so executors must not hold on to them across
/// calls.
pub trait ExecutorRun: Send {
    /// Fused single pass: observe the chunk's sparse values *and* emit
    /// the processed block in the same scan, pushing output to `sink`.
    /// Appearance indices are assigned on the fly
    /// ([`Vocab::observe_apply`]) and must be bit-identical to the
    /// two-pass result. Executors that cannot fuse
    /// ([`Executor::supports_fused`] = false) are never called here and
    /// may bail.
    fn process_observing(&mut self, block: &RowBlock, sink: &mut dyn Sink) -> Result<()>;

    /// Two-pass, pass 1: observe a decoded chunk (GenVocab).
    fn observe(&mut self, block: &RowBlock) -> Result<()>;

    /// Two-pass barrier between the passes (merge/freeze vocabulary
    /// state). Never called under the fused strategy — there is no
    /// barrier to cross.
    fn seal(&mut self) -> Result<()> {
        Ok(())
    }

    /// Two-pass, pass 2: process a decoded chunk into a column block.
    fn process(&mut self, block: &RowBlock) -> Result<ProcessedColumns>;

    /// End of submission; `stats` carries the engine's stream totals for
    /// the timing models.
    fn finish(&mut self, stats: &StreamStats) -> Result<ExecutorReport>;

    /// Split this run's fused pass into its stateless and vocab stages
    /// ([`FusedStages`]) so the engine's stage-pipelined scheduler can
    /// overlap them across chunks. `None` (the default) means the run
    /// cannot be stage-split and the engine falls back to driving
    /// [`Self::process_observing`] chunk-at-a-time. Only meaningful
    /// under the fused strategy; the engine calls it at most once per
    /// submission, and a run driven through its stages never sees
    /// `process_observing`.
    fn stages(&mut self) -> Option<FusedStages<'_>> {
        None
    }
}

/// The fused pass of one [`ExecutorRun`], split into the two stages the
/// engine's pipelined scheduler drives independently (see the module
/// docs). Both closures borrow disjoint halves of the run
/// ([`ChunkState::stage_split`]), which is what makes the overlap safe:
///
/// * `stateless` — stage (b): labels + dense finishing + vocab-free
///   sparse programs over a decoded chunk. Touches no vocabulary state
///   (`Fn`, `Sync`), so the engine may call it from the decode stage
///   thread while `vocab` is mid-scan on an *earlier* chunk.
/// * `vocab` — stage (c): the sequential in-order observe/apply scan
///   filling the vocabulary columns of the stateless stage's output.
///   The engine calls it from exactly one thread, strictly in chunk
///   order (the per-stage ordering lock) — the invariant that keeps
///   appearance-order index assignment bit-identical.
///
/// A vocabulary-free plan still splits cleanly: `vocab` degenerates to
/// a structural no-op (every column was already filled by `stateless`),
/// so the pipeline uniformly overlaps decode with processing.
pub struct FusedStages<'r> {
    pub stateless: Box<dyn Fn(&RowBlock) -> ProcessedColumns + Send + Sync + 'r>,
    pub vocab: Box<dyn FnMut(&RowBlock, &mut ProcessedColumns) + Send + 'r>,
}

/// Stream totals the engine accumulates over one submission.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    /// Raw bytes of one full pass over the source.
    pub raw_bytes: u64,
    pub rows: u64,
    pub chunks: u64,
    /// Wallclock of the whole submission, measured by the engine.
    pub wall: Duration,
    /// Engine-measured busy time of the stateless stage when the run
    /// was driven through [`ExecutorRun::stages`] (zero otherwise —
    /// then the executor timed its own phases inside
    /// `process_observing`). Executors fold it into their
    /// `process_time` at [`ExecutorRun::finish`].
    pub stateless_time: Duration,
    /// Engine-measured busy time of the ordered vocab stage under
    /// pipelined driving (zero otherwise). Executors fold it into
    /// their `observe_time` at [`ExecutorRun::finish`] — it *is* the
    /// GenVocab work, scheduled by the engine.
    pub vocab_time: Duration,
}

/// What an executor reports at the end of a submission.
#[derive(Debug, Clone, Copy)]
pub struct ExecutorReport {
    pub tag: TimeTag,
    /// Modeled end-to-end time; `None` = use the engine's measured wall
    /// clock (measured executors).
    pub modeled_e2e: Option<Duration>,
    /// Pure-computation time (the paper's Table 3 scope) where defined.
    pub compute: Option<Duration>,
    /// Measured wallclock spent in GenVocab-attributable work: the
    /// whole observe pass under two-pass, the sequential vocab-assign
    /// stage under fused (zero where the executor fuses inseparably).
    /// Always measured host time — even for sim-tagged executors, where
    /// it times the functional evaluation, not the model.
    pub observe_time: Duration,
    /// Measured wallclock spent emitting output (two-pass pass 2, or
    /// the fused pass minus any separable vocab stage).
    pub process_time: Duration,
    pub vocab_entries: usize,
}

/// The shared functional core: the plan's compiled per-column programs
/// ([`ColumnPlans`]) over decoded column blocks. Semantics match
/// [`crate::ops::PipelineSpec::execute`] exactly — each sparse column
/// runs its own Modulus → (GenVocab → ApplyVocab) slot, each dense
/// column its own kernel chain — applied streamingly with
/// insertion-ordered vocabularies. Every loop scans a contiguous column
/// slice and dispatches on that column's fixed-function slot (no global
/// flags); per-column vocabularies make the column visit order
/// irrelevant, so the columnar scan assigns exactly the indices the old
/// row-wise scan did.
#[derive(Debug)]
pub struct ChunkState {
    pub programs: ColumnPlans,
    pub vocabs: Vec<HashVocab>,
}

/// Where one sparse column's vocabulary indices come from on the
/// disaggregated service path ([`ChunkState::vocab_slots`]): columns
/// whose vocabulary lives on this worker sequence locally; columns
/// owned elsewhere batch their keys to the owner and splice the
/// returned indices in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VocabSlot {
    /// No vocabulary state (modulus-only / passthrough).
    Stateless,
    /// Vocabulary is owned by this worker; `apply` mirrors the
    /// column's ApplyVocab stage (false = build-only).
    Resident { apply: bool },
    /// Vocabulary is owned by another worker; keys are forwarded.
    Remote { apply: bool },
}

impl ChunkState {
    pub fn new(plan: &Plan) -> Self {
        Self::with_programs(plan.programs.clone())
    }

    /// Build from compiled programs directly (the net worker's path —
    /// it has no engine [`Plan`], just the job's compiled spec).
    pub fn with_programs(programs: ColumnPlans) -> Self {
        let n = programs.schema.num_sparse;
        ChunkState { programs, vocabs: (0..n).map(|_| HashVocab::new()).collect() }
    }

    pub fn schema(&self) -> Schema {
        self.programs.schema
    }

    /// Does any column of the plan build a vocabulary?
    pub fn has_gen_vocab(&self) -> bool {
        self.programs.any_gen_vocab()
    }

    /// Classify every sparse column's vocabulary slot for a service
    /// worker that owns the columns in `owned`: owned columns sequence
    /// indices locally, remote columns forward their keys to the
    /// owning worker. Single-node executors never call this — all
    /// their columns are trivially resident.
    pub fn vocab_slots(&self, owned: impl Fn(usize) -> bool) -> Vec<VocabSlot> {
        self.programs
            .sparse
            .iter()
            .enumerate()
            .map(|(c, slot)| {
                if !slot.gen_vocab {
                    VocabSlot::Stateless
                } else if owned(c) {
                    VocabSlot::Resident { apply: slot.apply_vocab }
                } else {
                    VocabSlot::Remote { apply: slot.apply_vocab }
                }
            })
            .collect()
    }

    /// Pass-1 GenVocab over a chunk: one tight loop per vocabulary-
    /// building sparse column (columns without GenVocab are skipped).
    pub fn observe(&mut self, block: &RowBlock) {
        for (c, vocab) in self.vocabs.iter_mut().enumerate() {
            let slot = &self.programs.sparse[c];
            if !slot.gen_vocab {
                continue;
            }
            let col = block.sparse_col(c);
            match slot.modulus {
                Some(m) => {
                    for &s in col {
                        vocab.observe(m.apply(s));
                    }
                }
                None => vocab.observe_slice(col),
            }
        }
    }

    /// Build private per-column sub-dictionaries over a row range of the
    /// block — the threaded GV of the CPU baseline, per chunk shard.
    pub fn observe_sub(&self, block: &RowBlock, range: Range<usize>) -> Vec<HashVocab> {
        let mut subs: Vec<HashVocab> =
            (0..self.schema().num_sparse).map(|_| HashVocab::new()).collect();
        for (c, sub) in subs.iter_mut().enumerate() {
            let slot = &self.programs.sparse[c];
            if !slot.gen_vocab {
                continue;
            }
            let col = &block.sparse_col(c)[range.clone()];
            match slot.modulus {
                Some(m) => {
                    for &s in col {
                        sub.observe(m.apply(s));
                    }
                }
                None => sub.observe_slice(col),
            }
        }
        subs
    }

    /// Merge sub-dictionaries in shard order — deterministically
    /// equivalent to a sequential scan (the same argument the CPU
    /// baseline's §2.3 merge relies on).
    pub fn merge_subs(&mut self, subs: &[Vec<HashVocab>]) {
        for set in subs {
            for (v, sub) in self.vocabs.iter_mut().zip(set.iter()) {
                v.merge_from(sub);
            }
        }
    }

    /// Pass-2: process a whole chunk into a column block (ApplyVocab +
    /// dense finishing).
    pub fn process(&self, block: &RowBlock) -> ProcessedColumns {
        self.process_range(block, 0..block.num_rows())
    }

    /// Pass-2 over a row range of the block — the shard form the CPU
    /// baseline's threads use. Slicing at any partition boundary and
    /// concatenating shard outputs in order equals [`Self::process`] of
    /// the whole block.
    pub fn process_range(&self, block: &RowBlock, range: Range<usize>) -> ProcessedColumns {
        let mut out = self.process_stateless_range(block, range.clone());
        for (c, dst) in out.sparse.iter_mut().enumerate() {
            let slot = &self.programs.sparse[c];
            if slot.is_stateless() {
                continue; // filled by the stateless stage above
            }
            let col = &block.sparse_col(c)[range.clone()];
            let start = dst.len();
            dst.resize(start + col.len(), 0);
            let dst = &mut dst[start..];
            let vocab = &self.vocabs[c];
            if slot.apply_vocab {
                for (&s, o) in col.iter().zip(dst.iter_mut()) {
                    *o = vocab.apply(slot.map(s)).unwrap_or(VOCAB_MISS);
                }
            } else {
                // GenVocab without ApplyVocab: the vocabulary builds,
                // raw modulus values pass through.
                for (&s, o) in col.iter().zip(dst.iter_mut()) {
                    *o = slot.map(s);
                }
            }
        }
        out
    }

    /// The stateless slice of pass 2 over a row range: labels, dense
    /// finishing, and the sparse columns whose program touches no
    /// vocabulary (modulus-only / passthrough —
    /// [`crate::ops::SparseColPlan::is_stateless`]); the vocabulary
    /// columns are left empty. Shardable across threads
    /// in *both* strategies because no vocabulary state is touched; the
    /// fused CPU executor runs this in parallel and fills the remaining
    /// sparse planes with the sequential [`Self::fuse_sparse`] stage —
    /// so vocab-free columns of a heterogeneous plan keep scaling with
    /// threads even under the fused strategy.
    pub fn process_stateless_range(
        &self,
        block: &RowBlock,
        range: Range<usize>,
    ) -> ProcessedColumns {
        stateless_range(&self.programs, block, range)
    }

    /// Split this state into the stage-pipelined scheduler's two
    /// disjoint halves: the immutable compiled programs (shared with the
    /// stateless stage, which may run on another thread) and the mutable
    /// vocabularies (exclusive to the ordered vocab stage). The borrow
    /// split is what lets chunk N+1's stateless stage run while chunk N
    /// is inside the sequential vocab scan without aliasing vocabulary
    /// state — the foundation every [`super::ExecutorRun::stages`]
    /// implementation builds its [`FusedStages`] closures on.
    pub fn stage_split(&mut self) -> (&ColumnPlans, &mut [HashVocab]) {
        (&self.programs, &mut self.vocabs)
    }

    /// Fused sparse stage: one sequential in-order scan per
    /// **vocabulary** column that observes *and* emits — GenVocab-1's
    /// bitmap and ApplyVocab-1's counter in the same pass
    /// ([`Vocab::observe_apply`]). Appends `block.num_rows()` indices to
    /// each vocabulary column of `out` (stateless columns were already
    /// filled by [`Self::process_stateless_range`]); bit-identical to
    /// `observe(block)` followed by the sparse half of `process(block)`
    /// because appearance indices are fixed at first appearance.
    /// Inherently sequential per column — the reason the fused CPU path
    /// cannot shard this stage across threads, which is exactly the
    /// scaling wall §2.3 describes.
    pub fn fuse_sparse(&mut self, block: &RowBlock, out: &mut ProcessedColumns) {
        fuse_sparse_into(&self.programs, &mut self.vocabs, block, out);
    }

    /// Fused single pass over a whole chunk: stateless stage + fused
    /// sparse stage. Equals `observe(block)` then `process(block)`.
    pub fn process_fused(&mut self, block: &RowBlock) -> ProcessedColumns {
        let mut out = self.process_stateless_range(block, 0..block.num_rows());
        self.fuse_sparse(block, &mut out);
        out
    }

    pub fn vocab_entries(&self) -> usize {
        self.vocabs.iter().map(|v| v.len()).sum()
    }
}

/// Free-function form of [`ChunkState::process_stateless_range`],
/// operating on the programs half of a [`ChunkState::stage_split`] —
/// the body every stateless-stage closure runs, on whatever thread the
/// scheduler put it.
pub fn stateless_range(
    programs: &ColumnPlans,
    block: &RowBlock,
    range: Range<usize>,
) -> ProcessedColumns {
    let mut out = ProcessedColumns::with_schema(programs.schema);
    out.labels.extend_from_slice(&block.labels()[range.clone()]);
    for (c, dst) in out.dense.iter_mut().enumerate() {
        let col = &block.dense_col(c)[range.clone()];
        // each dense column runs its own compiled kernel chain (the
        // common chains are specialized inside `run`)
        programs.dense[c].run(col, dst);
    }
    for (c, dst) in out.sparse.iter_mut().enumerate() {
        let slot = &programs.sparse[c];
        if !slot.is_stateless() {
            continue; // the vocabulary stages fill this column
        }
        let col = &block.sparse_col(c)[range.clone()];
        dst.reserve(col.len());
        for &s in col {
            dst.push(slot.map(s));
        }
    }
    out
}

/// Free-function form of [`ChunkState::fuse_sparse`], operating on the
/// split borrows of [`ChunkState::stage_split`] — the body of every
/// vocab-stage closure. Must be called strictly in chunk order (it
/// assigns appearance indices).
pub fn fuse_sparse_into(
    programs: &ColumnPlans,
    vocabs: &mut [HashVocab],
    block: &RowBlock,
    out: &mut ProcessedColumns,
) {
    for (c, vocab) in vocabs.iter_mut().enumerate() {
        let slot = programs.sparse[c];
        if slot.is_stateless() {
            continue; // filled by the sharded stateless stage
        }
        let col = block.sparse_col(c);
        let dst = &mut out.sparse[c];
        let start = dst.len();
        dst.resize(start + col.len(), 0);
        let dst = &mut dst[start..];
        match (slot.gen_vocab, slot.apply_vocab) {
            (true, true) => {
                for (&s, o) in col.iter().zip(dst.iter_mut()) {
                    *o = vocab.observe_apply(slot.map(s));
                }
            }
            (true, false) => {
                for (&s, o) in col.iter().zip(dst.iter_mut()) {
                    let v = slot.map(s);
                    vocab.observe(v);
                    *o = v;
                }
            }
            (false, _) => {
                // Only ApplyVocab-without-GenVocab reaches here
                // (stateless columns were skipped above) — program
                // validation forbids the combination, so if it ever
                // slips through, emit the explicit miss sentinel
                // rather than aliasing index 0.
                for (&s, o) in col.iter().zip(dst.iter_mut()) {
                    *o = vocab.apply(slot.map(s)).unwrap_or(VOCAB_MISS);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthConfig, SynthDataset};
    use crate::ops::PipelineSpec;

    fn plan(spec: &str) -> Plan {
        Plan::compile(PipelineSpec::parse(spec).unwrap(), Schema::CRITEO, InputFormat::Utf8, 4096)
            .unwrap()
    }

    #[test]
    fn chunked_observe_equals_sub_merge() {
        let ds = SynthDataset::generate(SynthConfig::small(300));
        let block = RowBlock::from_rows(&ds.rows, ds.schema());
        let p = plan("modulus:97|genvocab|applyvocab");
        let mut seq = ChunkState::new(&p);
        seq.observe(&block);

        let mut sharded = ChunkState::new(&p);
        let mut subs = Vec::new();
        let mut start = 0;
        while start < block.num_rows() {
            let end = (start + 77).min(block.num_rows());
            subs.push(sharded.observe_sub(&block, start..end));
            start = end;
        }
        sharded.merge_subs(&subs);

        assert_eq!(seq.vocab_entries(), sharded.vocab_entries());
        assert_eq!(seq.process(&block), sharded.process(&block));
    }

    #[test]
    fn process_matches_spec_execute() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let spec = PipelineSpec::dlrm(997);
        let reference = spec.execute(&ds.rows, ds.schema()).unwrap();

        let p = Plan::compile(spec, ds.schema(), InputFormat::Utf8, 4096).unwrap();
        let mut state = ChunkState::new(&p);
        let chunks: Vec<RowBlock> = ds
            .rows
            .chunks(31)
            .map(|c| RowBlock::from_rows(c, ds.schema()))
            .collect();
        for chunk in &chunks {
            state.observe(chunk);
        }
        let mut got = ProcessedColumns::with_schema(ds.schema());
        for chunk in &chunks {
            got.extend_from(&state.process(chunk));
        }
        assert_eq!(got, reference);
    }

    /// The load-bearing identity of the fused strategy at the functional
    /// core: one fused scan == observe-all then process-all, chunk by
    /// chunk, for every flag combination.
    #[test]
    fn fused_scan_equals_two_pass_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(320));
        let chunks: Vec<RowBlock> =
            ds.rows.chunks(47).map(|c| RowBlock::from_rows(c, ds.schema())).collect();
        for spec in [
            "modulus:97|genvocab|applyvocab",
            "modulus:97|genvocab|applyvocab|neg2zero|logarithm",
            "modulus:97|genvocab",
            "modulus:53|neg2zero",
            // heterogeneous per-column programs fuse identically too:
            // mixed vocab sizes, a vocab-free column, partial dense log,
            // one clipped+bucketized column
            "sparse[*]: modulus:97|genvocab|applyvocab; \
             sparse[0..3]: modulus:13|genvocab|applyvocab; \
             sparse[3]: modulus:29; \
             dense[*]: neg2zero|logarithm; \
             dense[0]: clip:0:100|bucketize:1:10:100; \
             dense[1]: neg2zero",
        ] {
            let p = plan(spec);
            let mut two_pass = ChunkState::new(&p);
            for chunk in &chunks {
                two_pass.observe(chunk);
            }
            let mut want = ProcessedColumns::with_schema(ds.schema());
            for chunk in &chunks {
                want.extend_from(&two_pass.process(chunk));
            }

            let mut fused = ChunkState::new(&p);
            let mut got = ProcessedColumns::with_schema(ds.schema());
            for chunk in &chunks {
                got.extend_from(&fused.process_fused(chunk));
            }
            assert_eq!(got, want, "spec {spec}");
            assert_eq!(fused.vocab_entries(), two_pass.vocab_entries(), "spec {spec}");
        }
    }

    /// The streaming per-column state must match the spec's row-wise
    /// reference interpreter for a heterogeneous program set.
    #[test]
    fn heterogeneous_process_matches_spec_execute() {
        let ds = SynthDataset::generate(SynthConfig::small(230));
        let spec = PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             sparse[5]: modulus:53|genvocab; \
             dense[*]: neg2zero|logarithm; \
             dense[2]: clip:0:40|bucketize:2:8:32",
        )
        .unwrap();
        let reference = spec.execute(&ds.rows, ds.schema()).unwrap();

        let p = Plan::compile(spec, ds.schema(), InputFormat::Utf8, 4096).unwrap();
        let chunks: Vec<RowBlock> =
            ds.rows.chunks(37).map(|c| RowBlock::from_rows(c, ds.schema())).collect();

        // two-pass
        let mut state = ChunkState::new(&p);
        for chunk in &chunks {
            state.observe(chunk);
        }
        let mut two = ProcessedColumns::with_schema(ds.schema());
        for chunk in &chunks {
            two.extend_from(&state.process(chunk));
        }
        assert_eq!(two, reference, "two-pass");

        // fused
        let mut fused = ChunkState::new(&p);
        let mut got = ProcessedColumns::with_schema(ds.schema());
        for chunk in &chunks {
            got.extend_from(&fused.process_fused(chunk));
        }
        assert_eq!(got, reference, "fused");
    }

    /// Fused = sharded stateless stage + sequential sparse stage (the
    /// CPU executor's fused decomposition).
    #[test]
    fn fused_decomposition_stateless_shards_plus_sequential_sparse() {
        let ds = SynthDataset::generate(SynthConfig::small(211));
        let block = RowBlock::from_rows(&ds.rows, ds.schema());
        let p = plan("modulus:97|genvocab|applyvocab|neg2zero|logarithm");

        let mut whole = ChunkState::new(&p);
        let want = whole.process_fused(&block);

        let mut decomposed = ChunkState::new(&p);
        let mut out = ProcessedColumns::with_schema(ds.schema());
        for r in crate::cpu_baseline::pipeline::partition_rows(block.num_rows(), 4) {
            out.extend_from(&decomposed.process_stateless_range(&block, r));
        }
        decomposed.fuse_sparse(&block, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn range_slicing_matches_whole_block() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let block = RowBlock::from_rows(&ds.rows, ds.schema());
        let p = plan("modulus:97|genvocab|applyvocab");
        let mut state = ChunkState::new(&p);
        state.observe(&block);
        let whole = state.process(&block);
        for parts in [1usize, 2, 3, 7] {
            let mut glued = ProcessedColumns::with_schema(ds.schema());
            for r in crate::cpu_baseline::pipeline::partition_rows(block.num_rows(), parts) {
                glued.extend_from(&state.process_range(&block, r));
            }
            assert_eq!(glued, whole, "{parts} shards");
        }
    }
}
