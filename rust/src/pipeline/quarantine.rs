//! Replayable quarantine sink: the side file `on_error=quarantine`
//! writes contained rows to, and the [`Source`] that re-ingests it.
//!
//! # File format (version 1)
//!
//! ```text
//! magic  "PIPQRN01"                        8 bytes
//! format u8 (0 = utf8, 1 = binary)         1 byte
//! record*:
//!   row    u64le   stream-absolute row index of the contained row
//!   offset u64le   stream-absolute byte offset of the row's first byte
//!   kind   u8      RowErrorKind discriminant
//!   len    u32le   raw byte count (capped at MAX_QUARANTINE_ROW_BYTES)
//!   bytes  [u8; len]  the row exactly as it appeared in the input
//! ```
//!
//! Raw bytes are preserved verbatim (including the defect), so after an
//! upstream fix — a schema change, a relaxed field cap — the same file
//! replays through the engine via [`QuarantineSource`] with no
//! conversion step. Everything is little-endian, matching the wire
//! protocol of [`crate::net::protocol`].

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::accel::InputFormat;
use crate::decode::errors::QuarantineSummary;
use crate::decode::{QuarantinedRow, RowErrorKind};
use crate::pipeline::Source;
use crate::Result;

/// File magic + version of the quarantine side-file format.
pub const QUARANTINE_MAGIC: &[u8; 8] = b"PIPQRN01";

fn format_to_u8(format: InputFormat) -> u8 {
    match format {
        InputFormat::Utf8 => 0,
        InputFormat::Binary => 1,
    }
}

fn format_from_u8(b: u8) -> Result<InputFormat> {
    match b {
        0 => Ok(InputFormat::Utf8),
        1 => Ok(InputFormat::Binary),
        other => anyhow::bail!("quarantine file: unknown input format byte {other}"),
    }
}

/// Streaming writer for the quarantine side file. Created eagerly at
/// run start (a failing path should fail before any rows stream), fed
/// by the engine's containment drain, sealed by [`Self::finish`].
#[derive(Debug)]
pub struct QuarantineWriter {
    path: PathBuf,
    file: BufWriter<File>,
    rows: u64,
}

impl QuarantineWriter {
    pub fn create(path: &Path, format: InputFormat) -> Result<Self> {
        let file = File::create(path)
            .with_context(|| format!("creating quarantine file {}", path.display()))?;
        let mut file = BufWriter::new(file);
        file.write_all(QUARANTINE_MAGIC)?;
        file.write_all(&[format_to_u8(format)])?;
        Ok(QuarantineWriter { path: path.to_path_buf(), file, rows: 0 })
    }

    /// Append one contained row.
    pub fn write(&mut self, row: &QuarantinedRow) -> Result<()> {
        self.file.write_all(&row.row.to_le_bytes())?;
        self.file.write_all(&row.offset.to_le_bytes())?;
        self.file.write_all(&[row.kind.as_u8()])?;
        self.file.write_all(&(row.bytes.len() as u32).to_le_bytes())?;
        self.file.write_all(&row.bytes)?;
        self.rows += 1;
        Ok(())
    }

    /// Rows written so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Flush and seal the file; returns the summary carried into
    /// [`crate::pipeline::RunReport`].
    pub fn finish(mut self) -> Result<QuarantineSummary> {
        self.file
            .flush()
            .with_context(|| format!("flushing quarantine file {}", self.path.display()))?;
        Ok(QuarantineSummary { path: Some(self.path), rows: self.rows })
    }
}

/// A fully loaded quarantine side file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuarantineFile {
    pub format: InputFormat,
    pub rows: Vec<QuarantinedRow>,
}

impl QuarantineFile {
    pub fn load(path: &Path) -> Result<Self> {
        let mut raw = Vec::new();
        File::open(path)
            .with_context(|| format!("opening quarantine file {}", path.display()))?
            .read_to_end(&mut raw)?;
        anyhow::ensure!(
            raw.len() >= QUARANTINE_MAGIC.len() + 1 && raw.starts_with(QUARANTINE_MAGIC),
            "{} is not a quarantine file (bad magic)",
            path.display()
        );
        let format = format_from_u8(raw[8])?;
        let mut rows = Vec::new();
        let mut at = 9usize;
        while at < raw.len() {
            anyhow::ensure!(
                raw.len() - at >= 21,
                "quarantine file truncated mid-header at byte {at}"
            );
            let row = u64::from_le_bytes(raw[at..at + 8].try_into().unwrap());
            let offset = u64::from_le_bytes(raw[at + 8..at + 16].try_into().unwrap());
            let kind = RowErrorKind::from_u8(raw[at + 16])
                .with_context(|| format!("quarantine file: bad error kind at byte {at}"))?;
            let len = u32::from_le_bytes(raw[at + 17..at + 21].try_into().unwrap()) as usize;
            at += 21;
            anyhow::ensure!(
                raw.len() - at >= len,
                "quarantine file truncated mid-record at byte {at}"
            );
            rows.push(QuarantinedRow { row, offset, kind, bytes: raw[at..at + len].to_vec() });
            at += len;
        }
        Ok(QuarantineFile { format, rows })
    }
}

/// Replays a quarantine file through the engine as a rewindable
/// [`Source`]: record payloads are concatenated back into a byte
/// stream in containment order (UTF-8 rows get their terminating
/// newline restored if the defect consumed it).
#[derive(Debug)]
pub struct QuarantineSource {
    format: InputFormat,
    buf: Vec<u8>,
    pos: usize,
}

impl QuarantineSource {
    pub fn open(path: &Path) -> Result<Self> {
        let file = QuarantineFile::load(path)?;
        let mut buf = Vec::new();
        for row in &file.rows {
            buf.extend_from_slice(&row.bytes);
            if file.format == InputFormat::Utf8 && !row.bytes.ends_with(b"\n") {
                buf.push(b'\n');
            }
        }
        Ok(QuarantineSource { format: file.format, buf, pos: 0 })
    }
}

impl Source for QuarantineSource {
    fn format(&self) -> InputFormat {
        self.format
    }

    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
        buf.clear();
        if self.pos >= self.buf.len() {
            return Ok(false);
        }
        let end = (self.pos + max_bytes.max(1)).min(self.buf.len());
        buf.extend_from_slice(&self.buf[self.pos..end]);
        self.pos = end;
        Ok(true)
    }

    fn can_rewind(&self) -> bool {
        true
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.buf.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(row: u64, offset: u64, kind: RowErrorKind, bytes: &[u8]) -> QuarantinedRow {
        QuarantinedRow { row, offset, kind, bytes: bytes.to_vec() }
    }

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("piper-qrnt-{tag}-{}.bin", std::process::id()))
    }

    #[test]
    fn write_then_load_round_trips() {
        let path = temp_path("round");
        let rows = vec![
            row(3, 120, RowErrorKind::IllegalByte, b"1,2,x3\n"),
            row(9, 410, RowErrorKind::WrongFieldCount, b"only,two\n"),
            row(11, 502, RowErrorKind::NumericOverflow, b""),
        ];
        let mut w = QuarantineWriter::create(&path, InputFormat::Utf8).unwrap();
        for r in &rows {
            w.write(r).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.rows, 3);
        assert_eq!(summary.path.as_deref(), Some(path.as_path()));

        let file = QuarantineFile::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(file.format, InputFormat::Utf8);
        assert_eq!(file.rows, rows);
    }

    #[test]
    fn source_replays_bytes_and_rewinds() {
        let path = temp_path("replay");
        let mut w = QuarantineWriter::create(&path, InputFormat::Utf8).unwrap();
        w.write(&row(0, 0, RowErrorKind::IllegalByte, b"a,b\n")).unwrap();
        // A row whose trailing newline was consumed by the defect.
        w.write(&row(5, 99, RowErrorKind::WrongFieldCount, b"c,d")).unwrap();
        w.finish().unwrap();

        let mut src = QuarantineSource::open(&path).unwrap();
        assert_eq!(src.format(), InputFormat::Utf8);
        assert!(src.can_rewind());
        let mut all = Vec::new();
        let mut chunk = Vec::new();
        while src.next_chunk(3, &mut chunk).unwrap() {
            all.extend_from_slice(&chunk);
        }
        assert_eq!(all, b"a,b\nc,d\n");
        src.reset().unwrap();
        assert!(src.next_chunk(1024, &mut chunk).unwrap());
        assert_eq!(chunk, b"a,b\nc,d\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = temp_path("garbage");
        std::fs::write(&path, b"definitely not a quarantine file").unwrap();
        assert!(QuarantineFile::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
