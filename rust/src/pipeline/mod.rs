//! The composable streaming pipeline engine — the crate's execution API.
//!
//! The paper's central claim is that preprocessing must be *pipelined
//! and streamed* to keep accelerators fed. This module is that seam:
//!
//! ```text
//! Source ──raw Vec<u8>──▶ [bounded channel] ──decode──▶ RowBlock ──▶ Executor ──▶ Sink
//!    ▲                                                                 (columns)
//!    └────────────── recycled raw buffers (pool lane) ◀────────────────────┘
//! ```
//!
//! * a [`Source`] fills engine-recycled byte buffers with the raw
//!   dataset in bounded chunks (in-memory buffer, file, synthetic
//!   generator, one-shot reader, TCP stream); rewinding is an *optional
//!   capability* ([`Source::can_rewind`]) that only two-pass plans need;
//! * a [`Plan`] is built **once** by [`PipelineBuilder::build`] from an
//!   [`crate::ops::PipelineSpec`] plus backend capability checks — a
//!   format mismatch or an over-capacity vocabulary is a *planning*
//!   error, not a runtime failure inside a serving worker. Planning also
//!   fixes the [`ExecStrategy`]: **fused** (one decode pass, appearance
//!   indices assigned while streaming output — the paper's hardware
//!   dataflow) whenever the executor supports it, **two-pass** (GenVocab
//!   scan, rewind, ApplyVocab scan) when it doesn't or when a global
//!   vocabulary barrier is required (the distributed leader-merge path);
//! * the decoded-chunk currency is the column-major
//!   [`RowBlock`](crate::data::RowBlock): [`ChunkDecoder`] decodes every
//!   raw chunk into one reusable scratch block (no per-row allocation),
//!   and an [`Executor`] (CPU baseline, GPU model, the three PIPER
//!   modes) runs GenVocab/ApplyVocab as tight loops over its contiguous
//!   column slices; all executors share the same functional core, so
//!   outputs are bit-identical across backends;
//! * a [`Sink`] receives processed column blocks as they are produced,
//!   and a [`RunReport`] carries uniformly [`TimeTag`]-tagged results.
//!
//! Execution is chunked with a bounded producer/worker channel sized by
//! `chunk_rows` × [`PipelineBuilder::channel_depth`], so peak resident
//! raw-input memory is a few chunks — never the dataset — and a built
//! [`Pipeline`] can be reused across many submissions (the serving
//! posture the ROADMAP asks for). Two allocation-recycling loops keep
//! the steady state alloc-free: raw chunk buffers return to the
//! producer through a pool lane instead of being freed per chunk, and
//! each pass decodes into a single reusable [`RowBlock`] scratch.
//!
//! ```no_run
//! use piper::accel::InputFormat;
//! use piper::coordinator::Backend;
//! use piper::cpu_baseline::ConfigKind;
//! use piper::ops::PipelineSpec;
//! use piper::pipeline::{FileSource, PipelineBuilder};
//! use std::path::Path;
//!
//! # fn main() -> piper::Result<()> {
//! let pipeline = PipelineBuilder::new()
//!     .spec(PipelineSpec::dlrm(5_000))
//!     .input(InputFormat::Utf8)
//!     .chunk_rows(64 * 1024)
//!     .executor(Backend::Cpu { kind: ConfigKind::I, threads: 8 }.executor())
//!     .build()?; // planning errors surface here
//! let mut source = FileSource::open(Path::new("dataset.txt"), InputFormat::Utf8)?;
//! let (columns, report) = pipeline.run_collect(&mut source)?;
//! println!("{} rows at {:.0} rows/s", report.rows, report.e2e_rows_per_sec());
//! # Ok(()) }
//! ```

pub mod executor;
pub mod frozen;
pub mod quarantine;
pub mod sink;
pub mod source;

pub use executor::{
    ChunkState, Executor, ExecutorReport, ExecutorRun, FusedStages, StreamStats, VocabSlot,
};
pub use frozen::{ApplyOutcome, FrozenPlan, MissPolicy};
pub use quarantine::{QuarantineFile, QuarantineSource, QuarantineWriter};
pub use sink::{CollectSink, CountSink, Sink};
pub use source::{
    serve_bytes, FileSource, MemorySource, ReaderSource, Source, SynthSource, TcpSource,
};

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::{RowBlock, Schema};
use crate::decode::errors::QuarantineSummary;
use crate::decode::{
    shard, DataError, DecodeTally, ErrorBudget, ErrorConfig, ErrorPolicy, IllegalLog,
    QuarantinedRow, RowError, RowErrorKind, RowErrorLog, ShardedUtf8Decoder,
};
use crate::ops::{ColumnPlans, Modulus, PipelineSpec};
use crate::report::{self, TimeTag};
use crate::Result;

// ---------------------------------------------------------------------
// Incremental decode
// ---------------------------------------------------------------------

/// Knobs of the engine's decode front: how many row shards decode a
/// chunk in parallel ([`crate::decode::shard`]) and whether the SWAR
/// wide-word loop or the byte-at-a-time oracle loop runs per shard
/// (the latter exists for the ablation benches).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeOptions {
    /// Decode threads per UTF-8 chunk; 1 = today's sequential path.
    /// Binary input ignores this (its bulk column copy already runs at
    /// memcpy speed).
    pub threads: usize,
    /// SWAR wide-word hot loop (default) vs the scalar per-byte loop.
    pub swar: bool,
    /// Malformed-row containment: policy, error budget, and detail cap.
    pub errors: ErrorConfig,
}

impl Default for DecodeOptions {
    fn default() -> Self {
        DecodeOptions { threads: 1, swar: true, errors: ErrorConfig::default() }
    }
}

/// Incremental decoder that survives arbitrary chunk boundaries — the
/// decode front of the engine, also used by the network worker
/// ([`crate::net::stream`]).
#[derive(Debug)]
pub struct ChunkDecoder {
    inner: DecoderInner,
    cfg: ErrorConfig,
}

#[derive(Debug)]
enum DecoderInner {
    Utf8(ShardedUtf8Decoder),
    Binary {
        schema: Schema,
        partial: Vec<u8>,
        /// Stream-absolute end position of the bytes fed so far.
        pos: u64,
        errors: RowErrorLog,
        quarantined: Vec<QuarantinedRow>,
    },
}

impl ChunkDecoder {
    /// Sequential decoder (decode threads = 1, SWAR on) — the network
    /// worker's default and the engine's `decode_threads(1)` path.
    pub fn new(format: InputFormat, schema: Schema) -> Self {
        Self::with_options(format, schema, DecodeOptions::default())
    }

    /// Decoder with explicit decode options (the engine passes the
    /// plan's `decode_threads` here).
    pub fn with_options(format: InputFormat, schema: Schema, opts: DecodeOptions) -> Self {
        let inner = match format {
            InputFormat::Utf8 => DecoderInner::Utf8(ShardedUtf8Decoder::with_errors(
                schema,
                opts.threads,
                opts.swar,
                opts.errors,
            )),
            InputFormat::Binary => DecoderInner::Binary {
                schema,
                partial: Vec::new(),
                pos: 0,
                errors: RowErrorLog::with_cap(opts.errors.detail_cap),
                quarantined: Vec::new(),
            },
        };
        ChunkDecoder { inner, cfg: opts.errors }
    }

    /// Illegal bytes skipped so far (UTF-8 only; offsets are absolute
    /// in the fed stream, never shard-relative).
    pub fn illegal(&self) -> Option<&IllegalLog> {
        match &self.inner {
            DecoderInner::Utf8(dec) => Some(dec.illegal()),
            DecoderInner::Binary { .. } => None,
        }
    }

    /// Row-level defects detected so far under the configured policy.
    pub fn errors(&self) -> &RowErrorLog {
        match &self.inner {
            DecoderInner::Utf8(dec) => dec.errors(),
            DecoderInner::Binary { errors, .. } => errors,
        }
    }

    /// Rows seen so far — kept plus contained (the error-rate budget's
    /// denominator). Binary counts whole rows fed so far.
    pub fn rows_seen(&self) -> u64 {
        match &self.inner {
            DecoderInner::Utf8(dec) => dec.rows_seen(),
            DecoderInner::Binary { schema, partial, pos, .. } => {
                (pos - partial.len() as u64) / schema.binary_row_bytes() as u64
            }
        }
    }

    /// Drain the raw bytes of rows contained under the quarantine
    /// policy since the last drain (empty under every other policy).
    pub fn take_quarantined(&mut self) -> Vec<QuarantinedRow> {
        match &mut self.inner {
            DecoderInner::Utf8(dec) => dec.take_quarantined(),
            DecoderInner::Binary { quarantined, .. } => std::mem::take(quarantined),
        }
    }

    /// Under `on_error=fail`, surface the first recorded defect as a
    /// typed [`DataError`]; no-op otherwise.
    fn enforce_fail(&self) -> Result<()> {
        if self.cfg.policy == ErrorPolicy::Fail {
            if let Some(first) = self.errors().first() {
                return Err(anyhow::Error::new(DataError::Row(*first)));
            }
        }
        Ok(())
    }

    /// Feed a chunk, appending all rows it completes to `out`.
    ///
    /// UTF-8 decodes through the row-sharded SWAR decoder: the chunk's
    /// interior rows fan out across the configured decode threads into
    /// disjoint row ranges of `out`, while the rows straddling chunk
    /// boundaries stay on the sequential carry path. Binary input takes
    /// a bulk fast path: when no partial row is carried and the chunk
    /// is row-aligned, the chunk's bytes are bulk-decoded straight into
    /// the block's column planes — no `extend_from_slice` + `drain`
    /// staging buffer (an O(chunk) memmove per chunk in the old
    /// row-wise decoder). Only the straddling tail bytes (< one row)
    /// ever touch the `partial` buffer.
    pub fn feed_into(&mut self, chunk: &[u8], out: &mut RowBlock) -> Result<()> {
        match &mut self.inner {
            DecoderInner::Utf8(dec) => {
                dec.feed_into(chunk, out);
                self.enforce_fail()
            }
            DecoderInner::Binary { schema, partial, pos, .. } => {
                let rb = schema.binary_row_bytes();
                *pos += chunk.len() as u64;
                let mut chunk = chunk;
                if !partial.is_empty() {
                    // Complete the row straddling the previous chunk.
                    let need = rb - partial.len();
                    let take = need.min(chunk.len());
                    partial.extend_from_slice(&chunk[..take]);
                    chunk = &chunk[take..];
                    if partial.len() == rb {
                        out.append_binary(partial);
                        partial.clear();
                    }
                }
                // Fast path: bulk-decode the row-aligned prefix directly
                // from the incoming chunk.
                let full = chunk.len() / rb * rb;
                out.append_binary(&chunk[..full]);
                partial.extend_from_slice(&chunk[full..]);
                Ok(())
            }
        }
    }

    /// Finish the pass; any trailing partial row is completed (UTF-8
    /// without final newline) or contained (truncated binary row). The
    /// returned tally carries the pass's full illegal-byte and row-error
    /// logs plus any still-undrained quarantined rows.
    ///
    /// A truncated binary tail is classified as `WrongFieldCount` (the
    /// stream ended before the row's fixed byte count): the legacy
    /// `zero` policy keeps rejecting the whole stream, `fail` surfaces a
    /// typed [`DataError`] naming the row's stream offset, and
    /// `skip`/`quarantine` contain just the tail row.
    pub fn finish_into(self, out: &mut RowBlock) -> Result<DecodeTally> {
        let cfg = self.cfg;
        match self.inner {
            DecoderInner::Utf8(dec) => {
                let tally = dec.finish_into(out);
                if cfg.policy == ErrorPolicy::Fail {
                    if let Some(first) = tally.errors.first() {
                        return Err(anyhow::Error::new(DataError::Row(*first)));
                    }
                }
                Ok(tally)
            }
            DecoderInner::Binary { schema, partial, pos, mut errors, mut quarantined } => {
                let rb = schema.binary_row_bytes() as u64;
                let mut rows_seen = (pos - partial.len() as u64) / rb;
                if !partial.is_empty() {
                    let err = RowError {
                        kind: RowErrorKind::WrongFieldCount,
                        offset: pos - partial.len() as u64,
                        row: rows_seen,
                    };
                    match cfg.policy {
                        ErrorPolicy::Zero => anyhow::bail!(
                            "binary stream ended mid-row ({} stray bytes)",
                            partial.len()
                        ),
                        ErrorPolicy::Fail => return Err(anyhow::Error::new(DataError::Row(err))),
                        ErrorPolicy::Skip => errors.note(err),
                        ErrorPolicy::Quarantine => {
                            errors.note(err);
                            quarantined.push(QuarantinedRow {
                                row: err.row,
                                offset: err.offset,
                                kind: err.kind,
                                bytes: partial,
                            });
                        }
                    }
                    rows_seen += 1;
                }
                Ok(DecodeTally { illegal: IllegalLog::default(), errors, quarantined, rows_seen })
            }
        }
    }
}

// ---------------------------------------------------------------------
// Plan + builder
// ---------------------------------------------------------------------

/// How a plan executes its stateful vocabulary operators — fixed at
/// planning time ([`PipelineBuilder::build`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecStrategy {
    /// One decode pass: each chunk is observed *and* emitted in the same
    /// scan ([`ExecutorRun::process_observing`]), appearance indices
    /// assigned on the fly with the bitmap+counter semantics of
    /// [`crate::ops::DirectVocab`]. No source rewind, no barrier;
    /// bit-identical to [`Self::TwoPass`] because an appearance index is
    /// fixed at first appearance. The default whenever the executor
    /// supports it.
    Fused,
    /// The classic two-loop design: a full GenVocab pass, a source
    /// rewind, then the ApplyVocab/emit pass. Requires
    /// [`Source::can_rewind`]. Retained for executors without fused
    /// support and for deployments that need a global vocabulary
    /// barrier before any output (the cluster leader-merge path).
    TwoPass,
}

impl ExecStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            ExecStrategy::Fused => "fused",
            ExecStrategy::TwoPass => "two-pass",
        }
    }

    /// Parse a CLI-style name.
    pub fn parse(s: &str) -> Result<ExecStrategy> {
        match s {
            "fused" => Ok(ExecStrategy::Fused),
            "two-pass" | "twopass" | "two_pass" => Ok(ExecStrategy::TwoPass),
            other => anyhow::bail!("unknown strategy `{other}` (fused|two-pass)"),
        }
    }
}

/// The validated, immutable execution plan: the spec's per-column
/// programs compiled against the schema into one fixed-function slot
/// per column ([`ColumnPlans`]), plus input format, chunking and
/// execution strategy. Built once by [`PipelineBuilder::build`];
/// executors read it, never mutate it.
#[derive(Debug, Clone)]
pub struct Plan {
    pub spec: PipelineSpec,
    /// The compiled physical side of `spec`: per-column modulus/vocab
    /// slots and dense kernel chains — what executor hot loops dispatch
    /// on (never the rule list itself). Also the single source of truth
    /// for the plan's schema ([`Plan::schema`]).
    pub programs: ColumnPlans,
    pub input: InputFormat,
    /// Rows per chunk the engine aims for (the producer/worker channel
    /// is sized in these units).
    pub chunk_rows: usize,
    /// Raw chunks the producer may queue ahead of the decode/execute
    /// worker (see [`PipelineBuilder::channel_depth`]).
    pub channel_depth: usize,
    /// Decoded chunks that may be in flight through the fused stage
    /// pipeline (see [`PipelineBuilder::pipeline_depth`]); 1 =
    /// sequential chunk-at-a-time driving.
    pub pipeline_depth: usize,
    /// Fused single pass vs two-pass-with-rewind (see [`ExecStrategy`]).
    pub strategy: ExecStrategy,
    /// Row shards decoding each UTF-8 chunk in parallel (see
    /// [`PipelineBuilder::decode_threads`]); 1 is the sequential path.
    pub decode_threads: usize,
    /// Malformed-row containment: policy, error budget, detail cap (see
    /// [`PipelineBuilder::on_error`]).
    pub errors: ErrorConfig,
    /// Side file receiving raw quarantined rows when `errors.policy` is
    /// [`ErrorPolicy::Quarantine`] (see [`PipelineBuilder::quarantine`]).
    pub quarantine: Option<PathBuf>,
}

impl Plan {
    /// Compile a bare plan (no executor attached): resolve the spec's
    /// rules against the schema. This is the planning core
    /// [`PipelineBuilder::build`] goes through; exposed for tests and
    /// benches that drive [`ChunkState`] directly.
    pub fn compile(
        spec: PipelineSpec,
        schema: Schema,
        input: InputFormat,
        chunk_rows: usize,
    ) -> Result<Plan> {
        Ok(Plan {
            programs: spec.compile(schema)?,
            spec,
            input,
            chunk_rows,
            channel_depth: DEFAULT_CHANNEL_DEPTH,
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
            strategy: ExecStrategy::TwoPass,
            decode_threads: 1,
            errors: ErrorConfig::default(),
            quarantine: None,
        })
    }

    /// The schema the programs were compiled against.
    pub fn schema(&self) -> Schema {
        self.programs.schema
    }

    /// Does any column of the plan build a vocabulary? (Decides the
    /// two-pass rewind and the fused CPU decomposition.)
    pub fn has_gen_vocab(&self) -> bool {
        self.programs.any_gen_vocab()
    }

    /// Decode passes over the source this plan costs per submission: 2
    /// only when a vocabulary-building plan runs under
    /// [`ExecStrategy::TwoPass`] (the rewind), 1 otherwise.
    pub fn decode_passes(&self) -> usize {
        if self.has_gen_vocab() && self.strategy == ExecStrategy::TwoPass {
            2
        } else {
            1
        }
    }

    /// Requested raw bytes per chunk, derived from `chunk_rows` and the
    /// format's approximate row width.
    pub fn chunk_bytes(&self) -> usize {
        let schema = self.schema();
        let per_row = match self.input {
            InputFormat::Binary => schema.binary_row_bytes(),
            // ~2 bytes label+newline, ~7 per dense field, 9 per sparse.
            InputFormat::Utf8 => 2 + 7 * schema.num_dense + 9 * schema.num_sparse,
        };
        (self.chunk_rows * per_row).max(1)
    }
}

/// Builder for a reusable [`Pipeline`]: operator spec, schema, input
/// format, chunking, executor. All validation happens in [`Self::build`].
pub struct PipelineBuilder {
    spec: PipelineSpec,
    schema: Schema,
    input: InputFormat,
    chunk_rows: usize,
    channel_depth: usize,
    pipeline_depth: usize,
    strategy: Option<ExecStrategy>,
    decode_threads: Option<usize>,
    on_error: Option<ErrorPolicy>,
    error_budget: ErrorBudget,
    error_details: usize,
    quarantine: Option<PathBuf>,
    executor: Option<Box<dyn Executor>>,
}

/// Default raw-chunk queue depth between the producer thread and the
/// decode/execute worker.
const DEFAULT_CHANNEL_DEPTH: usize = 2;

/// Default in-flight window of the fused stage pipeline: one chunk in
/// the ordered vocab stage plus one being decoded/stateless-processed —
/// the minimal window that overlaps decode N+1 with vocab N.
const DEFAULT_PIPELINE_DEPTH: usize = 2;

impl PipelineBuilder {
    pub fn new() -> Self {
        PipelineBuilder {
            spec: PipelineSpec::dlrm(Modulus::VOCAB_5K.range),
            schema: Schema::CRITEO,
            input: InputFormat::Utf8,
            chunk_rows: 64 * 1024,
            channel_depth: DEFAULT_CHANNEL_DEPTH,
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
            strategy: None,
            decode_threads: None,
            on_error: None,
            error_budget: ErrorBudget::Unlimited,
            error_details: crate::decode::errors::DEFAULT_ERROR_DETAILS,
            quarantine: None,
            executor: None,
        }
    }

    /// Operator pipeline (defaults to the paper's DLRM pipeline at 5K).
    pub fn spec(mut self, spec: PipelineSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Parse a `|`-separated spec string (see [`PipelineSpec::parse`]).
    pub fn spec_str(mut self, spec: &str) -> Result<Self> {
        self.spec = PipelineSpec::parse(spec)?;
        Ok(self)
    }

    pub fn schema(mut self, schema: Schema) -> Self {
        self.schema = schema;
        self
    }

    pub fn input(mut self, input: InputFormat) -> Self {
        self.input = input;
        self
    }

    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.chunk_rows = rows.max(1);
        self
    }

    /// Raw chunks the producer may queue ahead of the worker (default 2).
    ///
    /// Peak resident input memory ≈ `(channel_depth + pipeline_depth +
    /// 1) × chunk_bytes`: one raw chunk being filled by the producer,
    /// `channel_depth` raw chunks queued in the channel, and the
    /// decoded in-flight window of the fused stage pipeline —
    /// [`Self::pipeline_depth`] blocks under pipelined driving, one
    /// block everywhere else (sequential fused, two-pass, and vocab
    /// stages all decode into a single reused scratch, so
    /// `pipeline_depth` contributes exactly 1 there and the bound
    /// reduces to the classic `(channel_depth + 2) × chunk_bytes`). The
    /// formula is per *moment*, not per pass — a submission allocates
    /// exactly that many buffers over its whole lifetime, and a
    /// two-pass submission reuses the same set across both passes via
    /// the pool lane, so strategy changes throughput, never peak
    /// memory. Depth 1 minimizes memory but stalls the producer on
    /// every decode; deeper queues absorb source jitter (file/TCP
    /// reads) at linear memory cost. Validated ≥ 1 at [`Self::build`].
    pub fn channel_depth(mut self, depth: usize) -> Self {
        self.channel_depth = depth;
        self
    }

    /// Decoded chunks that may be in flight through the fused stage
    /// pipeline (default 2) — the window of [`RowBlock`]s circulating
    /// between the decode+stateless stage thread and the ordered vocab
    /// stage. Depth 1 pins the fused pass to sequential
    /// chunk-at-a-time driving (the pre-pipelining baseline); depth 2
    /// overlaps chunk N+1's decode and stateless column work with
    /// chunk N's sequential vocabulary scan — the reclaimed idle the
    /// paper's §2.3 scaling wall leaves on the table; deeper windows
    /// absorb chunk-to-chunk jitter in stage times at linear memory
    /// cost (see [`Self::channel_depth`] for the peak-memory formula).
    /// Output is bit-identical at every depth: chunks enter the vocab
    /// stage strictly in chunk order, so appearance-index assignment
    /// never observes the overlap. Two-pass plans and executors
    /// without a stage-split ignore the knob. Validated ≥ 1 at
    /// [`Self::build`].
    pub fn pipeline_depth(mut self, depth: usize) -> Self {
        self.pipeline_depth = depth;
        self
    }

    /// Force an execution strategy instead of letting [`Self::build`]
    /// pick one from executor capabilities. Forcing
    /// [`ExecStrategy::Fused`] on an executor without fused support is a
    /// planning error; forcing [`ExecStrategy::TwoPass`] is always legal
    /// (e.g. to reproduce the paper's two-loop baseline, or when the
    /// submission needs a vocabulary barrier before any output).
    pub fn strategy(mut self, strategy: ExecStrategy) -> Self {
        self.strategy = Some(strategy);
        self
    }

    /// Row shards decoding each UTF-8 chunk in parallel (default: one
    /// per available core). The chunk splits at `\n` boundaries and the
    /// shards decode on scoped threads into disjoint row ranges of the
    /// scratch block ([`crate::decode::shard`]), so output is
    /// bit-identical for every thread count; `1` preserves the
    /// sequential decode path. Binary input ignores the knob (its bulk
    /// column copy is already memcpy-bound). Validated ≥ 1 at
    /// [`Self::build`].
    pub fn decode_threads(mut self, threads: usize) -> Self {
        self.decode_threads = Some(threads);
        self
    }

    /// Malformed-row policy (default [`ErrorPolicy::Zero`], the legacy
    /// zero-fill behavior). `fail` aborts the submission with a typed
    /// [`DataError`] naming the first offending stream offset; `skip`
    /// drops defective rows; `quarantine` drops them *and* writes their
    /// raw bytes to the side file set via [`Self::quarantine`] (which is
    /// then required at [`Self::build`]).
    pub fn on_error(mut self, policy: ErrorPolicy) -> Self {
        self.on_error = Some(policy);
        self
    }

    /// Abort the submission once contained rows exceed this budget — an
    /// absolute count or a rate over rows seen (default unlimited). Only
    /// meaningful under `skip`/`quarantine`; `fail` aborts on the first
    /// defect regardless and `zero` contains nothing.
    pub fn error_budget(mut self, budget: ErrorBudget) -> Self {
        self.error_budget = budget;
        self
    }

    /// Per-log cap on *recorded* defect details — first-N illegal-byte
    /// offsets and first-N row errors surfaced in the report (default
    /// 64). Totals are always exact; the cap bounds only detail memory.
    /// Validated ≥ 1 at [`Self::build`].
    pub fn error_details(mut self, cap: usize) -> Self {
        self.error_details = cap;
        self
    }

    /// Side file receiving raw quarantined rows. Setting a path without
    /// [`Self::on_error`] implies [`ErrorPolicy::Quarantine`]; setting
    /// one alongside a different explicit policy is a planning error.
    pub fn quarantine(mut self, path: impl Into<PathBuf>) -> Self {
        self.quarantine = Some(path.into());
        self
    }

    pub fn executor(mut self, executor: Box<dyn Executor>) -> Self {
        self.executor = Some(executor);
        self
    }

    /// Plan and build. Every capability/config mismatch surfaces here as
    /// a planning error — a built pipeline does not fail on submission
    /// for reasons knowable up front.
    pub fn build(self) -> Result<Pipeline> {
        let executor = self
            .executor
            .ok_or_else(|| anyhow::anyhow!("PipelineBuilder needs an executor"))?;
        anyhow::ensure!(
            self.channel_depth >= 1,
            "planning: channel_depth must be >= 1 (got {})",
            self.channel_depth
        );
        anyhow::ensure!(
            self.pipeline_depth >= 1,
            "planning: pipeline_depth must be >= 1 (got {})",
            self.pipeline_depth
        );
        let decode_threads = match self.decode_threads {
            Some(0) => anyhow::bail!("planning: decode_threads must be >= 1 (got 0)"),
            Some(n) => n,
            None => shard::default_threads(),
        };
        anyhow::ensure!(
            self.error_details >= 1,
            "planning: error_details must be >= 1 (got 0)"
        );
        let policy = match (self.on_error, &self.quarantine) {
            (Some(ErrorPolicy::Quarantine), None) => {
                anyhow::bail!("planning: on_error=quarantine needs a quarantine path")
            }
            (Some(p), Some(_)) if p != ErrorPolicy::Quarantine => anyhow::bail!(
                "planning: quarantine path set but on_error={} (expected quarantine)",
                p.name()
            ),
            (Some(p), _) => p,
            (None, Some(_)) => ErrorPolicy::Quarantine,
            (None, None) => ErrorPolicy::Zero,
        };
        let errors = ErrorConfig {
            policy,
            budget: self.error_budget,
            detail_cap: self.error_details,
        };
        // The spec was validated at its construction; resolving its
        // column selectors against the schema is the planning step that
        // can still fail (a schema mismatch is a planning error).
        let mut plan = Plan {
            programs: self.spec.compile(self.schema)?,
            spec: self.spec,
            input: self.input,
            chunk_rows: self.chunk_rows,
            channel_depth: self.channel_depth,
            pipeline_depth: self.pipeline_depth,
            strategy: ExecStrategy::TwoPass, // provisional until capability check
            decode_threads,
            errors,
            quarantine: self.quarantine,
        };
        anyhow::ensure!(
            executor.accepts(plan.input),
            "planning: {} does not accept {:?} input",
            executor.name(),
            plan.input
        );
        // Strategy selection: fused whenever the executor can (it is the
        // cheaper plan — one decode pass), unless the caller forced one.
        plan.strategy = match self.strategy {
            Some(ExecStrategy::Fused) => {
                anyhow::ensure!(
                    executor.supports_fused(&plan),
                    "planning: {} cannot run the fused single-pass strategy",
                    executor.name()
                );
                ExecStrategy::Fused
            }
            Some(ExecStrategy::TwoPass) => ExecStrategy::TwoPass,
            None if executor.supports_fused(&plan) => ExecStrategy::Fused,
            None => ExecStrategy::TwoPass,
        };
        executor.plan_check(&plan)?;
        Ok(Pipeline { plan, executor })
    }

}

impl Default for PipelineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------
// Pipeline + engine loop
// ---------------------------------------------------------------------

/// A planned, reusable preprocessing pipeline: run it over any number of
/// sources; each submission streams with bounded memory.
pub struct Pipeline {
    plan: Plan,
    executor: Box<dyn Executor>,
}

impl Pipeline {
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn executor_name(&self) -> String {
        self.executor.name()
    }

    /// Run one submission: stream `source` through the planned operator
    /// graph on this pipeline's executor, pushing column blocks into
    /// `sink` as they are produced.
    pub fn run(&self, source: &mut dyn Source, sink: &mut dyn Sink) -> Result<RunReport> {
        anyhow::ensure!(
            source.format() == self.plan.input,
            "source yields {:?} but the pipeline was planned for {:?}",
            source.format(),
            self.plan.input
        );
        let t0 = Instant::now();
        let mut run = self.executor.begin(&self.plan)?;

        // Raw chunk buffers recycle through this pool for the lifetime
        // of the submission; when a two-pass plan streams the source
        // twice, the second pass reuses the first pass's buffers.
        let mut pool: Vec<Vec<u8>> = Vec::new();
        let mut decode_time = Duration::ZERO;

        if self.plan.strategy == ExecStrategy::TwoPass {
            // Pass 1 (GenVocab) only when the plan has stateful vocab
            // ops — it rewinds the source for a second decode pass.
            if self.plan.has_gen_vocab() {
                anyhow::ensure!(
                    source.can_rewind(),
                    "two-pass gen_vocab plan needs a rewindable source; \
                     this source streams once — build the pipeline with the \
                     fused strategy instead"
                );
                // The observe pass runs quarantine downgraded to skip:
                // keep/drop decisions are identical (so both passes see
                // the same rows), but raw bytes are written and counters
                // reported once, by the emit pass.
                let pass1 = stream_chunks(
                    &self.plan,
                    &mut *source,
                    &mut pool,
                    self.plan.errors.for_observe_pass(),
                    None,
                    |block| run.observe(block),
                )?;
                decode_time += pass1.decode;
                source.reset()?;
            }
            run.seal()?;
        }

        let mut quarantine_writer = match (&self.plan.quarantine, self.plan.errors.policy) {
            (Some(path), ErrorPolicy::Quarantine) => {
                Some(QuarantineWriter::create(path, self.plan.input)?)
            }
            _ => None,
        };

        let mut stage = StageTimes::default();
        let mut effective_depth = 1;
        let totals = match self.plan.strategy {
            // Fused with an in-flight window: drive the run through its
            // stage-split ([`ExecutorRun::stages`]) so chunk N+1's
            // decode+stateless work overlaps chunk N's sequential vocab
            // scan. Falls back to the sequential fused loop for
            // executors that cannot stage-split.
            ExecStrategy::Fused if self.plan.pipeline_depth > 1 => {
                let piped = match run.stages() {
                    Some(stages) => Some(run_fused_pipelined(
                        &self.plan,
                        &mut *source,
                        &mut pool,
                        self.plan.errors,
                        quarantine_writer.as_mut(),
                        stages,
                        sink,
                    )?),
                    None => None,
                };
                match piped {
                    Some((totals, times)) => {
                        stage = times;
                        effective_depth = self.plan.pipeline_depth;
                        totals
                    }
                    None => stream_chunks(
                        &self.plan,
                        &mut *source,
                        &mut pool,
                        self.plan.errors,
                        quarantine_writer.as_mut(),
                        |block| run.process_observing(block, sink),
                    )?,
                }
            }
            // Fused, sequential (pipeline_depth 1 — the pinned
            // pre-pipelining baseline): the single decode pass observes
            // and emits at once — no rewind, no barrier, output streams
            // while vocabularies build.
            ExecStrategy::Fused => stream_chunks(
                &self.plan,
                &mut *source,
                &mut pool,
                self.plan.errors,
                quarantine_writer.as_mut(),
                |block| run.process_observing(block, sink),
            )?,
            ExecStrategy::TwoPass => stream_chunks(
                &self.plan,
                &mut *source,
                &mut pool,
                self.plan.errors,
                quarantine_writer.as_mut(),
                |block| {
                    let columns = run.process(block)?;
                    sink.push(&columns)
                },
            )?,
        };
        decode_time += totals.decode;

        let quarantine = match quarantine_writer {
            Some(writer) => writer.finish()?,
            None => QuarantineSummary::default(),
        };
        let (rows_skipped, rows_quarantined) = match self.plan.errors.policy {
            ErrorPolicy::Skip => (totals.errors.total, 0),
            ErrorPolicy::Quarantine => (0, totals.errors.total),
            _ => (0, 0),
        };

        let stats = StreamStats {
            raw_bytes: totals.raw_bytes,
            rows: totals.rows,
            chunks: totals.chunks,
            wall: t0.elapsed(),
            stateless_time: stage.stateless,
            vocab_time: stage.vocab,
        };
        let rep = run.finish(&stats)?;
        Ok(RunReport {
            executor: self.executor.name(),
            rows: totals.rows as usize,
            chunks: totals.chunks as usize,
            decode_passes: self.plan.decode_passes(),
            strategy: self.plan.strategy,
            decode_threads: self.plan.decode_threads,
            decode_time,
            illegal_bytes: totals.illegal.total,
            illegal: totals.illegal,
            row_errors: totals.errors,
            rows_skipped,
            rows_quarantined,
            quarantine,
            e2e: rep.modeled_e2e.unwrap_or(stats.wall),
            wall: stats.wall,
            tag: rep.tag,
            compute: rep.compute,
            observe_time: rep.observe_time,
            process_time: rep.process_time,
            vocab_entries: rep.vocab_entries,
            pipeline_depth: effective_depth,
            stage_stateless_time: stage.stateless,
            vocab_wait_time: stage.vocab_wait,
        })
    }

    /// Run and gather the full output — the drop-in replacement for the
    /// old one-shot drivers.
    pub fn run_collect(&self, source: &mut dyn Source) -> Result<(ProcessedColumns, RunReport)> {
        let mut sink = CollectSink::with_schema(self.plan.schema());
        let report = self.run(source, &mut sink)?;
        Ok((sink.into_columns(), report))
    }
}

/// Totals of one streaming pass over the source.
#[derive(Debug, Default, Clone)]
struct PassTotals {
    raw_bytes: u64,
    rows: u64,
    chunks: u64,
    /// Wallclock spent inside the decode front (feed + finish), summed
    /// over the pass — the numerator of the decode-scaling tables.
    decode: Duration,
    /// Illegal input bytes the decode skipped during this pass (full
    /// log: exact total plus the first-N recorded offsets).
    illegal: IllegalLog,
    /// Row-level defects contained during this pass under the plan's
    /// error policy.
    errors: RowErrorLog,
}

/// Drain freshly quarantined rows to the side file and enforce the
/// error budget against the decoder's running totals. Called once per
/// fed chunk (so a blown budget aborts within one chunk of the
/// offending row) and once more at pass finish.
fn contain_step(
    decoder: &mut ChunkDecoder,
    errors: ErrorConfig,
    quarantine: &mut Option<&mut QuarantineWriter>,
) -> Result<()> {
    if let Some(writer) = quarantine.as_deref_mut() {
        for row in decoder.take_quarantined() {
            writer.write(&row)?;
        }
    }
    let log = decoder.errors();
    if errors.budget.exceeded(log.total, decoder.rows_seen()) {
        return Err(anyhow::Error::new(DataError::BudgetExceeded {
            errors: log.total,
            rows: decoder.rows_seen(),
            budget: errors.budget,
            first: log.first().copied(),
        }));
    }
    Ok(())
}

/// The finish-time counterpart of [`contain_step`]: drain the tally's
/// still-undrained quarantined rows and run the final budget check.
fn contain_tally(
    tally: &mut DecodeTally,
    errors: ErrorConfig,
    quarantine: &mut Option<&mut QuarantineWriter>,
) -> Result<()> {
    if let Some(writer) = quarantine.as_deref_mut() {
        for row in tally.quarantined.drain(..) {
            writer.write(&row)?;
        }
    }
    if errors.budget.exceeded(tally.errors.total, tally.rows_seen) {
        return Err(anyhow::Error::new(DataError::BudgetExceeded {
            errors: tally.errors.total,
            rows: tally.rows_seen,
            budget: errors.budget,
            first: tally.errors.first().copied(),
        }));
    }
    Ok(())
}

/// One streaming pass: a producer thread pulls raw chunks from the
/// source into a bounded channel while this thread decodes them into a
/// reused [`RowBlock`] scratch and feeds the executor. UTF-8 decode
/// fans each chunk's interior rows out across `plan.decode_threads`
/// scoped threads ([`crate::decode::shard`]); decode wallclock is
/// accumulated separately so reports can show the decode/execute
/// split. Consumed raw buffers return to the producer through an
/// unbounded pool lane, seeded from and drained back into the caller's
/// `pool`, so steady state allocates nothing per chunk — neither raw
/// `Vec<u8>`s nor decoded rows. A fused plan makes exactly one call; a
/// two-pass plan calls twice and the pool carries the buffers across.
fn stream_chunks<F>(
    plan: &Plan,
    source: &mut dyn Source,
    pool: &mut Vec<Vec<u8>>,
    errors: ErrorConfig,
    mut quarantine: Option<&mut QuarantineWriter>,
    mut consume: F,
) -> Result<PassTotals>
where
    F: FnMut(&RowBlock) -> Result<()>,
{
    let chunk_bytes = plan.chunk_bytes();
    let mut decoder = ChunkDecoder::with_options(
        plan.input,
        plan.schema(),
        DecodeOptions { threads: plan.decode_threads, swar: true, errors },
    );
    let mut block = RowBlock::with_capacity(plan.schema(), plan.chunk_rows);
    let mut raw_bytes = 0u64;
    let mut rows = 0u64;
    let mut chunks = 0u64;
    let mut decode = Duration::ZERO;

    let passed: Result<()> = std::thread::scope(|scope| {
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(plan.channel_depth);
        let (pool_tx, pool_rx) = mpsc::channel::<Vec<u8>>();
        for buf in pool.drain(..) {
            let _ = pool_tx.send(buf); // seed with the previous pass's buffers
        }
        let producer_pool = pool_tx.clone();
        let producer = scope.spawn(move || {
            let result = (|| -> Result<()> {
                loop {
                    // Reuse a recycled buffer when one has come back;
                    // only ever `channel_depth + 2`-ish buffers exist.
                    let mut buf = pool_rx.try_recv().unwrap_or_default();
                    if !source.next_chunk(chunk_bytes, &mut buf)? {
                        let _ = producer_pool.send(buf); // keep it pooled
                        break;
                    }
                    if tx.send(buf).is_err() {
                        break; // consumer bailed; its error wins below
                    }
                }
                Ok(())
            })();
            (result, pool_rx)
        });

        let mut consumer_err: Option<anyhow::Error> = None;
        for chunk in &rx {
            raw_bytes += chunk.len() as u64;
            chunks += 1;
            block.clear();
            let td = Instant::now();
            let fed = decoder.feed_into(&chunk, &mut block);
            decode += td.elapsed();
            let step = fed.and_then(|()| {
                contain_step(&mut decoder, errors, &mut quarantine)?;
                if block.is_empty() {
                    return Ok(());
                }
                rows += block.num_rows() as u64;
                consume(&block)
            });
            let _ = pool_tx.send(chunk); // recycle the raw buffer
            if let Err(e) = step {
                consumer_err = Some(e);
                break;
            }
        }
        drop(rx); // unblock the producer if we bailed early

        let (produced, pool_rx) = match producer.join() {
            Ok(pair) => pair,
            Err(panic) => {
                let what = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".into());
                return Err(anyhow::anyhow!("pipeline source producer panicked: {what}"));
            }
        };
        // Reclaim every pooled buffer for the caller's next pass.
        pool.extend(pool_rx.try_iter());
        match (produced, consumer_err) {
            // A producer error explains any downstream decode error.
            (Err(e), _) => Err(e),
            (Ok(()), Some(e)) => Err(e),
            (Ok(()), None) => Ok(()),
        }
    });
    passed?;

    block.clear();
    let td = Instant::now();
    let mut tally = decoder.finish_into(&mut block)?;
    decode += td.elapsed();
    contain_tally(&mut tally, errors, &mut quarantine)?;
    if !block.is_empty() {
        rows += block.num_rows() as u64;
        consume(&block)?;
    }
    Ok(PassTotals { raw_bytes, rows, chunks, decode, illegal: tally.illegal, errors: tally.errors })
}

// ---------------------------------------------------------------------
// Stage-pipelined fused scheduler
// ---------------------------------------------------------------------

/// Busy/wait split measured by the stage-pipelined scheduler, folded
/// into [`RunReport`] (and, via [`StreamStats`], into the executor's
/// own observe/process accounting).
#[derive(Debug, Default, Clone, Copy)]
struct StageTimes {
    /// Busy time inside stage (b) — the sharded stateless column ops —
    /// on the stage thread.
    stateless: Duration,
    /// Busy time inside stage (c) — the sequential in-order vocab
    /// observe/apply scan — on the consumer thread.
    vocab: Duration,
    /// Time the stage thread spent blocked waiting for a free window
    /// slot: decode idle attributable to the vocab stage.
    vocab_wait: Duration,
}

/// Totals the decode+stateless stage thread accumulates; the scheduler
/// converts them into [`PassTotals`] + [`StageTimes`] after the join.
#[derive(Default)]
struct StageSide {
    raw_bytes: u64,
    rows: u64,
    chunks: u64,
    /// Full decode tally of the pass (illegal bytes, row errors),
    /// captured at decoder finish.
    tally: DecodeTally,
    decode: Duration,
    stateless: Duration,
    window_wait: Duration,
}

/// Per-stage ordering lock (the axiom-recorder `ProcessingStageLock`
/// idiom): chunks enter the guarded stage strictly in chunk order.
/// Stages (a)/(b) are free-running; only the vocab scan (c) and sink
/// emit (d) are ordered — appearance-order index assignment depends on
/// it, which is what keeps pipelined output bit-identical to the
/// sequential paths. With a single consumer thread draining a FIFO the
/// lock never blocks in practice; it asserts the invariant and keeps
/// the ordered section explicit should the consumer side ever shard.
struct StageGate {
    done: Mutex<u64>,
    cv: Condvar,
}

impl StageGate {
    fn new() -> Self {
        StageGate { done: Mutex::new(0), cv: Condvar::new() }
    }

    /// Block until every chunk before `seq` has left the stage.
    fn enter(&self, seq: u64) {
        let guard = self.done.lock().unwrap();
        let _guard = self.cv.wait_while(guard, |done| *done < seq).unwrap();
    }

    /// Mark chunk `seq` done and wake the next one.
    fn leave(&self, seq: u64) {
        let mut done = self.done.lock().unwrap();
        assert_eq!(*done, seq, "chunks must leave the ordered stage in order");
        *done += 1;
        self.cv.notify_all();
    }
}

/// Pull a decoded-block slot out of the in-flight window, preferring a
/// locally held (empty-decode) block over the shared lane; accumulates
/// blocked time into `wait`. `None` means the consumer bailed and
/// dropped its end — the stage should unwind quietly (the consumer's
/// error wins).
fn take_slot(
    held: &mut Option<RowBlock>,
    free_rx: &mpsc::Receiver<RowBlock>,
    wait: &mut Duration,
) -> Option<RowBlock> {
    if let Some(block) = held.take() {
        return Some(block);
    }
    let tw = Instant::now();
    match free_rx.recv() {
        Ok(block) => {
            *wait += tw.elapsed();
            Some(block)
        }
        Err(_) => None,
    }
}

/// The fused pass as a software pipeline: chunk N+1's decode (a) and
/// sharded stateless ops (b) run on a dedicated stage thread while this
/// thread runs chunk N's sequential vocab scan (c) and sink emit (d).
/// Throughput approaches max(decode+stateless rate, vocab rate) instead
/// of their sum — the tf.data prefetch insight applied to the paper's
/// sequential-vocabulary CPU wall.
///
/// Topology (one [`std::thread::scope`]):
///
/// ```text
/// producer ──raw chunks──▶ stage thread ──(seq, RowBlock, cols)──▶ this thread
///    ▲                      decode+stateless        │ ordered vocab + sink
///    └── raw-buffer pool ◀──────┘   ▲               │
///                                   └── free RowBlock window ◀──┘
/// ```
///
/// The in-flight window is `plan.pipeline_depth` pre-allocated
/// [`RowBlock`]s cycling through an unbounded free lane — the bound
/// comes from the slot count, not the channel. [`ChunkDecoder`] carries
/// partial-row state across chunks, so decode stays sequential *across*
/// chunks (one stage thread) while sharding *within* each chunk across
/// `plan.decode_threads`. [`Sink`] is not `Send`, so stages (c)+(d)
/// stay on the caller's thread. Teardown never deadlocks: the stage
/// thread holds no clone of the free-lane sender, so when this thread
/// bails and drops `free_tx`/`work_rx`, the stage's blocking
/// `free_rx.recv()` (or `work_tx.send`) errors and it unwinds quietly.
/// Error precedence mirrors [`stream_chunks`]: producer > stage >
/// consumer.
fn run_fused_pipelined(
    plan: &Plan,
    source: &mut dyn Source,
    pool: &mut Vec<Vec<u8>>,
    errors: ErrorConfig,
    quarantine: Option<&mut QuarantineWriter>,
    stages: FusedStages<'_>,
    sink: &mut dyn Sink,
) -> Result<(PassTotals, StageTimes)> {
    let chunk_bytes = plan.chunk_bytes();
    let FusedStages { stateless, mut vocab } = stages;
    let mut times = StageTimes::default();

    let (totals, passed): (PassTotals, Result<()>) = std::thread::scope(|scope| {
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(plan.channel_depth);
        let (pool_tx, pool_rx) = mpsc::channel::<Vec<u8>>();
        for buf in pool.drain(..) {
            let _ = pool_tx.send(buf); // seed with the caller's buffers
        }
        let producer_pool = pool_tx.clone();
        let producer = scope.spawn(move || {
            let result = (|| -> Result<()> {
                loop {
                    let mut buf = pool_rx.try_recv().unwrap_or_default();
                    if !source.next_chunk(chunk_bytes, &mut buf)? {
                        let _ = producer_pool.send(buf); // keep it pooled
                        break;
                    }
                    if tx.send(buf).is_err() {
                        break; // downstream bailed; its error wins below
                    }
                }
                Ok(())
            })();
            (result, pool_rx)
        });

        // The in-flight window: exactly `pipeline_depth` decoded-block
        // slots exist, so peak decoded memory is bounded by the window
        // even though the lanes themselves are unbounded channels.
        let (free_tx, free_rx) = mpsc::channel::<RowBlock>();
        for _ in 0..plan.pipeline_depth {
            let _ = free_tx.send(RowBlock::with_capacity(plan.schema(), plan.chunk_rows));
        }
        let (work_tx, work_rx) = mpsc::channel::<(u64, RowBlock, ProcessedColumns)>();

        let stage_pool = pool_tx.clone();
        let stateless = &stateless;
        // The writer moves onto the stage thread: decode (and therefore
        // containment) happens there, and the scope joins the thread
        // before the caller's borrow ends.
        let mut quarantine = quarantine;
        let stage = scope.spawn(move || {
            let mut side = StageSide::default();
            let mut decoder = ChunkDecoder::with_options(
                plan.input,
                plan.schema(),
                DecodeOptions { threads: plan.decode_threads, swar: true, errors },
            );
            // A block that decoded to zero rows (partial row spanning
            // the chunk) is held locally instead of cycling through the
            // window, so an empty decode never consumes a slot.
            let mut held: Option<RowBlock> = None;
            let mut seq = 0u64;
            let result = (|| -> Result<()> {
                for chunk in &rx {
                    side.raw_bytes += chunk.len() as u64;
                    side.chunks += 1;
                    let Some(mut block) = take_slot(&mut held, &free_rx, &mut side.window_wait)
                    else {
                        return Ok(()); // consumer bailed
                    };
                    block.clear();
                    let td = Instant::now();
                    let fed = decoder.feed_into(&chunk, &mut block);
                    side.decode += td.elapsed();
                    let _ = stage_pool.send(chunk); // recycle the raw buffer
                    fed?;
                    contain_step(&mut decoder, errors, &mut quarantine)?;
                    if block.is_empty() {
                        held = Some(block);
                        continue;
                    }
                    side.rows += block.num_rows() as u64;
                    let ts = Instant::now();
                    let cols = stateless(&block);
                    side.stateless += ts.elapsed();
                    if work_tx.send((seq, block, cols)).is_err() {
                        return Ok(()); // consumer bailed
                    }
                    seq += 1;
                }
                // Flush the decoder's carried partial row.
                let Some(mut block) = take_slot(&mut held, &free_rx, &mut side.window_wait)
                else {
                    return Ok(());
                };
                block.clear();
                let td = Instant::now();
                let mut tally = decoder.finish_into(&mut block)?;
                side.decode += td.elapsed();
                contain_tally(&mut tally, errors, &mut quarantine)?;
                side.tally = tally;
                if !block.is_empty() {
                    side.rows += block.num_rows() as u64;
                    let ts = Instant::now();
                    let cols = stateless(&block);
                    side.stateless += ts.elapsed();
                    let _ = work_tx.send((seq, block, cols));
                }
                Ok(())
            })();
            (result, side)
        });
        drop(pool_tx);

        // Stages (c)+(d), in strict chunk order under the gate.
        let gate = StageGate::new();
        let mut consumer_err: Option<anyhow::Error> = None;
        for (seq, block, mut cols) in &work_rx {
            gate.enter(seq);
            let tv = Instant::now();
            vocab(&block, &mut cols);
            times.vocab += tv.elapsed();
            let pushed = sink.push(&cols);
            gate.leave(seq);
            drop(cols);
            let _ = free_tx.send(block); // return the slot to the window
            if let Err(e) = pushed {
                consumer_err = Some(e);
                break;
            }
        }
        // Dropping our ends unblocks a stage thread parked in
        // `free_rx.recv()` or `work_tx.send()`.
        drop(work_rx);
        drop(free_tx);

        let join = |what: &str, panic: Box<dyn std::any::Any + Send>| {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "unknown panic".into());
            anyhow::anyhow!("pipeline {what} panicked: {msg}")
        };
        let (staged, side) = match stage.join() {
            Ok(pair) => pair,
            Err(panic) => return (PassTotals::default(), Err(join("stage thread", panic))),
        };
        let (produced, pool_rx) = match producer.join() {
            Ok(pair) => pair,
            Err(panic) => return (PassTotals::default(), Err(join("source producer", panic))),
        };
        pool.extend(pool_rx.try_iter());

        times.stateless = side.stateless;
        times.vocab_wait = side.window_wait;
        let totals = PassTotals {
            raw_bytes: side.raw_bytes,
            rows: side.rows,
            chunks: side.chunks,
            decode: side.decode,
            illegal: side.tally.illegal,
            errors: side.tally.errors,
        };
        let passed = match (produced, staged, consumer_err) {
            // A producer error explains any downstream failure.
            (Err(e), _, _) => Err(e),
            (Ok(()), Err(e), _) => Err(e),
            (Ok(()), Ok(()), Some(e)) => Err(e),
            (Ok(()), Ok(()), None) => Ok(()),
        };
        (totals, passed)
    });
    passed?;
    Ok((totals, times))
}

// ---------------------------------------------------------------------
// Run report
// ---------------------------------------------------------------------

/// Uniform, [`TimeTag`]-propagating result of one pipeline submission —
/// the single result type all executors report through.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub executor: String,
    pub rows: usize,
    pub chunks: usize,
    /// Decode passes over the source: 2 when a `gen_vocab` plan ran
    /// two-pass (the paper's two-loop design, with a rewind), 1 under
    /// the fused strategy or for vocabulary-free plans. Surfaces the
    /// decode waste the fused strategy eliminates.
    pub decode_passes: usize,
    /// The execution strategy the plan ran under.
    pub strategy: ExecStrategy,
    /// Row shards that decoded each UTF-8 chunk (the plan's
    /// `decode_threads`); 1 = the sequential decode path.
    pub decode_threads: usize,
    /// Measured wallclock inside the decode front (SWAR + sharding),
    /// summed over every pass of the submission. `wall - decode_time`
    /// is the execute/stream side of the split — the decode-scaling
    /// bench tables report both.
    pub decode_time: Duration,
    /// Illegal input bytes the decode skipped (non-panicking, per the
    /// hardware's error-line semantics; offsets are logged stream-
    /// absolute at the decoder — [`crate::decode::IllegalLog`]).
    /// Counted over one decode pass: a two-pass plan reads the same
    /// bytes twice but reports them once. Zero for well-formed input.
    pub illegal_bytes: u64,
    /// The full illegal-byte log behind `illegal_bytes`: exact total
    /// plus the first-N recorded stream-absolute offsets (N = the
    /// plan's `error_details` cap).
    pub illegal: IllegalLog,
    /// Row-level defects detected during the emit pass: exact totals
    /// per [`crate::decode::RowErrorKind`] plus the first-N recorded
    /// `(offset, kind, row)` details. Populated under every policy —
    /// the legacy `zero` policy drops no rows but still logs what the
    /// other policies would have contained.
    pub row_errors: RowErrorLog,
    /// Rows dropped by `on_error=skip` (0 under every other policy).
    pub rows_skipped: u64,
    /// Rows dropped *and* written to the quarantine side file by
    /// `on_error=quarantine`.
    pub rows_quarantined: u64,
    /// Where quarantined rows went: side-file path and row count
    /// (defaults when no quarantine file was configured).
    pub quarantine: QuarantineSummary,
    /// End-to-end time: modeled for sim executors, measured wallclock
    /// for the CPU baseline. Check `tag`.
    pub e2e: Duration,
    /// Engine-measured wallclock of this submission (always measured,
    /// regardless of `tag`).
    pub wall: Duration,
    pub tag: TimeTag,
    /// Pure-computation time (the paper's Table 3 scope) where defined.
    pub compute: Option<Duration>,
    /// Measured time in GenVocab-attributable executor work: the whole
    /// observe pass under two-pass; the sequential vocab-assign stage
    /// under fused where the executor separates it (the CPU baseline),
    /// zero where it fuses inseparably. Comparing the two strategies'
    /// splits shows *where* the fused strategy's saving comes from —
    /// the observe pass's decode+scan disappears, while `process_time`
    /// stays roughly flat.
    pub observe_time: Duration,
    /// Measured time in the emit-side executor work (pass 2, or the
    /// fused pass minus any separable vocab stage).
    pub process_time: Duration,
    pub vocab_entries: usize,
    /// Effective in-flight chunk window this run executed with: the
    /// plan's `pipeline_depth` when the stage-pipelined fused scheduler
    /// ran, 1 for the sequential paths (two-pass, `pipeline_depth = 1`,
    /// or an executor without a stage-split).
    pub pipeline_depth: usize,
    /// Engine-measured busy time of the pipelined stateless stage
    /// (stage (b): sharded vocab-free column ops on the stage thread).
    /// Together with `decode_time` it is the overlappable side of the
    /// stage split; `observe_time` approximates the sequential vocab
    /// side. Zero when the run was not stage-pipelined.
    pub stage_stateless_time: Duration,
    /// Time the decode+stateless stage thread spent blocked waiting for
    /// a free slot in the in-flight window — decode idle time
    /// attributable to the sequential vocab stage. Large values with a
    /// small `pipeline_depth` mean the vocab scan is the bottleneck and
    /// a deeper window cannot help; zero when not stage-pipelined.
    pub vocab_wait_time: Duration,
}

impl RunReport {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        report::rows_per_sec(self.rows, self.e2e)
    }

    pub fn compute_rows_per_sec(&self) -> Option<f64> {
        self.compute.map(|c| report::rows_per_sec(self.rows, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, utf8, SynthConfig, SynthDataset};

    #[test]
    fn chunk_decoder_survives_any_boundary() {
        let ds = SynthDataset::generate(SynthConfig::small(60));
        for (format, raw) in [
            (InputFormat::Utf8, utf8::encode_dataset(&ds)),
            (InputFormat::Binary, binary::encode_dataset(&ds)),
        ] {
            for chunk in [1usize, 7, 64, 4096] {
                let mut dec = ChunkDecoder::new(format, ds.schema());
                let mut out = RowBlock::new(ds.schema());
                for c in raw.chunks(chunk) {
                    dec.feed_into(c, &mut out).unwrap();
                }
                dec.finish_into(&mut out).unwrap();
                assert_eq!(out.to_rows(), ds.rows, "{format:?} chunk {chunk}");
            }
        }
    }

    #[test]
    fn chunk_decoder_scratch_reuse_matches_one_shot() {
        // The engine's calling convention: one scratch block, cleared
        // between chunks. Rows accumulated across clears must equal a
        // single-shot decode.
        let ds = SynthDataset::generate(SynthConfig::small(45));
        let raw = binary::encode_dataset(&ds);
        let mut dec = ChunkDecoder::new(InputFormat::Binary, ds.schema());
        let mut scratch = RowBlock::new(ds.schema());
        let mut rows = Vec::new();
        for c in raw.chunks(101) {
            scratch.clear();
            dec.feed_into(c, &mut scratch).unwrap();
            rows.extend(scratch.to_rows());
        }
        scratch.clear();
        dec.finish_into(&mut scratch).unwrap();
        rows.extend(scratch.to_rows());
        assert_eq!(rows, ds.rows);
    }

    #[test]
    fn truncated_binary_rejected_at_finish() {
        let ds = SynthDataset::generate(SynthConfig::small(3));
        let mut raw = binary::encode_dataset(&ds);
        raw.pop();
        let mut dec = ChunkDecoder::new(InputFormat::Binary, ds.schema());
        let mut out = RowBlock::new(ds.schema());
        dec.feed_into(&raw, &mut out).unwrap();
        assert!(dec.finish_into(&mut out).is_err());
    }

    #[test]
    fn builder_rejects_zero_channel_depth() {
        let err = PipelineBuilder::new()
            .channel_depth(0)
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build();
        assert!(err.is_err(), "channel_depth 0 must fail at planning");
    }

    #[test]
    fn builder_resolves_decode_threads() {
        let auto = PipelineBuilder::new()
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build()
            .unwrap();
        assert!(auto.plan().decode_threads >= 1, "default must resolve to >= 1");

        let pinned = PipelineBuilder::new()
            .decode_threads(3)
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build()
            .unwrap();
        assert_eq!(pinned.plan().decode_threads, 3);

        let err = PipelineBuilder::new()
            .decode_threads(0)
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build();
        assert!(err.is_err(), "decode_threads 0 must fail at planning");
    }

    #[test]
    fn decode_threads_produce_identical_output_and_report_split() {
        let ds = SynthDataset::generate(SynthConfig::small(400));
        let raw = utf8::encode_dataset(&ds);
        let run_with = |threads: usize| {
            let pipeline = PipelineBuilder::new()
                .spec(crate::ops::PipelineSpec::dlrm(997))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(64)
                .decode_threads(threads)
                .executor(crate::coordinator::Backend::Gpu.executor())
                .build()
                .unwrap();
            let mut src = crate::pipeline::MemorySource::new(&raw, InputFormat::Utf8);
            pipeline.run_collect(&mut src).unwrap()
        };
        let (cols1, rep1) = run_with(1);
        let (cols4, rep4) = run_with(4);
        assert_eq!(cols1, cols4, "decode_threads must not change output");
        assert_eq!(rep1.decode_threads, 1);
        assert_eq!(rep4.decode_threads, 4);
        assert!(rep1.decode_time <= rep1.wall);
        assert!(rep4.decode_time <= rep4.wall);
        assert_eq!(rep1.illegal_bytes, 0, "well-formed input must report no skips");
        assert_eq!(rep4.illegal_bytes, 0);
    }

    #[test]
    fn builder_requires_an_executor() {
        assert!(PipelineBuilder::new().build().is_err());
    }

    #[test]
    fn builder_rejects_invalid_spec_at_planning() {
        let b = PipelineBuilder::new().spec_str("genvocab"); // needs modulus
        assert!(b.is_err() || b.unwrap().build().is_err());
    }

    #[test]
    fn builder_defaults_to_fused_and_honors_forced_two_pass() {
        let fused = PipelineBuilder::new()
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build()
            .unwrap();
        assert_eq!(fused.plan().strategy, ExecStrategy::Fused);
        assert_eq!(fused.plan().decode_passes(), 1);

        let two = PipelineBuilder::new()
            .strategy(ExecStrategy::TwoPass)
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build()
            .unwrap();
        assert_eq!(two.plan().strategy, ExecStrategy::TwoPass);
        assert_eq!(two.plan().decode_passes(), 2, "gen_vocab plan rewinds under two-pass");
    }

    #[test]
    fn decode_passes_is_one_without_gen_vocab_even_two_pass() {
        let p = PipelineBuilder::new()
            .spec_str("modulus:97|logarithm")
            .unwrap()
            .strategy(ExecStrategy::TwoPass)
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build()
            .unwrap();
        assert_eq!(p.plan().decode_passes(), 1);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [ExecStrategy::Fused, ExecStrategy::TwoPass] {
            assert_eq!(ExecStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(ExecStrategy::parse("sideways").is_err());
    }

    #[test]
    fn plan_chunk_bytes_scales_with_rows() {
        let p = Plan::compile(
            crate::ops::PipelineSpec::dlrm(97),
            Schema::CRITEO,
            InputFormat::Binary,
            1000,
        )
        .unwrap();
        assert_eq!(p.chunk_bytes(), 1000 * Schema::CRITEO.binary_row_bytes());
    }

    /// A spec whose selectors don't fit the schema is a planning error
    /// — caught in `build`, never inside a serving worker.
    #[test]
    fn out_of_schema_selector_is_a_planning_error() {
        let err = PipelineBuilder::new()
            .spec_str("sparse[40]: modulus:5|genvocab|applyvocab")
            .unwrap() // parses fine: 40 may exist in some schema
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build(); // ... but not in CRITEO's 26
        assert!(err.is_err(), "selector out of schema must fail at planning");
    }

    #[test]
    fn builder_rejects_zero_pipeline_depth() {
        let err = PipelineBuilder::new()
            .pipeline_depth(0)
            .executor(crate::coordinator::Backend::Gpu.executor())
            .build();
        assert!(err.is_err(), "pipeline_depth 0 must fail at planning");
    }

    /// The tentpole pin at the unit level: pipelined fused output is
    /// bit-identical to the sequential depth-1 path, the reported
    /// effective depth reflects what actually ran, and the engine's
    /// stage split lands in the report.
    #[test]
    fn pipelined_fused_matches_sequential_and_reports_stage_split() {
        use crate::cpu_baseline::{ConfigKind, CpuExecutor};
        let ds = SynthDataset::generate(SynthConfig::small(700));
        let raw = utf8::encode_dataset(&ds);
        let run_with = |depth: usize| {
            let pipeline = PipelineBuilder::new()
                .spec(crate::ops::PipelineSpec::dlrm(997))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(64)
                .strategy(ExecStrategy::Fused)
                .pipeline_depth(depth)
                .executor(Box::new(CpuExecutor::new(ConfigKind::I, 4)))
                .build()
                .unwrap();
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            pipeline.run_collect(&mut src).unwrap()
        };
        let (seq_cols, seq) = run_with(1);
        let (pip_cols, pip) = run_with(4);
        assert_eq!(pip_cols, seq_cols, "pipelined output must be bit-identical");
        assert_eq!(seq.pipeline_depth, 1);
        assert_eq!(pip.pipeline_depth, 4, "stage-split CPU run must report the window");
        assert_eq!(pip.rows, seq.rows);
        assert_eq!(pip.chunks, seq.chunks);
        // Sequential driving leaves the engine-side stage fields zero
        // (the executor timed its own phases); pipelined driving fills
        // them and the executor folds them into the same split.
        assert_eq!(seq.stage_stateless_time, Duration::ZERO);
        assert_eq!(seq.vocab_wait_time, Duration::ZERO);
        assert!(pip.stage_stateless_time > Duration::ZERO, "stateless stage must be timed");
        assert!(pip.observe_time > Duration::ZERO, "vocab stage must fold into observe");
        assert!(pip.process_time > Duration::ZERO);
    }

    /// Source wrapper counting how many `next_chunk` calls arrive with a
    /// fresh (never-recycled) buffer — every capacity-0 handout is one
    /// raw-chunk allocation the engine made.
    struct AllocCounting<'a> {
        inner: MemorySource<'a>,
        fresh: usize,
    }

    impl Source for AllocCounting<'_> {
        fn format(&self) -> InputFormat {
            self.inner.format()
        }
        fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
            if buf.capacity() == 0 {
                self.fresh += 1;
            }
            self.inner.next_chunk(max_bytes, buf)
        }
    }

    /// The peak-memory bound documented at
    /// [`PipelineBuilder::channel_depth`]: a pipelined submission hands
    /// out at most `channel_depth + 2` raw buffers (producer scratch +
    /// queue + one downstream), and the decoded in-flight window is
    /// `pipeline_depth` blocks by construction — together the documented
    /// `(channel_depth + pipeline_depth + 1) × chunk_bytes` ceiling.
    #[test]
    fn pipelined_pool_stays_within_documented_bound() {
        use crate::cpu_baseline::{ConfigKind, CpuExecutor};
        let ds = SynthDataset::generate(SynthConfig::small(900));
        let raw = utf8::encode_dataset(&ds);
        let (channel_depth, pipeline_depth) = (2usize, 3usize);
        let pipeline = PipelineBuilder::new()
            .spec(crate::ops::PipelineSpec::dlrm(997))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(32) // many chunks, so recycling must actually engage
            .strategy(ExecStrategy::Fused)
            .channel_depth(channel_depth)
            .pipeline_depth(pipeline_depth)
            .executor(Box::new(CpuExecutor::new(ConfigKind::I, 2)))
            .build()
            .unwrap();
        let mut src =
            AllocCounting { inner: MemorySource::new(&raw, InputFormat::Utf8), fresh: 0 };
        let (_, report) = pipeline.run_collect(&mut src).unwrap();
        assert!(report.chunks > channel_depth + pipeline_depth + 2, "need recycling pressure");
        assert!(
            src.fresh <= channel_depth + 2,
            "engine allocated {} raw buffers over {} chunks; pool bound is channel_depth + 2 = {}",
            src.fresh,
            report.chunks,
            channel_depth + 2
        );
    }
}
