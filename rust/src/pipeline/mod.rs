//! The composable streaming pipeline engine — the crate's execution API.
//!
//! The paper's central claim is that preprocessing must be *pipelined
//! and streamed* to keep accelerators fed. This module is that seam:
//!
//! ```text
//! Source ──raw chunks──▶ [bounded channel] ──decode──▶ Executor ──blocks──▶ Sink
//! ```
//!
//! * a [`Source`] yields the raw dataset in bounded chunks (in-memory
//!   buffer, file, synthetic generator, TCP stream) and can rewind for
//!   the second vocabulary pass;
//! * a [`Plan`] is built **once** by [`PipelineBuilder::build`] from an
//!   [`crate::ops::PipelineSpec`] plus backend capability checks — a
//!   format mismatch or an over-capacity vocabulary is a *planning*
//!   error, not a runtime failure inside a serving worker;
//! * an [`Executor`] (CPU baseline, GPU model, the three PIPER modes)
//!   consumes decoded-row chunks; all executors share the same
//!   functional core, so outputs are bit-identical across backends;
//! * a [`Sink`] receives processed column blocks as they are produced,
//!   and a [`RunReport`] carries uniformly [`TimeTag`]-tagged results.
//!
//! Execution is chunked with a bounded producer/worker channel sized by
//! `chunk_rows`, so peak resident raw-input memory is a few chunks —
//! never the dataset — and a built [`Pipeline`] can be reused across
//! many submissions (the serving posture the ROADMAP asks for).
//!
//! ```no_run
//! use piper::accel::InputFormat;
//! use piper::coordinator::Backend;
//! use piper::cpu_baseline::ConfigKind;
//! use piper::ops::PipelineSpec;
//! use piper::pipeline::{FileSource, PipelineBuilder};
//! use std::path::Path;
//!
//! # fn main() -> piper::Result<()> {
//! let pipeline = PipelineBuilder::new()
//!     .spec(PipelineSpec::dlrm(5_000))
//!     .input(InputFormat::Utf8)
//!     .chunk_rows(64 * 1024)
//!     .executor(Backend::Cpu { kind: ConfigKind::I, threads: 8 }.executor())
//!     .build()?; // planning errors surface here
//! let mut source = FileSource::open(Path::new("dataset.txt"), InputFormat::Utf8)?;
//! let (columns, report) = pipeline.run_collect(&mut source)?;
//! println!("{} rows at {:.0} rows/s", report.rows, report.e2e_rows_per_sec());
//! # Ok(()) }
//! ```

pub mod executor;
pub mod sink;
pub mod source;

pub use executor::{ChunkState, Executor, ExecutorReport, ExecutorRun, StreamStats};
pub use sink::{CollectSink, CountSink, Sink};
pub use source::{serve_bytes, FileSource, MemorySource, Source, SynthSource, TcpSource};

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::accel::InputFormat;
use crate::data::row::ProcessedColumns;
use crate::data::{DecodedRow, Schema};
use crate::decode::RowAssembler;
use crate::ops::{Modulus, OpFlags, PipelineSpec};
use crate::report::{self, TimeTag};
use crate::Result;

// ---------------------------------------------------------------------
// Incremental decode
// ---------------------------------------------------------------------

/// Incremental decoder that survives arbitrary chunk boundaries — the
/// decode front of the engine, also used by the network worker
/// ([`crate::net::stream`]).
#[derive(Debug)]
pub struct ChunkDecoder(DecoderInner);

#[derive(Debug)]
enum DecoderInner {
    Utf8(RowAssembler),
    Binary { schema: Schema, partial: Vec<u8> },
}

impl ChunkDecoder {
    pub fn new(format: InputFormat, schema: Schema) -> Self {
        ChunkDecoder(match format {
            InputFormat::Utf8 => DecoderInner::Utf8(RowAssembler::new(schema)),
            InputFormat::Binary => DecoderInner::Binary { schema, partial: Vec::new() },
        })
    }

    /// Feed a chunk, returning all rows completed by it.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<Vec<DecodedRow>> {
        match &mut self.0 {
            DecoderInner::Utf8(asm) => {
                asm.feed_bytes(chunk);
                Ok(asm.take_rows())
            }
            DecoderInner::Binary { schema, partial } => {
                partial.extend_from_slice(chunk);
                let rb = schema.binary_row_bytes();
                let full = partial.len() / rb * rb;
                let rows = crate::data::binary::decode_bytes(&partial[..full], *schema)?;
                partial.drain(..full);
                Ok(rows)
            }
        }
    }

    /// Finish the pass; any trailing partial row is completed (UTF-8
    /// without final newline) or rejected (truncated binary row).
    pub fn finish(self) -> Result<Vec<DecodedRow>> {
        match self.0 {
            DecoderInner::Utf8(asm) => Ok(asm.finish()),
            DecoderInner::Binary { partial, .. } => {
                anyhow::ensure!(
                    partial.is_empty(),
                    "binary stream ended mid-row ({} stray bytes)",
                    partial.len()
                );
                Ok(Vec::new())
            }
        }
    }
}

// ---------------------------------------------------------------------
// Plan + builder
// ---------------------------------------------------------------------

/// The validated, immutable execution plan: operator graph (as parsed
/// flags + modulus), schema, input format and chunking. Built once by
/// [`PipelineBuilder::build`]; executors read it, never mutate it.
#[derive(Debug, Clone)]
pub struct Plan {
    pub spec: PipelineSpec,
    pub flags: OpFlags,
    pub modulus: Option<Modulus>,
    pub schema: Schema,
    pub input: InputFormat,
    /// Rows per chunk the engine aims for (the producer/worker channel
    /// is sized in these units).
    pub chunk_rows: usize,
}

impl Plan {
    /// Requested raw bytes per chunk, derived from `chunk_rows` and the
    /// format's approximate row width.
    pub fn chunk_bytes(&self) -> usize {
        let per_row = match self.input {
            InputFormat::Binary => self.schema.binary_row_bytes(),
            // ~2 bytes label+newline, ~7 per dense field, 9 per sparse.
            InputFormat::Utf8 => 2 + 7 * self.schema.num_dense + 9 * self.schema.num_sparse,
        };
        (self.chunk_rows * per_row).max(1)
    }
}

/// Builder for a reusable [`Pipeline`]: operator spec, schema, input
/// format, chunking, executor. All validation happens in [`Self::build`].
pub struct PipelineBuilder {
    spec: PipelineSpec,
    schema: Schema,
    input: InputFormat,
    chunk_rows: usize,
    executor: Option<Box<dyn Executor>>,
}

impl PipelineBuilder {
    pub fn new() -> Self {
        PipelineBuilder {
            spec: PipelineSpec::dlrm(Modulus::VOCAB_5K.range),
            schema: Schema::CRITEO,
            input: InputFormat::Utf8,
            chunk_rows: 64 * 1024,
            executor: None,
        }
    }

    /// Operator pipeline (defaults to the paper's DLRM pipeline at 5K).
    pub fn spec(mut self, spec: PipelineSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Parse a `|`-separated spec string (see [`PipelineSpec::parse`]).
    pub fn spec_str(mut self, spec: &str) -> Result<Self> {
        self.spec = PipelineSpec::parse(spec)?;
        Ok(self)
    }

    pub fn schema(mut self, schema: Schema) -> Self {
        self.schema = schema;
        self
    }

    pub fn input(mut self, input: InputFormat) -> Self {
        self.input = input;
        self
    }

    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.chunk_rows = rows.max(1);
        self
    }

    pub fn executor(mut self, executor: Box<dyn Executor>) -> Self {
        self.executor = Some(executor);
        self
    }

    /// Plan and build. Every capability/config mismatch surfaces here as
    /// a planning error — a built pipeline does not fail on submission
    /// for reasons knowable up front.
    pub fn build(self) -> Result<Pipeline> {
        let executor = self
            .executor
            .ok_or_else(|| anyhow::anyhow!("PipelineBuilder needs an executor"))?;
        self.spec.validate()?;
        let plan = Plan {
            flags: self.spec.flags(),
            modulus: self.spec.modulus(),
            spec: self.spec,
            schema: self.schema,
            input: self.input,
            chunk_rows: self.chunk_rows,
        };
        anyhow::ensure!(
            executor.accepts(plan.input),
            "planning: {} does not accept {:?} input",
            executor.name(),
            plan.input
        );
        executor.plan_check(&plan)?;
        Ok(Pipeline { plan, executor })
    }

    /// Assemble a bare [`Plan`] without an executor — internal helper
    /// for unit tests of executor state.
    pub(crate) fn plan_only(
        spec: PipelineSpec,
        schema: Schema,
        input: InputFormat,
        chunk_rows: usize,
    ) -> Plan {
        Plan {
            flags: spec.flags(),
            modulus: spec.modulus(),
            spec,
            schema,
            input,
            chunk_rows,
        }
    }
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------
// Pipeline + engine loop
// ---------------------------------------------------------------------

/// A planned, reusable preprocessing pipeline: run it over any number of
/// sources; each submission streams with bounded memory.
pub struct Pipeline {
    plan: Plan,
    executor: Box<dyn Executor>,
}

/// Raw chunks in flight between the producer thread and the decode/
/// execute worker. Peak resident raw input ≈ (depth + 2) × chunk_bytes.
const CHANNEL_DEPTH: usize = 2;

impl Pipeline {
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn executor_name(&self) -> String {
        self.executor.name()
    }

    /// Run one submission: stream `source` through the planned operator
    /// graph on this pipeline's executor, pushing column blocks into
    /// `sink` as they are produced.
    pub fn run(&self, source: &mut dyn Source, sink: &mut dyn Sink) -> Result<RunReport> {
        anyhow::ensure!(
            source.format() == self.plan.input,
            "source yields {:?} but the pipeline was planned for {:?}",
            source.format(),
            self.plan.input
        );
        let t0 = Instant::now();
        let mut run = self.executor.begin(&self.plan)?;

        // Pass 1 (GenVocab) only when the plan has stateful vocab ops.
        if self.plan.flags.gen_vocab {
            stream_chunks(&self.plan, &mut *source, |rows| run.observe(rows))?;
            source.reset()?;
        }
        run.seal()?;

        let (raw_bytes, rows, chunks) = stream_chunks(&self.plan, &mut *source, |rows| {
            let block = run.process(rows)?;
            sink.push(&block)
        })?;

        let stats = StreamStats { raw_bytes, rows, chunks, wall: t0.elapsed() };
        let rep = run.finish(&stats)?;
        Ok(RunReport {
            executor: self.executor.name(),
            rows: rows as usize,
            chunks: chunks as usize,
            e2e: rep.modeled_e2e.unwrap_or(stats.wall),
            wall: stats.wall,
            tag: rep.tag,
            compute: rep.compute,
            vocab_entries: rep.vocab_entries,
        })
    }

    /// Run and gather the full output — the drop-in replacement for the
    /// old one-shot drivers.
    pub fn run_collect(&self, source: &mut dyn Source) -> Result<(ProcessedColumns, RunReport)> {
        let mut sink = CollectSink::with_schema(self.plan.schema);
        let report = self.run(source, &mut sink)?;
        Ok((sink.into_columns(), report))
    }
}

/// One streaming pass: a producer thread pulls raw chunks from the
/// source into a bounded channel while this thread decodes them and
/// feeds the executor. Returns `(raw_bytes, rows, chunks)`.
fn stream_chunks<F>(plan: &Plan, source: &mut dyn Source, mut consume: F) -> Result<(u64, u64, u64)>
where
    F: FnMut(&[DecodedRow]) -> Result<()>,
{
    let chunk_bytes = plan.chunk_bytes();
    let mut decoder = ChunkDecoder::new(plan.input, plan.schema);
    let mut raw_bytes = 0u64;
    let mut rows = 0u64;
    let mut chunks = 0u64;

    let passed: Result<()> = std::thread::scope(|scope| {
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(CHANNEL_DEPTH);
        let producer = scope.spawn(move || -> Result<()> {
            while let Some(chunk) = source.next_chunk(chunk_bytes)? {
                if tx.send(chunk).is_err() {
                    break; // consumer bailed; its error wins below
                }
            }
            Ok(())
        });

        let mut consumer_err: Option<anyhow::Error> = None;
        for chunk in &rx {
            raw_bytes += chunk.len() as u64;
            chunks += 1;
            let step = decoder.feed(&chunk).and_then(|decoded| {
                if decoded.is_empty() {
                    return Ok(());
                }
                rows += decoded.len() as u64;
                consume(&decoded)
            });
            if let Err(e) = step {
                consumer_err = Some(e);
                break;
            }
        }
        drop(rx); // unblock the producer if we bailed early

        let produced = producer.join().expect("pipeline source producer panicked");
        match (produced, consumer_err) {
            // A producer error explains any downstream decode error.
            (Err(e), _) => Err(e),
            (Ok(()), Some(e)) => Err(e),
            (Ok(()), None) => Ok(()),
        }
    });
    passed?;

    let tail = decoder.finish()?;
    if !tail.is_empty() {
        rows += tail.len() as u64;
        consume(&tail)?;
    }
    Ok((raw_bytes, rows, chunks))
}

// ---------------------------------------------------------------------
// Run report
// ---------------------------------------------------------------------

/// Uniform, [`TimeTag`]-propagating result of one pipeline submission —
/// the single result type all executors report through.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub executor: String,
    pub rows: usize,
    pub chunks: usize,
    /// End-to-end time: modeled for sim executors, measured wallclock
    /// for the CPU baseline. Check `tag`.
    pub e2e: Duration,
    /// Engine-measured wallclock of this submission (always measured,
    /// regardless of `tag`).
    pub wall: Duration,
    pub tag: TimeTag,
    /// Pure-computation time (the paper's Table 3 scope) where defined.
    pub compute: Option<Duration>,
    pub vocab_entries: usize,
}

impl RunReport {
    pub fn e2e_rows_per_sec(&self) -> f64 {
        report::rows_per_sec(self.rows, self.e2e)
    }

    pub fn compute_rows_per_sec(&self) -> Option<f64> {
        self.compute.map(|c| report::rows_per_sec(self.rows, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, utf8, SynthConfig, SynthDataset};

    #[test]
    fn chunk_decoder_survives_any_boundary() {
        let ds = SynthDataset::generate(SynthConfig::small(60));
        for (format, raw) in [
            (InputFormat::Utf8, utf8::encode_dataset(&ds)),
            (InputFormat::Binary, binary::encode_dataset(&ds)),
        ] {
            for chunk in [1usize, 7, 64, 4096] {
                let mut dec = ChunkDecoder::new(format, ds.schema());
                let mut rows = Vec::new();
                for c in raw.chunks(chunk) {
                    rows.extend(dec.feed(c).unwrap());
                }
                rows.extend(dec.finish().unwrap());
                assert_eq!(rows, ds.rows, "{format:?} chunk {chunk}");
            }
        }
    }

    #[test]
    fn truncated_binary_rejected_at_finish() {
        let ds = SynthDataset::generate(SynthConfig::small(3));
        let mut raw = binary::encode_dataset(&ds);
        raw.pop();
        let mut dec = ChunkDecoder::new(InputFormat::Binary, ds.schema());
        dec.feed(&raw).unwrap();
        assert!(dec.finish().is_err());
    }

    #[test]
    fn builder_requires_an_executor() {
        assert!(PipelineBuilder::new().build().is_err());
    }

    #[test]
    fn builder_rejects_invalid_spec_at_planning() {
        let b = PipelineBuilder::new().spec_str("genvocab"); // needs modulus
        assert!(b.is_err() || b.unwrap().build().is_err());
    }

    #[test]
    fn plan_chunk_bytes_scales_with_rows() {
        let p = PipelineBuilder::plan_only(
            crate::ops::PipelineSpec::dlrm(97),
            Schema::CRITEO,
            InputFormat::Binary,
            1000,
        );
        assert_eq!(p.chunk_bytes(), 1000 * Schema::CRITEO.binary_row_bytes());
    }
}
