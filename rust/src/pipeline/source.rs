//! Chunked raw-row input sources for the streaming engine.
//!
//! A [`Source`] yields the raw dataset bytes (UTF-8 or binary, the
//! paper's two on-disk formats) in bounded chunks. Rewinding for a
//! second pass is an **optional capability** ([`Source::can_rewind`]):
//! only two-pass plans need it — the fused strategy streams any source
//! exactly once. Chunks are written into caller-provided buffers: the
//! engine recycles consumed chunk buffers back to the producer, so a
//! steady-state pass allocates nothing per chunk. Five implementations
//! cover the serving postures the ROADMAP asks for:
//!
//! * [`MemorySource`] — a borrowed in-memory buffer (the old
//!   `run_backend` calling convention); rewindable;
//! * [`FileSource`] — reads a dataset file chunk by chunk; resident
//!   memory is one chunk, never the file; rewindable (seek);
//! * [`SynthSource`] — generates the deterministic synthetic dataset on
//!   the fly (arbitrarily large workloads with no materialization);
//!   rewindable (regenerate);
//! * [`TcpSource`] — streams from a remote dataset server over TCP
//!   (paper Fig. 7d ingest; each pass is one connection); rewindable
//!   (reconnect — a two-pass plan sends the dataset over the wire
//!   twice);
//! * [`ReaderSource`] — wraps any `Read` (a pipe, a socket, stdin, a
//!   decompressor): genuinely one-shot, usable only by fused or
//!   vocabulary-free plans.

use std::io::{Read, Seek, SeekFrom, Write as _};
use std::net::{TcpListener, TcpStream};
use std::path::Path;

use crate::accel::InputFormat;
use crate::data::{utf8, RowGen, SynthConfig};
use crate::Result;

/// A stream of raw dataset bytes.
///
/// `Send` is required so the engine's producer thread can own the source
/// for the duration of a pass.
pub trait Source: Send {
    /// Raw format of the bytes this source yields.
    fn format(&self) -> InputFormat;

    /// Fill `buf` (cleared first, allocation reused) with the next chunk
    /// of at most `max_bytes` bytes; returns `false` when the pass is
    /// over. Chunks may cut rows anywhere — the engine's incremental
    /// decoder handles boundaries.
    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool>;

    /// Whether this source can replay its byte stream from the start
    /// ([`Self::reset`]). Only plans running the two-pass strategy need
    /// it; the engine checks this at submission and the fused strategy
    /// never asks. Default: `false` — rewinding is an opt-in capability
    /// a source must claim by overriding both this and `reset`.
    fn can_rewind(&self) -> bool {
        false
    }

    /// Rewind to the start of the dataset for another pass. The replayed
    /// byte stream must be identical. Sources that return `false` from
    /// [`Self::can_rewind`] keep this default, which fails.
    fn reset(&mut self) -> Result<()> {
        anyhow::bail!("this source cannot rewind (one-shot stream)")
    }

    /// Total bytes per pass, when known in advance.
    fn len_hint(&self) -> Option<u64> {
        None
    }
}

// ---------------------------------------------------------------------
// In-memory buffer
// ---------------------------------------------------------------------

/// Source over a borrowed raw buffer.
#[derive(Debug)]
pub struct MemorySource<'a> {
    raw: &'a [u8],
    format: InputFormat,
    pos: usize,
}

impl<'a> MemorySource<'a> {
    pub fn new(raw: &'a [u8], format: InputFormat) -> Self {
        MemorySource { raw, format, pos: 0 }
    }
}

impl Source for MemorySource<'_> {
    fn format(&self) -> InputFormat {
        self.format
    }

    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
        buf.clear();
        if self.pos >= self.raw.len() {
            return Ok(false);
        }
        let end = (self.pos + max_bytes.max(1)).min(self.raw.len());
        buf.extend_from_slice(&self.raw[self.pos..end]);
        self.pos = end;
        Ok(true)
    }

    fn can_rewind(&self) -> bool {
        true
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.raw.len() as u64)
    }
}

// ---------------------------------------------------------------------
// File reader
// ---------------------------------------------------------------------

/// Source over a dataset file. Holds one chunk at a time; `reset` is a
/// seek back to the start.
#[derive(Debug)]
pub struct FileSource {
    file: std::fs::File,
    format: InputFormat,
    len: u64,
}

impl FileSource {
    pub fn open(path: &Path, format: InputFormat) -> Result<Self> {
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening dataset {}: {e}", path.display()))?;
        let len = file
            .metadata()
            .map_err(|e| anyhow::anyhow!("stat {}: {e}", path.display()))?
            .len();
        Ok(FileSource { file, format, len })
    }
}

impl Source for FileSource {
    fn format(&self) -> InputFormat {
        self.format
    }

    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
        buf.clear();
        // read_to_end on a Take fills the recycled buffer up to the
        // budget with no zero-fill of the dirty capacity.
        let filled = self.file.by_ref().take(max_bytes.max(1) as u64).read_to_end(buf)?;
        Ok(filled > 0)
    }

    fn can_rewind(&self) -> bool {
        true
    }

    fn reset(&mut self) -> Result<()> {
        self.file.seek(SeekFrom::Start(0))?;
        Ok(())
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.len)
    }
}

// ---------------------------------------------------------------------
// Synthetic generator
// ---------------------------------------------------------------------

/// Source that generates the deterministic synthetic dataset row by row
/// and encodes it on the fly — the same bytes
/// [`crate::data::utf8::encode_dataset`] / [`crate::data::binary::encode_dataset`]
/// would materialize, without ever holding the dataset.
#[derive(Debug)]
pub struct SynthSource {
    config: SynthConfig,
    format: InputFormat,
    gen: RowGen,
    /// Persistent scratch row the generator refills in place — without
    /// it every `next_chunk` call would allocate two field `Vec`s per
    /// generated row, and synthetic-input benches would measure source
    /// allocation instead of decode.
    scratch: crate::data::DecodedRow,
    /// Encoded bytes generated but not yet emitted (a row can overshoot
    /// one chunk's byte budget; the excess carries into the next chunk).
    pending: Vec<u8>,
}

impl SynthSource {
    pub fn new(config: SynthConfig, format: InputFormat) -> Self {
        let gen = RowGen::new(config.clone());
        let scratch =
            crate::data::DecodedRow { label: 0, dense: Vec::new(), sparse: Vec::new() };
        SynthSource { config, format, gen, scratch, pending: Vec::new() }
    }
}

impl Source for SynthSource {
    fn format(&self) -> InputFormat {
        self.format
    }

    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
        buf.clear();
        let cap = max_bytes.max(1);
        while self.pending.len() < cap {
            let Some(mask) = self.gen.next_row_into(&mut self.scratch) else { break };
            let row = &self.scratch;
            match self.format {
                InputFormat::Utf8 => utf8::encode_row(row, mask, &mut self.pending),
                InputFormat::Binary => {
                    self.pending.extend_from_slice(&row.label.to_le_bytes());
                    for &d in &row.dense {
                        self.pending.extend_from_slice(&d.to_le_bytes());
                    }
                    for &s in &row.sparse {
                        self.pending.extend_from_slice(&s.to_le_bytes());
                    }
                }
            }
        }
        if self.pending.is_empty() {
            return Ok(false);
        }
        let take = self.pending.len().min(cap);
        buf.extend_from_slice(&self.pending[..take]);
        // The carry is at most one encoded row — a small memmove.
        self.pending.drain(..take);
        Ok(true)
    }

    fn can_rewind(&self) -> bool {
        true
    }

    fn reset(&mut self) -> Result<()> {
        self.gen = RowGen::new(self.config.clone());
        self.pending.clear();
        Ok(())
    }

    fn len_hint(&self) -> Option<u64> {
        match self.format {
            InputFormat::Binary => {
                Some((self.config.rows * self.config.schema.binary_row_bytes()) as u64)
            }
            InputFormat::Utf8 => None, // variable-width rows
        }
    }
}

// ---------------------------------------------------------------------
// TCP stream
// ---------------------------------------------------------------------

/// Source that streams the dataset from a remote server: one connection
/// per pass, read to EOF (the convention [`serve_bytes`] implements).
/// `reset` drops the connection; the next chunk reconnects — so a
/// two-pass plan costs two connections ("the dataset crosses the wire
/// twice"), while a fused plan costs one.
#[derive(Debug)]
pub struct TcpSource {
    addr: String,
    format: InputFormat,
    conn: Option<TcpStream>,
    /// Set once the current pass hit EOF (so next_chunk stops retrying).
    done: bool,
}

impl TcpSource {
    pub fn connect(addr: &str, format: InputFormat) -> Self {
        TcpSource { addr: addr.to_string(), format, conn: None, done: false }
    }
}

impl Source for TcpSource {
    fn format(&self) -> InputFormat {
        self.format
    }

    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
        buf.clear();
        if self.done {
            return Ok(false);
        }
        if self.conn.is_none() {
            let stream = TcpStream::connect(&self.addr)
                .map_err(|e| anyhow::anyhow!("connecting to dataset server {}: {e}", self.addr))?;
            stream.set_nodelay(true)?;
            self.conn = Some(stream);
        }
        let conn = self.conn.as_mut().expect("connection established above");
        let budget = max_bytes.max(1);
        // As for FileSource: fill the recycled buffer without zeroing
        // its dirty capacity. A short read means the peer closed — the
        // end of this pass.
        let filled = conn.take(budget as u64).read_to_end(buf)?;
        if filled < budget {
            self.done = true;
            self.conn = None;
        }
        Ok(filled > 0)
    }

    fn can_rewind(&self) -> bool {
        true // reconnecting replays the dataset (serve_bytes convention)
    }

    fn reset(&mut self) -> Result<()> {
        self.conn = None;
        self.done = false;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// One-shot reader
// ---------------------------------------------------------------------

/// Source over any [`Read`] — a pipe, a socket, stdin, a decompressor.
/// Genuinely one-shot: it cannot rewind, so only fused or
/// vocabulary-free plans accept it. This is the ingestion posture the
/// fused strategy unlocks — a `gen_vocab` pipeline fed straight from a
/// stream that exists only once.
#[derive(Debug)]
pub struct ReaderSource<R: Read + Send> {
    reader: R,
    format: InputFormat,
    done: bool,
}

impl<R: Read + Send> ReaderSource<R> {
    pub fn new(reader: R, format: InputFormat) -> Self {
        ReaderSource { reader, format, done: false }
    }
}

impl<R: Read + Send> Source for ReaderSource<R> {
    fn format(&self) -> InputFormat {
        self.format
    }

    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> Result<bool> {
        buf.clear();
        if self.done {
            return Ok(false);
        }
        let filled = self.reader.by_ref().take(max_bytes.max(1) as u64).read_to_end(buf)?;
        if filled == 0 {
            self.done = true;
        }
        Ok(filled > 0)
    }
    // can_rewind/reset keep the one-shot defaults.
}

/// Serve `passes` copies of `raw` on `listener`, one connection each —
/// the dataset-server side of [`TcpSource`]. Used by tests, the
/// `network_serve` example and ad-hoc loopback setups.
pub fn serve_bytes(listener: &TcpListener, raw: &[u8], passes: usize) -> Result<()> {
    for _ in 0..passes {
        let (mut stream, _addr) = listener.accept()?;
        stream.write_all(raw)?;
        // Dropping the stream closes it; the reader sees EOF.
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, SynthDataset};

    fn drain(src: &mut dyn Source, chunk: usize) -> Vec<u8> {
        let mut out = Vec::new();
        let mut buf = Vec::new();
        while src.next_chunk(chunk, &mut buf).unwrap() {
            assert!(buf.len() <= chunk.max(1), "chunk over budget");
            out.extend_from_slice(&buf);
        }
        out
    }

    #[test]
    fn memory_source_round_trips_and_resets() {
        let raw = b"0\t1\t2\n3\t4\t5\n".to_vec();
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        assert_eq!(drain(&mut src, 5), raw);
        let mut buf = Vec::new();
        assert!(!src.next_chunk(5, &mut buf).unwrap());
        src.reset().unwrap();
        assert_eq!(drain(&mut src, 3), raw);
        assert_eq!(src.len_hint(), Some(raw.len() as u64));
    }

    #[test]
    fn sources_reuse_the_caller_buffer() {
        let raw: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let mut src = MemorySource::new(&raw, InputFormat::Binary);
        let mut buf = Vec::new();
        let mut out = Vec::new();
        while src.next_chunk(1000, &mut buf).unwrap() {
            out.extend_from_slice(&buf);
        }
        assert_eq!(out, raw);
        // The buffer kept its allocation across calls — no regrow after
        // the first chunk.
        assert!(buf.capacity() >= 1000);
    }

    #[test]
    fn synth_source_matches_materialized_encoding() {
        let cfg = SynthConfig::small(120);
        let ds = SynthDataset::generate(cfg.clone());

        let mut u = SynthSource::new(cfg.clone(), InputFormat::Utf8);
        assert_eq!(drain(&mut u, 777), utf8::encode_dataset(&ds));
        u.reset().unwrap();
        assert_eq!(drain(&mut u, 131), utf8::encode_dataset(&ds), "reset replays");

        let mut b = SynthSource::new(cfg.clone(), InputFormat::Binary);
        let bin = binary::encode_dataset(&ds);
        assert_eq!(drain(&mut b, 4096), bin);
        assert_eq!(b.len_hint(), Some(bin.len() as u64));
    }

    #[test]
    fn file_source_streams_in_bounded_chunks() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("piper-src-{}.bin", std::process::id()));
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &payload).unwrap();
        let mut src = FileSource::open(&path, InputFormat::Binary).unwrap();
        assert_eq!(src.len_hint(), Some(10_000));
        assert_eq!(drain(&mut src, 999), payload);
        src.reset().unwrap();
        assert_eq!(drain(&mut src, 10_000), payload);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reader_source_is_one_shot() {
        let raw: Vec<u8> = (0..=255u8).cycle().take(3000).collect();
        let mut src = ReaderSource::new(std::io::Cursor::new(raw.clone()), InputFormat::Binary);
        assert!(!src.can_rewind());
        assert_eq!(drain(&mut src, 700), raw);
        let mut buf = Vec::new();
        assert!(!src.next_chunk(700, &mut buf).unwrap(), "EOF is sticky");
        assert!(src.reset().is_err(), "one-shot source must refuse to rewind");
    }

    #[test]
    fn rewind_capability_matches_reset_behaviour() {
        let raw = b"1\t2\t3\n".to_vec();
        let mem = MemorySource::new(&raw, InputFormat::Utf8);
        assert!(mem.can_rewind());
        let tcp = TcpSource::connect("127.0.0.1:1", InputFormat::Utf8);
        assert!(tcp.can_rewind());
        let synth = SynthSource::new(SynthConfig::small(1), InputFormat::Utf8);
        assert!(synth.can_rewind());
    }

    #[test]
    fn file_source_missing_file_is_an_error() {
        assert!(FileSource::open(Path::new("/no/such/piper-file"), InputFormat::Utf8).is_err());
    }

    #[test]
    fn tcp_source_reads_one_pass_per_connection() {
        let raw: Vec<u8> = (0..5_000u32).flat_map(|v| v.to_le_bytes()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let payload = raw.clone();
        let server = std::thread::spawn(move || serve_bytes(&listener, &payload, 2));

        let mut src = TcpSource::connect(&addr, InputFormat::Binary);
        assert_eq!(drain(&mut src, 512), raw, "pass 1");
        let mut buf = Vec::new();
        assert!(!src.next_chunk(512, &mut buf).unwrap(), "EOF is sticky");
        src.reset().unwrap();
        assert_eq!(drain(&mut src, 2048), raw, "pass 2 reconnects");
        server.join().unwrap().unwrap();
    }
}
