//! Output sinks for the streaming engine.
//!
//! The engine hands each processed column block to a [`Sink`] as soon as
//! the executor produces it — nothing forces the whole output to be
//! resident. [`CollectSink`] reproduces the old one-shot behaviour
//! (gather everything); [`CountSink`] keeps only counters, for
//! bounded-memory serving paths and throughput measurement.

use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::Result;

/// Consumer of processed column blocks, called in row order.
pub trait Sink {
    fn push(&mut self, block: &ProcessedColumns) -> Result<()>;
}

/// Gathers all blocks into one [`ProcessedColumns`] (the Concatenate /
/// CFR stage of the paper, applied incrementally).
#[derive(Debug)]
pub struct CollectSink {
    columns: ProcessedColumns,
}

impl CollectSink {
    pub fn with_schema(schema: Schema) -> Self {
        CollectSink { columns: ProcessedColumns::with_schema(schema) }
    }

    pub fn columns(&self) -> &ProcessedColumns {
        &self.columns
    }

    pub fn into_columns(self) -> ProcessedColumns {
        self.columns
    }
}

impl Sink for CollectSink {
    fn push(&mut self, block: &ProcessedColumns) -> Result<()> {
        self.columns.extend_from(block);
        Ok(())
    }
}

/// Discards the data, keeping only row/block counters — the output side
/// of a bounded-memory run.
#[derive(Debug, Default)]
pub struct CountSink {
    pub rows: usize,
    pub blocks: usize,
}

impl CountSink {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Sink for CountSink {
    fn push(&mut self, block: &ProcessedColumns) -> Result<()> {
        self.rows += block.num_rows();
        self.blocks += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ProcessedRow;

    fn block(schema: Schema, labels: &[i32]) -> ProcessedColumns {
        let mut b = ProcessedColumns::with_schema(schema);
        for &l in labels {
            b.push_row(&ProcessedRow {
                label: l,
                dense: vec![0.5; schema.num_dense],
                sparse: vec![1; schema.num_sparse],
            });
        }
        b
    }

    #[test]
    fn collect_concatenates_in_order() {
        let schema = Schema::new(2, 3);
        let mut sink = CollectSink::with_schema(schema);
        sink.push(&block(schema, &[1, 2])).unwrap();
        sink.push(&block(schema, &[3])).unwrap();
        let cols = sink.into_columns();
        assert_eq!(cols.labels, vec![1, 2, 3]);
        assert_eq!(cols.dense.len(), 2);
        assert_eq!(cols.sparse[0].len(), 3);
    }

    #[test]
    fn count_sink_counts() {
        let schema = Schema::new(1, 1);
        let mut sink = CountSink::new();
        sink.push(&block(schema, &[1, 2, 3])).unwrap();
        sink.push(&block(schema, &[4])).unwrap();
        assert_eq!(sink.rows, 4);
        assert_eq!(sink.blocks, 2);
    }
}
