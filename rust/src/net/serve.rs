//! Online serving mode: low-latency request/response preprocessing
//! against a frozen vocabulary artifact.
//!
//! Batch mode moves a dataset once; serving answers a stream of *small*
//! requests (tens of rows) at inference time — the request-path
//! preprocessing tf.data service disaggregates from training
//! (PAPERS.md). The session protocol:
//!
//! ```text
//! client                                worker
//!   ServeJob  (artifact+policy+depth) →   freeze, validate
//!   ServeRequest (req_id + raw rows)  →   decode → apply → pack
//!                                     ←   ServeResponse (status+rows)
//!   ...                                   ...
//!   ServeEnd                          →
//!                                     ←   ServeReport (p50/p99, misses)
//! ```
//!
//! Every request runs the engine's existing fast path — one
//! [`ChunkDecoder`] scan into a reused [`RowBlock`] scratch, then
//! [`FrozenPlan::apply_block`] (the batch pass-2 hot loop) — so a served
//! row is bit-identical to the batch ApplyVocab result for the same
//! artifact; the serving equivalence suite pins this across wire
//! formats and miss policies.
//!
//! **Admission control**: the worker bounds in-flight requests at the
//! job's `queue_depth`. A request over the bound gets an immediate
//! explicit [`ServeStatus::Overloaded`] response instead of unbounded
//! buffering — the client learns it must back off *now*, not after the
//! queue melts.
//!
//! **Row-level containment**: malformed rows inside a request (illegal
//! bytes, wrong field counts, a misaligned binary tail) no longer fail
//! the whole batch. The request decodes under [`ErrorPolicy::Skip`];
//! well-formed rows are transformed and returned, and the response
//! carries [`ServeStatus::BadRows`] plus the request-relative indices
//! of the contained rows, so the client knows exactly which inputs to
//! fix or drop. Only an oversized request (or one with more malformed
//! rows than [`MAX_BAD_ROW_DETAILS`]) gets [`ServeStatus::BadRequest`];
//! the session keeps serving either way — only a broken *frame* stream
//! ends it.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::data::{RowBlock, Schema};
use crate::decode::{ErrorConfig, ErrorPolicy};
use crate::ops::artifact::VocabArtifact;
use crate::pipeline::{ChunkDecoder, DecodeOptions, FrozenPlan, MissPolicy};
use crate::Result;

use super::protocol::{self, NetError, Tag};
use super::stream::WireFormat;
use super::NetConfig;

/// In-flight bound when the client does not pick one.
pub const DEFAULT_QUEUE_DEPTH: u32 = 32;

/// Hard per-request payload cap — serving frames are small batches; a
/// request this large belongs on the batch protocol.
pub const MAX_REQUEST_BYTES: usize = 1 << 24;

/// Max malformed-row indices a single response reports. A request with
/// more contained rows than this is answered with
/// [`ServeStatus::BadRequest`] instead — at that point the batch is
/// garbage, not a batch with stragglers.
pub const MAX_BAD_ROW_DETAILS: usize = 1 << 16;

/// Rolling latency window: percentiles cover the last this-many
/// requests, so a long session reports current behavior, not its
/// cold-start tail forever.
const LATENCY_WINDOW: usize = 1024;

/// Session header: everything the worker needs to serve — the frozen
/// artifact itself (spec + schema + vocabularies, checksummed), the
/// miss policy, the request wire format, and the admission bound.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeJob {
    pub policy: MissPolicy,
    pub format: WireFormat,
    /// Max in-flight requests before [`ServeStatus::Overloaded`]
    /// replies; 0 means [`DEFAULT_QUEUE_DEPTH`].
    pub queue_depth: u32,
    pub artifact: VocabArtifact,
}

impl ServeJob {
    /// Frame layout: `policy:u8 default:u32 format:u8 depth:u32
    /// artifact:rest` — the artifact crosses the wire in its checksummed
    /// file encoding and is fully re-validated on decode.
    pub fn encode(&self) -> Vec<u8> {
        let artifact = self.artifact.encode();
        let mut out = Vec::with_capacity(10 + artifact.len());
        let (tag, default) = self.policy.to_wire();
        out.push(tag);
        out.extend_from_slice(&default.to_le_bytes());
        out.push(match self.format {
            WireFormat::Utf8 => 0,
            WireFormat::Binary => 1,
        });
        out.extend_from_slice(&self.queue_depth.to_le_bytes());
        out.extend_from_slice(&artifact);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ServeJob> {
        anyhow::ensure!(buf.len() >= 10, "serve job frame must be >= 10 bytes, got {}", buf.len());
        let policy = MissPolicy::from_wire(
            buf[0],
            u32::from_le_bytes([buf[1], buf[2], buf[3], buf[4]]),
        )?;
        let format = match buf[5] {
            0 => WireFormat::Utf8,
            1 => WireFormat::Binary,
            v => anyhow::bail!("bad wire format {v}"),
        };
        let queue_depth = u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]);
        let artifact = VocabArtifact::decode(&buf[10..])?;
        Ok(ServeJob { policy, format, queue_depth, artifact })
    }
}

/// Outcome of one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ServeStatus {
    /// Transformed rows in the payload, every key in vocabulary.
    Ok = 0,
    /// Transformed rows in the payload, minus rows the
    /// [`MissPolicy::RejectRow`] policy dropped.
    RejectedRows = 1,
    /// The request as a whole could not be served (oversized, or more
    /// malformed rows than [`MAX_BAD_ROW_DETAILS`]); payload carries
    /// the reason. The session survives.
    BadRequest = 2,
    /// Admission control refused the request — more than `queue_depth`
    /// requests were in flight. Retry with backoff.
    Overloaded = 3,
    /// Transformed rows in the payload, minus malformed rows the
    /// decoder contained; `bad_rows` lists their request-relative
    /// indices. The well-formed rows are served normally.
    BadRows = 4,
}

impl ServeStatus {
    pub fn from_u8(v: u8) -> Result<ServeStatus> {
        Ok(match v {
            0 => ServeStatus::Ok,
            1 => ServeStatus::RejectedRows,
            2 => ServeStatus::BadRequest,
            3 => ServeStatus::Overloaded,
            4 => ServeStatus::BadRows,
            other => anyhow::bail!("unknown serve status {other}"),
        })
    }
}

/// One response frame: echo of the request id, status, the request's
/// miss accounting, the indices of contained malformed rows, and the
/// transformed rows in [`protocol::pack_rows`] layout (or a UTF-8
/// reason for [`ServeStatus::BadRequest`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    pub req_id: u64,
    pub status: ServeStatus,
    pub misses: u32,
    pub rejected_rows: u32,
    /// Request-relative indices of rows the decoder contained
    /// ([`ServeStatus::BadRows`]); empty otherwise. An index counts
    /// every row of the request in order, kept or contained.
    pub bad_rows: Vec<u32>,
    pub payload: Vec<u8>,
}

impl ServeResponse {
    /// Frame layout: `req_id:u64 status:u8 misses:u32 rejected:u32
    /// nbad:u32 bad_rows:[u32; nbad] payload:rest`.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(21 + 4 * self.bad_rows.len() + self.payload.len());
        out.extend_from_slice(&self.req_id.to_le_bytes());
        out.push(self.status as u8);
        out.extend_from_slice(&self.misses.to_le_bytes());
        out.extend_from_slice(&self.rejected_rows.to_le_bytes());
        out.extend_from_slice(&(self.bad_rows.len() as u32).to_le_bytes());
        for &r in &self.bad_rows {
            out.extend_from_slice(&r.to_le_bytes());
        }
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ServeResponse> {
        anyhow::ensure!(buf.len() >= 21, "serve response must be >= 21 bytes, got {}", buf.len());
        let rd32 = |i: usize| u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
        let mut id = [0u8; 8];
        id.copy_from_slice(&buf[..8]);
        let nbad = rd32(17) as usize;
        anyhow::ensure!(
            nbad <= MAX_BAD_ROW_DETAILS && buf.len() - 21 >= 4 * nbad,
            "serve response truncated: {nbad} bad-row indices in a {}-byte frame",
            buf.len()
        );
        let bad_rows = (0..nbad).map(|i| rd32(21 + 4 * i)).collect();
        Ok(ServeResponse {
            req_id: u64::from_le_bytes(id),
            status: ServeStatus::from_u8(buf[8])?,
            misses: rd32(9),
            rejected_rows: rd32(13),
            bad_rows,
            payload: buf[21 + 4 * nbad..].to_vec(),
        })
    }

    /// Rows in the payload (0 for error statuses).
    pub fn rows(&self, schema: Schema) -> usize {
        self.payload.len() / schema.binary_row_bytes()
    }
}

/// Aggregate session statistics, returned as the final frame.
/// `ok` counts requests answered with transformed rows (including ones
/// RejectRow trimmed or with malformed rows contained); `bad_requests`
/// and `overloaded` count the error replies; the latency percentiles
/// are over the rolling window of the last [`LATENCY_WINDOW`] served
/// requests, admission to response flushed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServeReport {
    pub requests: u64,
    pub ok: u64,
    pub bad_requests: u64,
    pub overloaded: u64,
    /// Rows returned across all responses (after RejectRow trimming).
    pub rows: u64,
    pub misses: u64,
    pub rejected_rows: u64,
    /// Malformed rows contained across all requests ([`ServeStatus::BadRows`]).
    pub bad_rows: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl ServeReport {
    pub fn p50(&self) -> Duration {
        Duration::from_micros(self.p50_us)
    }

    pub fn p99(&self) -> Duration {
        Duration::from_micros(self.p99_us)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(80);
        for v in [
            self.requests,
            self.ok,
            self.bad_requests,
            self.overloaded,
            self.rows,
            self.misses,
            self.rejected_rows,
            self.bad_rows,
            self.p50_us,
            self.p99_us,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ServeReport> {
        anyhow::ensure!(buf.len() == 80, "serve report must be 80 bytes, got {}", buf.len());
        let rd = |i: usize| {
            let mut w = [0u8; 8];
            w.copy_from_slice(&buf[8 * i..8 * i + 8]);
            u64::from_le_bytes(w)
        };
        Ok(ServeReport {
            requests: rd(0),
            ok: rd(1),
            bad_requests: rd(2),
            overloaded: rd(3),
            rows: rd(4),
            misses: rd(5),
            rejected_rows: rd(6),
            bad_rows: rd(7),
            p50_us: rd(8),
            p99_us: rd(9),
        })
    }
}

/// Ring of the last [`LATENCY_WINDOW`] request latencies (µs).
#[derive(Debug, Default)]
struct LatencyWindow {
    samples: Vec<u64>,
    next: usize,
}

impl LatencyWindow {
    fn record(&mut self, us: u64) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(us);
        } else {
            self.samples[self.next] = us;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
    }

    /// The `p`-th percentile (0..=100) by nearest-rank over the window;
    /// 0 when nothing was recorded.
    fn percentile(&self, p: u64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        sorted[(sorted.len() - 1) * p as usize / 100]
    }
}

/// Messages from the acceptor thread to the responder loop.
enum Msg {
    Request { req_id: u64, raw: Vec<u8>, t0: Instant },
    Overloaded { req_id: u64 },
    End,
}

/// Acceptor: read frames, admit or refuse. Admission is a compare-and-
/// bump on the shared in-flight counter — refusals never wait on the
/// processor, so an overloaded worker still answers instantly.
fn accept_loop<R: Read>(
    mut reader: R,
    tx: mpsc::Sender<Msg>,
    in_flight: &AtomicUsize,
    depth: usize,
) -> Result<()> {
    loop {
        let (tag, payload) = protocol::read_frame(&mut reader)?;
        match tag {
            Tag::ServeRequest => {
                anyhow::ensure!(
                    payload.len() >= 8,
                    "serve request of {} bytes has no request id",
                    payload.len()
                );
                let mut id = [0u8; 8];
                id.copy_from_slice(&payload[..8]);
                let req_id = u64::from_le_bytes(id);
                let admitted = in_flight
                    .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                        (n < depth).then_some(n + 1)
                    })
                    .is_ok();
                let msg = if admitted {
                    Msg::Request { req_id, raw: payload[8..].to_vec(), t0: Instant::now() }
                } else {
                    Msg::Overloaded { req_id }
                };
                if tx.send(msg).is_err() {
                    // Responder gone (it owns whatever error ended it).
                    return Ok(());
                }
            }
            Tag::ServeEnd => {
                let _ = tx.send(Msg::End);
                return Ok(());
            }
            other => anyhow::bail!("unexpected frame {other:?} in serving session"),
        }
    }
}

/// Decode and apply one request. Malformed rows are contained per row
/// (skip policy): the well-formed rows are transformed and the
/// contained rows' request-relative indices come back alongside. `Err`
/// is a whole-request, client-attributable reason →
/// [`ServeStatus::BadRequest`]; the session continues either way.
fn apply_request(
    frozen: &FrozenPlan,
    format: WireFormat,
    raw: &[u8],
    scratch: &mut RowBlock,
) -> std::result::Result<(crate::pipeline::ApplyOutcome, Vec<u32>), String> {
    if raw.len() > MAX_REQUEST_BYTES {
        return Err(format!(
            "request of {} bytes exceeds the serving cap of {MAX_REQUEST_BYTES}",
            raw.len()
        ));
    }
    scratch.clear();
    // Sequential decode: serving requests are tens of rows — thread
    // fan-out would cost more than it saves.
    let errors = ErrorConfig {
        policy: ErrorPolicy::Skip,
        detail_cap: MAX_BAD_ROW_DETAILS,
        ..ErrorConfig::default()
    };
    let mut dec = ChunkDecoder::with_options(
        format.into(),
        frozen.schema(),
        DecodeOptions { threads: 1, swar: true, errors },
    );
    dec.feed_into(raw, scratch).map_err(|e| e.to_string())?;
    let tally = dec.finish_into(scratch).map_err(|e| e.to_string())?;
    if tally.errors.total > tally.errors.recorded.len() as u64 {
        return Err(format!(
            "{} malformed rows exceed the per-request detail cap of {MAX_BAD_ROW_DETAILS}",
            tally.errors.total
        ));
    }
    let bad: Vec<u32> =
        tally.errors.recorded.iter().map(|e| e.row.min(u32::MAX as u64) as u32).collect();
    Ok((frozen.apply_block(scratch), bad))
}

/// Run one serving session over an established connection: freeze the
/// job's artifact, then answer requests until `ServeEnd`, and emit the
/// final [`ServeReport`] frame. The acceptor thread keeps reading (and
/// refusing over-bound requests) while the responder transforms — so
/// admission latency stays flat even when the processor is saturated.
pub fn run_session<R, W>(reader: R, writer: &mut W, job: &ServeJob) -> Result<ServeReport>
where
    R: Read + Send,
    W: Write,
{
    let frozen = FrozenPlan::from_artifact(&job.artifact, job.policy)?;
    let schema = frozen.schema();
    let depth = if job.queue_depth == 0 { DEFAULT_QUEUE_DEPTH } else { job.queue_depth } as usize;
    let in_flight = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Msg>();
    let mut report = ServeReport::default();
    let mut window = LatencyWindow::default();
    let mut scratch = RowBlock::new(schema);

    let ended = std::thread::scope(|scope| -> Result<bool> {
        let acceptor = {
            let tx = tx.clone();
            let in_flight = &in_flight;
            scope.spawn(move || accept_loop(reader, tx, in_flight, depth))
        };
        drop(tx); // rx drains to a close once the acceptor exits
        let mut ended = false;
        for msg in rx {
            let resp = match msg {
                Msg::End => {
                    ended = true;
                    break;
                }
                Msg::Overloaded { req_id } => {
                    report.requests += 1;
                    report.overloaded += 1;
                    ServeResponse {
                        req_id,
                        status: ServeStatus::Overloaded,
                        misses: 0,
                        rejected_rows: 0,
                        bad_rows: Vec::new(),
                        payload: Vec::new(),
                    }
                }
                Msg::Request { req_id, raw, t0 } => {
                    report.requests += 1;
                    let resp = match apply_request(&frozen, job.format, &raw, &mut scratch) {
                        Ok((out, bad)) => {
                            report.ok += 1;
                            report.rows += out.columns.num_rows() as u64;
                            report.misses += out.misses;
                            report.rejected_rows += out.rejected_rows;
                            report.bad_rows += bad.len() as u64;
                            ServeResponse {
                                req_id,
                                status: if !bad.is_empty() {
                                    ServeStatus::BadRows
                                } else if out.rejected_rows > 0 {
                                    ServeStatus::RejectedRows
                                } else {
                                    ServeStatus::Ok
                                },
                                misses: out.misses.min(u32::MAX as u64) as u32,
                                rejected_rows: out.rejected_rows.min(u32::MAX as u64) as u32,
                                bad_rows: bad,
                                payload: protocol::pack_columns(&out.columns, schema),
                            }
                        }
                        Err(reason) => {
                            report.bad_requests += 1;
                            ServeResponse {
                                req_id,
                                status: ServeStatus::BadRequest,
                                misses: 0,
                                rejected_rows: 0,
                                bad_rows: Vec::new(),
                                payload: reason.into_bytes(),
                            }
                        }
                    };
                    protocol::write_frame(writer, Tag::ServeResponse, &resp.encode())?;
                    writer.flush()?;
                    in_flight.fetch_sub(1, Ordering::AcqRel);
                    window.record(t0.elapsed().as_micros().min(u64::MAX as u128) as u64);
                    continue;
                }
            };
            // Overloaded refusals: respond immediately, no latency sample.
            protocol::write_frame(writer, Tag::ServeResponse, &resp.encode())?;
            writer.flush()?;
        }
        acceptor.join().map_err(|_| anyhow::anyhow!("serve acceptor panicked"))??;
        Ok(ended)
    })?;
    anyhow::ensure!(ended, "serving stream closed without ServeEnd");

    report.p50_us = window.percentile(50);
    report.p99_us = window.percentile(99);
    protocol::write_frame(writer, Tag::ServeReport, &report.encode())?;
    writer.flush()?;
    Ok(report)
}

/// Client side of the serving protocol — what the CLI `request` command
/// and the serving bench use.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    schema: Schema,
    next_id: u64,
    addr: String,
}

impl ServeClient {
    /// Connect and send the session header (default [`NetConfig`]:
    /// 30 s I/O deadline, no retry on the connect itself).
    pub fn connect(addr: &str, job: &ServeJob) -> Result<ServeClient> {
        Self::connect_once(addr, job, &NetConfig::default(), &super::JobClock::unbounded())
    }

    /// Connect with retry-with-backoff on transient failures (refused
    /// connects while the worker restarts, timeouts) — the graceful-
    /// degradation client posture. Fails fast on non-retryable errors.
    pub fn connect_retry(addr: &str, job: &ServeJob, cfg: &NetConfig) -> Result<ServeClient> {
        let clock = cfg.clock();
        let mut last_err = None;
        for attempt in 0..=cfg.retries {
            if attempt > 0 {
                clock.sleep(cfg.backoff_for(attempt));
            }
            clock
                .check("connecting to serving worker")
                .map_err(|e| last_err.take().unwrap_or(e))?;
            match Self::connect_once(addr, job, cfg, &clock) {
                Ok(client) => return Ok(client),
                Err(e) if NetError::of(&e).is_some_and(NetError::retryable) => {
                    last_err = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("no attempt ran"))
            .context(format!("connect to serving worker {addr}: retries exhausted")))
    }

    fn connect_once(
        addr: &str,
        job: &ServeJob,
        cfg: &NetConfig,
        clock: &super::JobClock,
    ) -> Result<ServeClient> {
        let stream = super::connect(addr, cfg.io_timeout, clock)?;
        let reader = BufReader::with_capacity(1 << 16, stream.try_clone()?);
        let mut writer = BufWriter::with_capacity(1 << 16, stream);
        protocol::write_frame(&mut writer, Tag::ServeJob, &job.encode())?;
        writer.flush()?;
        Ok(ServeClient {
            reader,
            writer,
            schema: job.artifact.schema(),
            next_id: 0,
            addr: addr.to_string(),
        })
    }

    pub fn schema(&self) -> Schema {
        self.schema
    }

    /// Fire one request without waiting for its response; returns the
    /// request id (responses come back in request order).
    pub fn send(&mut self, raw: &[u8]) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let mut payload = Vec::with_capacity(8 + raw.len());
        payload.extend_from_slice(&id.to_le_bytes());
        payload.extend_from_slice(raw);
        protocol::write_frame(&mut self.writer, Tag::ServeRequest, &payload)?;
        self.writer.flush()?;
        Ok(id)
    }

    /// Read the next response; a worker [`Tag::ErrorReply`] surfaces as
    /// a typed [`NetError::JobFailed`] carrying the worker's message.
    pub fn recv(&mut self) -> Result<ServeResponse> {
        let (tag, payload) = protocol::read_frame(&mut self.reader)?;
        match tag {
            Tag::ServeResponse => ServeResponse::decode(&payload),
            Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                worker: self.addr.clone(),
                reason: String::from_utf8_lossy(&payload).into_owned(),
            }),
            other => anyhow::bail!(NetError::Malformed {
                what: format!("unexpected frame {other:?} from worker"),
            }),
        }
    }

    /// One full round trip.
    pub fn request(&mut self, raw: &[u8]) -> Result<ServeResponse> {
        let id = self.send(raw)?;
        let resp = self.recv()?;
        anyhow::ensure!(resp.req_id == id, "response {} for request {id}", resp.req_id);
        Ok(resp)
    }

    /// One round trip with retry-with-backoff on
    /// [`ServeStatus::Overloaded`] refusals — the worker asked us to
    /// back off, so we do, resending the same rows. Gives up with a
    /// typed [`NetError::Overloaded`] when the refusals outlast the
    /// retry budget; transport errors are not retried here (the session
    /// socket is gone — reconnect with [`ServeClient::connect_retry`]).
    pub fn request_retry(&mut self, raw: &[u8], cfg: &NetConfig) -> Result<ServeResponse> {
        let clock = cfg.clock();
        for attempt in 0..=cfg.retries {
            if attempt > 0 {
                clock.sleep(cfg.backoff_for(attempt));
            }
            clock.check("retrying an overloaded serving request")?;
            let resp = self.request(raw)?;
            if resp.status != ServeStatus::Overloaded {
                return Ok(resp);
            }
        }
        Err(anyhow::Error::new(NetError::Overloaded)
            .context("serving request: worker stayed overloaded past the retry budget"))
    }

    /// End the session: drain any outstanding responses and return the
    /// worker's final report alongside them.
    pub fn finish(mut self) -> Result<(ServeReport, Vec<ServeResponse>)> {
        protocol::write_frame(&mut self.writer, Tag::ServeEnd, &[])?;
        self.writer.flush()?;
        let mut late = Vec::new();
        loop {
            let (tag, payload) = protocol::read_frame(&mut self.reader)?;
            match tag {
                Tag::ServeResponse => late.push(ServeResponse::decode(&payload)?),
                Tag::ServeReport => return Ok((ServeReport::decode(&payload)?, late)),
                Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                    worker: self.addr.clone(),
                    reason: String::from_utf8_lossy(&payload).into_owned(),
                }),
                other => anyhow::bail!(NetError::Malformed {
                    what: format!("unexpected frame {other:?} from worker"),
                }),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::PipelineSpec;

    fn tiny_job(policy: MissPolicy, queue_depth: u32) -> ServeJob {
        // Vocabulary {5→0, 12→1} on a 1-dense/1-sparse schema.
        let spec = PipelineSpec::parse("modulus:97|genvocab|applyvocab").unwrap();
        let artifact =
            VocabArtifact::new(spec, Schema::new(1, 1), vec![vec![5, 12]]).unwrap();
        ServeJob { policy, format: WireFormat::Binary, queue_depth, artifact }
    }

    fn bin_rows(rows: &[(i32, i32, u32)]) -> Vec<u8> {
        let mut out = Vec::new();
        for &(label, dense, sparse) in rows {
            out.extend_from_slice(&label.to_le_bytes());
            out.extend_from_slice(&dense.to_le_bytes());
            out.extend_from_slice(&sparse.to_le_bytes());
        }
        out
    }

    /// Script a whole session into a buffer, run it against in-memory
    /// I/O, and hand back the response frames.
    fn run_scripted(job: &ServeJob, requests: &[Vec<u8>]) -> (ServeReport, Vec<ServeResponse>) {
        let mut script = Vec::new();
        for (id, raw) in requests.iter().enumerate() {
            let mut payload = (id as u64).to_le_bytes().to_vec();
            payload.extend_from_slice(raw);
            protocol::write_frame(&mut script, Tag::ServeRequest, &payload).unwrap();
        }
        protocol::write_frame(&mut script, Tag::ServeEnd, &[]).unwrap();

        let mut out = Vec::new();
        let report = run_session(std::io::Cursor::new(script), &mut out, job).unwrap();

        let mut responses = Vec::new();
        let mut r = &out[..];
        loop {
            let (tag, payload) = protocol::read_frame(&mut r).unwrap();
            match tag {
                Tag::ServeResponse => responses.push(ServeResponse::decode(&payload).unwrap()),
                Tag::ServeReport => {
                    assert_eq!(ServeReport::decode(&payload).unwrap(), report);
                    assert!(r.is_empty(), "report must be the last frame");
                    return (report, responses);
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
    }

    #[test]
    fn serve_job_round_trips() {
        for job in [
            tiny_job(MissPolicy::Sentinel, 0),
            tiny_job(MissPolicy::DefaultIndex(3), 8),
            tiny_job(MissPolicy::RejectRow, 1),
        ] {
            assert_eq!(ServeJob::decode(&job.encode()).unwrap(), job);
        }
        assert!(ServeJob::decode(&[1, 2, 3]).is_err(), "truncated header");
        let mut corrupt = tiny_job(MissPolicy::Sentinel, 4).encode();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0xFF;
        assert!(ServeJob::decode(&corrupt).is_err(), "artifact checksum must hold");
    }

    #[test]
    fn serve_response_round_trips() {
        for bad_rows in [vec![], vec![0u32, 3, 17]] {
            let resp = ServeResponse {
                req_id: 7,
                status: ServeStatus::RejectedRows,
                misses: 3,
                rejected_rows: 2,
                bad_rows,
                payload: vec![1, 2, 3, 4],
            };
            assert_eq!(ServeResponse::decode(&resp.encode()).unwrap(), resp);
        }
        assert!(ServeResponse::decode(&[0u8; 5]).is_err());
        assert!(ServeResponse::decode(&[0u8; 20]).is_err(), "pre-bad-rows header rejected");
        // An nbad larger than the remaining bytes must be rejected,
        // never a giant reservation or a slice panic.
        let mut truncated = ServeResponse {
            req_id: 1,
            status: ServeStatus::BadRows,
            misses: 0,
            rejected_rows: 0,
            bad_rows: vec![2],
            payload: Vec::new(),
        }
        .encode();
        truncated.truncate(22);
        assert!(ServeResponse::decode(&truncated).is_err());
    }

    #[test]
    fn serve_report_round_trips() {
        let report = ServeReport {
            requests: 10,
            ok: 7,
            bad_requests: 1,
            overloaded: 2,
            rows: 320,
            misses: 5,
            rejected_rows: 1,
            bad_rows: 4,
            p50_us: 120,
            p99_us: 900,
        };
        assert_eq!(ServeReport::decode(&report.encode()).unwrap(), report);
        assert_eq!(report.p50(), Duration::from_micros(120));
        assert!(ServeReport::decode(&[0u8; 72]).is_err(), "old 72-byte frame rejected");
    }

    #[test]
    fn latency_window_percentiles() {
        let mut w = LatencyWindow::default();
        assert_eq!(w.percentile(99), 0, "empty window");
        for us in 1..=100 {
            w.record(us);
        }
        assert_eq!(w.percentile(0), 1);
        assert_eq!(w.percentile(50), 50);
        assert_eq!(w.percentile(99), 99);
        assert_eq!(w.percentile(100), 100);
        // Rolling: after 2×LATENCY_WINDOW more samples of value 7, old
        // samples are gone.
        for _ in 0..2 * LATENCY_WINDOW {
            w.record(7);
        }
        assert_eq!(w.percentile(99), 7);
    }

    #[test]
    fn scripted_session_serves_and_reports() {
        let job = tiny_job(MissPolicy::Sentinel, 4);
        let schema = job.artifact.schema();
        let (report, responses) = run_scripted(
            &job,
            &[
                bin_rows(&[(1, 7, 12), (0, -3, 5)]), // both in vocabulary
                bin_rows(&[(0, 2, 40)]),             // 40 is a miss
            ],
        );
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].status, ServeStatus::Ok);
        assert_eq!(responses[0].rows(schema), 2);
        let rows = protocol::unpack_rows(&responses[0].payload, schema).unwrap();
        assert_eq!(rows[0].sparse, vec![1]);
        assert_eq!(rows[1].sparse, vec![0]);
        assert_eq!(responses[1].status, ServeStatus::Ok, "sentinel policy still answers");
        assert_eq!(responses[1].misses, 1);
        assert_eq!((report.requests, report.ok, report.rows), (2, 2, 3));
        assert_eq!(report.misses, 1);
        assert!(report.p99_us >= report.p50_us);
    }

    #[test]
    fn bad_requests_do_not_end_the_session() {
        let job = tiny_job(MissPolicy::Sentinel, 4);
        let (report, responses) = run_scripted(
            &job,
            &[
                vec![0u8; MAX_REQUEST_BYTES + 1], // over the serving cap
                bin_rows(&[(1, 7, 5)]),           // still served
            ],
        );
        assert_eq!(responses[0].status, ServeStatus::BadRequest);
        assert!(!responses[0].payload.is_empty(), "reason travels in the payload");
        assert_eq!(responses[1].status, ServeStatus::Ok);
        assert_eq!((report.bad_requests, report.ok), (1, 1));
    }

    /// A misaligned binary request is no longer an all-or-nothing
    /// BadRequest: the complete rows are served and the truncated tail
    /// comes back as a per-row index (the PR-9 serving satellite).
    #[test]
    fn misaligned_binary_tail_is_contained_per_row() {
        let job = tiny_job(MissPolicy::Sentinel, 4);
        let schema = job.artifact.schema();
        let mut raw = bin_rows(&[(1, 7, 12), (0, -3, 5)]);
        raw.extend_from_slice(&[9, 9, 9]); // 3 stray bytes: a truncated third row
        let (report, responses) = run_scripted(&job, &[raw, bin_rows(&[(1, 7, 5)])]);
        assert_eq!(responses[0].status, ServeStatus::BadRows);
        assert_eq!(responses[0].bad_rows, vec![2], "the tail is row 2 of the request");
        assert_eq!(responses[0].rows(schema), 2, "complete rows still served");
        let rows = protocol::unpack_rows(&responses[0].payload, schema).unwrap();
        assert_eq!(rows[0].sparse, vec![1]);
        assert_eq!(rows[1].sparse, vec![0]);
        assert_eq!(responses[1].status, ServeStatus::Ok, "session survives");
        assert_eq!((report.ok, report.bad_requests, report.bad_rows), (2, 0, 1));
    }

    /// UTF-8 requests with malformed rows interleaved: each bad row is
    /// indexed request-relative, the good rows around it are served.
    #[test]
    fn malformed_utf8_rows_are_indexed_and_good_rows_served() {
        let mut job = tiny_job(MissPolicy::Sentinel, 4);
        job.format = WireFormat::Utf8;
        let schema = job.artifact.schema();
        // Sparse fields are hex (c = 12). Rows 1 (illegal byte) and 3
        // (wrong field count) are bad.
        let raw = b"1\t7\tc\n0\t-3\tx5\n0\t2\t5\n1\t9\n0\t4\tc\n".to_vec();
        let (report, responses) = run_scripted(&job, &[raw]);
        assert_eq!(responses[0].status, ServeStatus::BadRows);
        assert_eq!(responses[0].bad_rows, vec![1, 3]);
        assert_eq!(responses[0].rows(schema), 3);
        let rows = protocol::unpack_rows(&responses[0].payload, schema).unwrap();
        assert_eq!(
            rows.iter().map(|r| r.sparse[0]).collect::<Vec<_>>(),
            vec![1, 0, 1],
            "kept rows are exactly the well-formed ones, in order"
        );
        assert_eq!((report.rows, report.bad_rows), (3, 2));
    }

    #[test]
    fn unexpected_frame_ends_the_session_with_an_error() {
        let job = tiny_job(MissPolicy::Sentinel, 4);
        let mut script = Vec::new();
        protocol::write_frame(&mut script, Tag::Pass1Chunk, b"nope").unwrap();
        let mut out = Vec::new();
        let err = run_session(std::io::Cursor::new(script), &mut out, &job);
        assert!(err.is_err());
    }

    #[test]
    fn hangup_without_serve_end_is_an_error() {
        let job = tiny_job(MissPolicy::Sentinel, 4);
        let mut script = Vec::new();
        let mut payload = 0u64.to_le_bytes().to_vec();
        payload.extend_from_slice(&bin_rows(&[(1, 7, 5)]));
        protocol::write_frame(&mut script, Tag::ServeRequest, &payload).unwrap();
        let mut out = Vec::new();
        assert!(run_session(std::io::Cursor::new(script), &mut out, &job).is_err());
    }
}
