//! Multi-accelerator deployment: shard the dataset across several PIPER
//! workers (paper §3.4.2 — "the disaggregated architecture offers the
//! flexibility to scale the number of FPGAs ... individually"; §4.4.6 —
//! "using multiple FPGAs can further improve the overall performance").
//!
//! The interesting part is the *stateful* operator: each worker builds
//! sub-vocabularies over its row shard in pass 1, the leader gathers and
//! merges them in shard order (deterministically equivalent to a single
//! sequential scan, the same argument as for CPU threads), broadcasts
//! the merged vocabularies, and pass 2 runs sharded with the global
//! state. Exactly one synchronization point — the same merge the CPU
//! baseline pays per-thread, paid once per worker here.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::Result;

use super::protocol::{self, Job, RunStats, Tag};

/// Result of a cluster run.
#[derive(Debug)]
pub struct ClusterRun {
    pub processed: ProcessedColumns,
    pub stats: RunStats,
    pub workers: usize,
    pub wallclock: Duration,
}

/// One leader-side worker connection.
struct WorkerConn {
    writer: std::io::BufWriter<TcpStream>,
    reader: std::io::BufReader<TcpStream>,
    shard: std::ops::Range<usize>,
}

/// Split a raw buffer into `n` contiguous shards on row boundaries.
pub fn shard_rows(raw: &[u8], schema: Schema, binary: bool, n: usize) -> Vec<std::ops::Range<usize>> {
    let n = n.max(1);
    if binary {
        let rb = schema.binary_row_bytes();
        let rows = raw.len() / rb;
        crate::cpu_baseline::pipeline::partition_rows(rows, n)
            .into_iter()
            .map(|r| r.start * rb..r.end * rb)
            .collect()
    } else {
        // cut at the newline nearest each equal byte split
        let mut cuts = vec![0usize];
        for i in 1..n {
            let target = raw.len() * i / n;
            let cut = raw[target..]
                .iter()
                .position(|&b| b == b'\n')
                .map(|p| target + p + 1)
                .unwrap_or(raw.len());
            let floor = cuts.last().copied().unwrap_or(0);
            cuts.push(cut.max(floor));
        }
        cuts.push(raw.len());
        (0..n).map(|i| cuts[i]..cuts[i + 1]).collect()
    }
}

/// Run a sharded two-pass job against `addrs` workers.
///
/// The cluster path is inherently two-pass: the global vocabulary merge
/// is a barrier *between* the passes, so no worker may emit a row until
/// every worker has observed its whole shard — the fused single-pass
/// strategy cannot apply here, which is why the engine retains the
/// two-pass protocol at all.
pub fn run_cluster(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<ClusterRun> {
    anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one worker");
    let start = Instant::now();
    let binary = matches!(job.format, super::stream::WireFormat::Binary);
    let shards = shard_rows(raw, job.schema, binary, addrs.len());

    // connect + send job + pass 1 per worker
    let mut conns = Vec::with_capacity(addrs.len());
    for (addr, shard) in addrs.iter().zip(shards) {
        let stream = TcpStream::connect(addr.as_str())?;
        stream.set_nodelay(true)?;
        let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream.try_clone()?);
        let reader = std::io::BufReader::with_capacity(1 << 20, stream);
        protocol::write_frame(&mut writer, Tag::Job, &job.encode())?;
        for chunk in raw[shard.clone()].chunks(chunk_size.max(1)) {
            protocol::write_frame(&mut writer, Tag::Pass1Chunk, chunk)?;
        }
        protocol::write_frame(&mut writer, Tag::Pass1End, &[])?;
        protocol::write_frame(&mut writer, Tag::VocabSync, &[])?;
        use std::io::Write as _;
        writer.flush()?;
        conns.push(WorkerConn { writer, reader, shard });
    }

    // gather sub-vocabularies, merge in shard order
    let mut merged: Vec<crate::ops::HashVocab> =
        (0..job.schema.num_sparse).map(|_| Default::default()).collect();
    for conn in conns.iter_mut() {
        let (tag, payload) = protocol::read_frame(&mut conn.reader)?;
        if tag == Tag::ErrorReply {
            anyhow::bail!("worker error: {}", String::from_utf8_lossy(&payload));
        }
        anyhow::ensure!(tag == Tag::VocabDump, "expected VocabDump, got {tag:?}");
        let cols = protocol::unpack_vocabs(&payload)?;
        anyhow::ensure!(cols.len() == merged.len(), "worker vocab column mismatch");
        use crate::ops::Vocab as _;
        for (dst, keys) in merged.iter_mut().zip(cols) {
            for k in keys {
                dst.observe(k);
            }
        }
    }
    let global: Vec<Vec<u32>> = merged
        .iter()
        .map(|v| v.iter_ordered().map(|(k, _)| k).collect())
        .collect();
    let vocab_entries: usize = global.iter().map(|c| c.len()).sum();

    // broadcast merged vocabularies + pass 2, collecting results per
    // worker on a reader thread (streams overlap). The merged payload
    // is serialized once — it can be many megabytes for large
    // per-column vocabularies.
    let packed = protocol::pack_vocabs(&global);
    let mut collectors = Vec::new();
    for mut conn in conns {
        protocol::write_frame(&mut conn.writer, Tag::VocabLoad, &packed)?;
        let schema = job.schema;
        let reader_handle = std::thread::spawn(move || -> Result<ProcessedColumns> {
            let mut cols = ProcessedColumns::with_schema(schema);
            loop {
                let (tag, payload) = protocol::read_frame(&mut conn.reader)?;
                match tag {
                    Tag::ResultChunk => {
                        for row in protocol::unpack_rows(&payload, schema)? {
                            cols.push_row(&row);
                        }
                    }
                    Tag::ResultEnd => return Ok(cols),
                    Tag::ErrorReply => {
                        anyhow::bail!("worker error: {}", String::from_utf8_lossy(&payload))
                    }
                    other => anyhow::bail!("unexpected {other:?} in pass 2"),
                }
            }
        });
        // keep writing on this thread
        for chunk in raw[conn.shard.clone()].chunks(chunk_size.max(1)) {
            protocol::write_frame(&mut conn.writer, Tag::Pass2Chunk, chunk)?;
        }
        protocol::write_frame(&mut conn.writer, Tag::Pass2End, &[])?;
        use std::io::Write as _;
        conn.writer.flush()?;
        collectors.push(reader_handle);
    }

    // concatenate shard outputs in order (the CFR step)
    let mut processed = ProcessedColumns::with_schema(job.schema);
    for h in collectors {
        let part = h.join().map_err(|_| anyhow::anyhow!("collector panicked"))??;
        processed.extend_from(&part);
    }
    let rows = processed.num_rows() as u64;
    Ok(ClusterRun {
        processed,
        stats: RunStats { rows, vocab_entries: vocab_entries as u64 },
        workers: addrs.len(),
        wallclock: start.elapsed(),
    })
}

/// Spawn `n` loopback workers and run a sharded job against them.
pub fn run_cluster_loopback(
    n: usize,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<ClusterRun> {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n.max(1) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        handles.push(std::thread::spawn(move || super::worker::serve_one(&listener)));
    }
    let run = run_cluster(&addrs, job, raw, chunk_size)?;
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    }
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::net::stream::WireFormat;
    use crate::ops::Modulus;

    fn reference(ds: &SynthDataset, m: Modulus) -> ProcessedColumns {
        let raw = utf8::encode_dataset(ds);
        crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        )
        .processed
    }

    #[test]
    fn cluster_sizes_agree_with_single_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(240));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let want = reference(&ds, m);
        for n in [1usize, 2, 4] {
            let run = run_cluster_loopback(n, &job, &raw, 777).unwrap();
            assert_eq!(run.workers, n);
            assert_eq!(run.processed, want, "{n} workers must equal sequential scan");
        }
    }

    #[test]
    fn cluster_binary_format() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let m = Modulus::new(499);
        let raw = binary::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Binary);
        let run = run_cluster_loopback(3, &job, &raw, 512).unwrap();
        assert_eq!(run.stats.rows, 150);
        assert_eq!(run.processed, reference(&ds, m));
    }

    /// The cluster's vocabulary merge is per column, so per-column
    /// programs shard too: a heterogeneous job across workers equals
    /// the sequential reference.
    #[test]
    fn cluster_heterogeneous_spec_agrees_with_single_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let spec = crate::ops::PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             sparse[5]: modulus:53; \
             dense[*]: neg2zero|log; \
             dense[1]: clip:0:50|bucketize:2:8:32",
        )
        .unwrap();
        let want = spec.execute(&ds.rows, ds.schema()).unwrap();
        let raw = utf8::encode_dataset(&ds);
        let job = Job { schema: ds.schema(), spec, format: WireFormat::Utf8 };
        for n in [1usize, 3] {
            let run = run_cluster_loopback(n, &job, &raw, 619).unwrap();
            assert_eq!(run.processed, want, "{n} workers");
        }
    }

    #[test]
    fn shards_cover_and_respect_rows() {
        let ds = SynthDataset::generate(SynthConfig::small(101));
        let raw = utf8::encode_dataset(&ds);
        for n in [1usize, 2, 5, 8] {
            let shards = shard_rows(&raw, ds.schema(), false, n);
            assert_eq!(shards.len(), n);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, raw.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                // every shard ends on a row boundary
                if w[0].end > 0 {
                    assert_eq!(raw[w[0].end - 1], b'\n');
                }
            }
        }
    }

    #[test]
    fn vocab_frame_roundtrip() {
        let cols = vec![vec![5u32, 1, 9], vec![], vec![42]];
        let packed = protocol::pack_vocabs(&cols);
        assert_eq!(protocol::unpack_vocabs(&packed).unwrap(), cols);
        assert!(protocol::unpack_vocabs(&packed[..packed.len() - 1]).is_err());
    }
}
