//! Multi-accelerator deployment: shard the dataset across several PIPER
//! workers (paper §3.4.2 — "the disaggregated architecture offers the
//! flexibility to scale the number of FPGAs ... individually"; §4.4.6 —
//! "using multiple FPGAs can further improve the overall performance").
//!
//! Since the preprocessing service ([`crate::service`]) landed, this
//! module is a thin client of it: [`run_cluster`] splits the input on
//! row boundaries ([`shard_rows`]), hands the splits to the service
//! dispatcher, and repackages the [`crate::service::ServiceRun`] as the
//! historical [`ClusterRun`] shape. The old two-pass protocol — every
//! worker observes its whole shard, the leader gathers and merges
//! sub-vocabularies, broadcasts them, and only then may pass 2 emit a
//! row — is gone from this path: vocabulary columns are *owned* by
//! workers (hash partition) and index assignment happens online as
//! splits stream, so the whole cluster runs the fused single-pass
//! dataflow with no global merge barrier. (Workers still speak the
//! two-pass wire protocol for compatibility; nothing here sends it.)
//!
//! Determinism is unchanged: split order defines both the vocabulary
//! fold order and the output concatenation order, so which worker
//! served which attempt of which split is invisible in the output —
//! bit-identical to a single sequential scan, pinned by the chaos and
//! scale-out suites. Fault tolerance is unchanged in contract (split
//! re-dispatch with capped backoff, struck workers leave the rotation,
//! typed [`NetError`]s inside the job deadline) and stronger in
//! mechanism: a struck worker's columns are re-owned by survivors and
//! re-seeded from the dispatcher's vocabulary mirror.

use std::time::Duration;

use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::service::{ServiceConfig, WorkerStats};
use crate::Result;

use super::protocol::{Job, RunStats};
use super::NetConfig;

/// Result of a cluster run.
#[derive(Debug)]
pub struct ClusterRun {
    pub processed: ProcessedColumns,
    /// Totals across all splits; the containment counters
    /// (`rows_skipped`, `rows_quarantined`, `illegal_bytes`) are the
    /// per-worker counters summed, and `vocab_entries` comes from the
    /// dispatcher's authoritative vocabulary mirror.
    pub stats: RunStats,
    pub workers: usize,
    pub wallclock: Duration,
    /// Recovery actions performed (0 on a clean run).
    pub retries: u64,
    /// Failure events observed (connects refused, sessions severed,
    /// timeouts, integrity mismatches).
    pub faults: u64,
    /// Per-worker split counts and merged stage-level stats.
    pub per_worker: Vec<WorkerStats>,
}

/// Split a raw buffer into at most `n` contiguous, non-overlapping,
/// non-empty shards on row boundaries, covering `raw` exactly.
///
/// Fewer than `n` shards come back when the input has fewer rows than
/// `n` (never an empty shard — an empty shard would dispatch a no-op
/// session and, worse, make "rows observed" checks vacuous). A UTF-8
/// input without a trailing newline keeps its final partial row in the
/// last shard; a misaligned binary tail also lands in the last shard so
/// the worker rejects it instead of the leader silently dropping bytes.
pub fn shard_rows(raw: &[u8], schema: Schema, binary: bool, n: usize) -> Vec<std::ops::Range<usize>> {
    let n = n.max(1);
    if raw.is_empty() {
        return Vec::new();
    }
    let mut shards: Vec<std::ops::Range<usize>> = if binary {
        let rb = schema.binary_row_bytes();
        let rows = raw.len() / rb;
        if rows == 0 {
            // Only a partial row: one shard; the worker reports the
            // misalignment.
            return vec![0..raw.len()];
        }
        let mut out: Vec<std::ops::Range<usize>> =
            crate::cpu_baseline::pipeline::partition_rows(rows, n)
                .into_iter()
                .map(|r| r.start * rb..r.end * rb)
                .collect();
        // A misaligned tail travels with the last shard.
        if let Some(last) = out.last_mut() {
            last.end = raw.len().max(last.end);
        }
        out
    } else {
        // Cut at the newline nearest each equal byte split. When n
        // exceeds the row count several targets resolve to the same
        // cut — the floor clamp makes them empty and the filter below
        // removes them.
        let mut cuts = vec![0usize];
        for i in 1..n {
            let target = raw.len() * i / n;
            let cut = raw[target..]
                .iter()
                .position(|&b| b == b'\n')
                .map(|p| target + p + 1)
                .unwrap_or(raw.len());
            let floor = cuts.last().copied().unwrap_or(0);
            cuts.push(cut.max(floor));
        }
        cuts.push(raw.len());
        (0..n).map(|i| cuts[i]..cuts[i + 1]).collect()
    };
    shards.retain(|s| !s.is_empty());
    shards
}

/// Rows a worker must account for (emitted + contained) over `shard` —
/// the integrity check that turns a dropped frame into a typed error.
pub(crate) fn expected_rows(shard: &[u8], schema: Schema, binary: bool) -> u64 {
    if binary {
        (shard.len() / schema.binary_row_bytes()) as u64
    } else {
        let full = crate::data::utf8::count_rows(shard);
        let partial_tail = !shard.is_empty() && shard[shard.len() - 1] != b'\n';
        (full + usize::from(partial_tail)) as u64
    }
}

/// Run a sharded job against `addrs` workers with the default
/// [`NetConfig`] (30 s I/O deadline, 2 retries per split).
pub fn run_cluster(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<ClusterRun> {
    run_cluster_cfg(addrs, job, raw, chunk_size, &NetConfig::default())
}

/// Run a sharded job against `addrs` workers: one split per worker,
/// dispatched through the preprocessing service (fused single-pass,
/// shard-owned vocabularies — see [`crate::service`]).
pub fn run_cluster_cfg(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    cfg: &NetConfig,
) -> Result<ClusterRun> {
    anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one worker");
    let binary = matches!(job.format, super::stream::WireFormat::Binary);
    let shards = shard_rows(raw, job.schema, binary, addrs.len());
    let scfg = ServiceConfig {
        net: *cfg,
        window: 0,
        decode_threads: 0,
        chunk_bytes: chunk_size.max(1),
    };
    let run = crate::service::run_service_cfg(addrs, job, raw, &shards, &scfg)?;
    Ok(ClusterRun {
        processed: run.processed,
        stats: run.stats,
        workers: run.workers,
        wallclock: run.wallclock,
        retries: run.retries,
        faults: run.faults,
        per_worker: run.per_worker,
    })
}

/// Spawn `n` loopback workers and run a sharded job against them. The
/// workers run [`super::worker::serve_until`] accept loops — they
/// survive failed sessions and serve retries — and are shut down
/// (drained) when the run completes.
pub fn run_cluster_loopback(
    n: usize,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<ClusterRun> {
    run_cluster_loopback_cfg(n, job, raw, chunk_size, &NetConfig::default())
}

/// [`run_cluster_loopback`] with explicit fault-tolerance knobs.
pub fn run_cluster_loopback_cfg(
    n: usize,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    cfg: &NetConfig,
) -> Result<ClusterRun> {
    let mut addrs = Vec::new();
    let mut shutdowns = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n.max(1) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        let shutdown = super::worker::ShutdownHandle::new(&listener)?;
        shutdowns.push(shutdown.clone());
        handles.push(std::thread::spawn(move || {
            super::worker::serve_until(&listener, &shutdown, &super::worker::WorkerOptions::default())
        }));
    }
    let run = run_cluster_cfg(&addrs, job, raw, chunk_size, cfg);
    for s in &shutdowns {
        s.shutdown();
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::net::protocol;
    use crate::net::stream::WireFormat;
    use crate::ops::Modulus;

    fn reference(ds: &SynthDataset, m: Modulus) -> ProcessedColumns {
        let raw = utf8::encode_dataset(ds);
        crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        )
        .processed
    }

    #[test]
    fn cluster_sizes_agree_with_single_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(240));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let want = reference(&ds, m);
        for n in [1usize, 2, 4] {
            let run = run_cluster_loopback(n, &job, &raw, 777).unwrap();
            assert_eq!(run.workers, n);
            assert_eq!(run.processed, want, "{n} workers must equal sequential scan");
            assert_eq!((run.retries, run.faults), (0, 0), "clean run retries nothing");
        }
    }

    #[test]
    fn cluster_binary_format() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let m = Modulus::new(499);
        let raw = binary::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Binary);
        let run = run_cluster_loopback(3, &job, &raw, 512).unwrap();
        assert_eq!(run.stats.rows, 150);
        assert_eq!(run.processed, reference(&ds, m));
    }

    /// The cluster's vocabulary merge is per column, so per-column
    /// programs shard too: a heterogeneous job across workers equals
    /// the sequential reference.
    #[test]
    fn cluster_heterogeneous_spec_agrees_with_single_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let spec = crate::ops::PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             sparse[5]: modulus:53; \
             dense[*]: neg2zero|log; \
             dense[1]: clip:0:50|bucketize:2:8:32",
        )
        .unwrap();
        let want = spec.execute(&ds.rows, ds.schema()).unwrap();
        let raw = utf8::encode_dataset(&ds);
        let job =
            Job { schema: ds.schema(), spec, format: WireFormat::Utf8, errors: Default::default() };
        for n in [1usize, 3] {
            let run = run_cluster_loopback(n, &job, &raw, 619).unwrap();
            assert_eq!(run.processed, want, "{n} workers");
        }
    }

    /// More workers than rows: the leader must not dispatch empty
    /// shards, and the output still equals the sequential scan.
    #[test]
    fn more_workers_than_rows_still_agrees() {
        let ds = SynthDataset::generate(SynthConfig::small(3));
        let m = Modulus::new(97);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let run = run_cluster_loopback(8, &job, &raw, 64).unwrap();
        assert_eq!(run.stats.rows, 3);
        assert_eq!(run.processed, reference(&ds, m));
    }

    #[test]
    fn shards_cover_and_respect_rows() {
        let ds = SynthDataset::generate(SynthConfig::small(101));
        let raw = utf8::encode_dataset(&ds);
        for n in [1usize, 2, 5, 8] {
            let shards = shard_rows(&raw, ds.schema(), false, n);
            assert!(!shards.is_empty() && shards.len() <= n);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, raw.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                // every shard ends on a row boundary
                if w[0].end > 0 {
                    assert_eq!(raw[w[0].end - 1], b'\n');
                }
            }
        }
    }

    /// Property test over row counts × shard counts × formats ×
    /// trailing-newline presence: shards are always contiguous,
    /// non-overlapping, non-empty, fully covering, row-aligned, and
    /// their expected-row counts sum to the input's row count.
    #[test]
    fn shard_rows_properties_hold_under_fuzz() {
        let mut g = crate::util::prng::XorShift64::new(0xC1A0_5EED);
        for case in 0..300 {
            let rows = (g.next_u64() % 40) as usize;
            let n = 1 + (g.next_u64() % 12) as usize;
            let binary_fmt = g.next_u64() % 2 == 0;
            let trailing_newline = g.next_u64() % 2 == 0;
            let ds = SynthDataset::generate(SynthConfig::small(rows.max(1)));
            let schema = ds.schema();
            let mut raw = if binary_fmt {
                binary::encode_dataset(&ds)
            } else {
                utf8::encode_dataset(&ds)
            };
            if rows == 0 {
                raw.clear();
            }
            if !binary_fmt && !trailing_newline && raw.last() == Some(&b'\n') {
                raw.pop(); // final row without its newline
            }
            let total_rows = if rows == 0 { 0 } else { ds.rows.len() } as u64;
            let shards = shard_rows(&raw, schema, binary_fmt, n);

            assert!(shards.len() <= n, "case {case}: {} shards for n={n}", shards.len());
            assert!(shards.iter().all(|s| !s.is_empty()), "case {case}: empty shard");
            if raw.is_empty() {
                assert!(shards.is_empty(), "case {case}");
                continue;
            }
            assert_eq!(shards[0].start, 0, "case {case}");
            assert_eq!(shards.last().unwrap().end, raw.len(), "case {case}: full coverage");
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start, "case {case}: contiguous, non-overlapping");
                if !binary_fmt {
                    assert_eq!(raw[w[0].end - 1], b'\n', "case {case}: row-aligned cut");
                }
            }
            let counted: u64 = shards
                .iter()
                .map(|s| expected_rows(&raw[s.clone()], schema, binary_fmt))
                .sum();
            assert_eq!(counted, total_rows, "case {case}: row counts partition the input");
        }
    }

    #[test]
    fn shard_exactly_at_raw_len_and_no_trailing_newline() {
        // A cut target landing past the last newline must clamp to
        // raw.len() exactly once, and the partial final row stays in
        // the last shard.
        let raw = b"1,2,3\n4,5,6\n7,8,9"; // no trailing newline
        let schema = crate::data::Schema::new(1, 1);
        for n in [2usize, 3, 5, 17] {
            let shards = shard_rows(raw, schema, false, n);
            assert_eq!(shards.last().unwrap().end, raw.len());
            assert!(shards.iter().all(|s| !s.is_empty()));
            let rows: u64 = shards.iter().map(|s| expected_rows(&raw[s.clone()], schema, false)).sum();
            assert_eq!(rows, 3, "n={n}");
        }
    }

    #[test]
    fn vocab_frame_roundtrip() {
        let cols = vec![vec![5u32, 1, 9], vec![], vec![42]];
        let packed = protocol::pack_vocabs(&cols);
        assert_eq!(protocol::unpack_vocabs(&packed).unwrap(), cols);
        assert!(protocol::unpack_vocabs(&packed[..packed.len() - 1]).is_err());
    }
}
