//! Multi-accelerator deployment: shard the dataset across several PIPER
//! workers (paper §3.4.2 — "the disaggregated architecture offers the
//! flexibility to scale the number of FPGAs ... individually"; §4.4.6 —
//! "using multiple FPGAs can further improve the overall performance").
//!
//! The interesting part is the *stateful* operator: each worker builds
//! sub-vocabularies over its row shard in pass 1, the leader gathers and
//! merges them in shard order (deterministically equivalent to a single
//! sequential scan, the same argument as for CPU threads), broadcasts
//! the merged vocabularies, and pass 2 runs sharded with the global
//! state. Exactly one synchronization point — the same merge the CPU
//! baseline pays per-thread, paid once per worker here.
//!
//! # Split-level recovery
//!
//! The unit of work *and of retry* is the shard, not the worker. When a
//! shard's session fails or times out — in either pass — the shard is
//! re-dispatched to the next worker in rotation with capped exponential
//! backoff ([`NetConfig::backoff_for`]); a worker whose *connect* is
//! refused is struck from the rotation (process dead), while a
//! mid-session failure leaves the worker eligible (often only the
//! connection died). A pass-2 retry opens a fresh session that skips
//! pass 1 entirely (`Job → Pass1End → VocabLoad → Pass2…` — legal
//! because an empty pass 1 is legal) since the merged vocabularies are
//! already global.
//!
//! Determinism under retry: sub-vocabulary dumps are *per shard* and
//! merged in shard order, and shard outputs are concatenated in shard
//! order — so which worker served which attempt of which shard is
//! invisible in the output. The chaos suite pins this bit-identical.
//! Integrity under faults: every pass-1 dump carries the rows the
//! worker observed (kept *and* contained — invariant under the error
//! policy) and every pass-2 `ResultEnd` the rows it emitted plus the
//! rows it skipped or quarantined; the leader checks both sums against
//! the shard's true row count, so a dropped frame is a typed,
//! retryable error — never silent skew, even on dirty input.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::data::row::ProcessedColumns;
use crate::data::Schema;
use crate::Result;

use super::protocol::{self, Job, NetError, RunStats, Tag};
use super::{JobClock, NetConfig};

/// Result of a cluster run.
#[derive(Debug)]
pub struct ClusterRun {
    pub processed: ProcessedColumns,
    /// Totals across all shards; the containment counters
    /// (`rows_skipped`, `rows_quarantined`, `illegal_bytes`) are the
    /// per-worker pass-2 counters summed in shard order.
    pub stats: RunStats,
    pub workers: usize,
    pub wallclock: Duration,
    /// Shard re-dispatch attempts performed (0 on a clean run).
    pub retries: u64,
    /// Failed shard attempts observed (connects refused, sessions
    /// severed, timeouts, integrity mismatches).
    pub faults: u64,
}

/// Split a raw buffer into at most `n` contiguous, non-overlapping,
/// non-empty shards on row boundaries, covering `raw` exactly.
///
/// Fewer than `n` shards come back when the input has fewer rows than
/// `n` (never an empty shard — an empty shard would dispatch a no-op
/// session and, worse, make "rows observed" checks vacuous). A UTF-8
/// input without a trailing newline keeps its final partial row in the
/// last shard; a misaligned binary tail also lands in the last shard so
/// the worker rejects it instead of the leader silently dropping bytes.
pub fn shard_rows(raw: &[u8], schema: Schema, binary: bool, n: usize) -> Vec<std::ops::Range<usize>> {
    let n = n.max(1);
    if raw.is_empty() {
        return Vec::new();
    }
    let mut shards: Vec<std::ops::Range<usize>> = if binary {
        let rb = schema.binary_row_bytes();
        let rows = raw.len() / rb;
        if rows == 0 {
            // Only a partial row: one shard; the worker reports the
            // misalignment.
            return vec![0..raw.len()];
        }
        let mut out: Vec<std::ops::Range<usize>> =
            crate::cpu_baseline::pipeline::partition_rows(rows, n)
                .into_iter()
                .map(|r| r.start * rb..r.end * rb)
                .collect();
        // A misaligned tail travels with the last shard.
        if let Some(last) = out.last_mut() {
            last.end = raw.len().max(last.end);
        }
        out
    } else {
        // Cut at the newline nearest each equal byte split. When n
        // exceeds the row count several targets resolve to the same
        // cut — the floor clamp makes them empty and the filter below
        // removes them.
        let mut cuts = vec![0usize];
        for i in 1..n {
            let target = raw.len() * i / n;
            let cut = raw[target..]
                .iter()
                .position(|&b| b == b'\n')
                .map(|p| target + p + 1)
                .unwrap_or(raw.len());
            let floor = cuts.last().copied().unwrap_or(0);
            cuts.push(cut.max(floor));
        }
        cuts.push(raw.len());
        (0..n).map(|i| cuts[i]..cuts[i + 1]).collect()
    };
    shards.retain(|s| !s.is_empty());
    shards
}

/// Rows a worker must observe (pass 1) and emit (pass 2) for `shard` —
/// the integrity check that turns a dropped frame into a typed error.
fn expected_rows(shard: &[u8], schema: Schema, binary: bool) -> u64 {
    if binary {
        (shard.len() / schema.binary_row_bytes()) as u64
    } else {
        let full = crate::data::utf8::count_rows(shard);
        let partial_tail = !shard.is_empty() && shard[shard.len() - 1] != b'\n';
        (full + usize::from(partial_tail)) as u64
    }
}

/// One leader↔worker session for one shard attempt.
struct ShardSession {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    addr: String,
}

/// Everything a shard dispatch thread needs — shared, read-only (the
/// counters and strike list are atomics).
struct Dispatch<'a> {
    addrs: &'a [String],
    job: &'a Job,
    raw: &'a [u8],
    chunk_size: usize,
    cfg: &'a NetConfig,
    clock: JobClock,
    /// Workers whose connect was refused — dead processes, skipped by
    /// the rotation.
    struck: &'a [AtomicBool],
    retries: &'a AtomicU64,
    faults: &'a AtomicU64,
}

impl Dispatch<'_> {
    /// The worker for `shard_idx`'s `attempt`-th try: rotate so a
    /// retried shard lands on a *different* worker first, skipping
    /// struck ones. `None` when no worker survives.
    fn pick_worker(&self, shard_idx: usize, attempt: u32) -> Option<usize> {
        let n = self.addrs.len();
        let start = (shard_idx + attempt as usize) % n;
        (0..n)
            .map(|k| (start + k) % n)
            .find(|&w| !self.struck[w].load(Ordering::Acquire))
    }

    /// Connect to worker `widx`; a refused/unreachable connect strikes
    /// it from the rotation.
    fn connect_worker(&self, widx: usize) -> Result<ShardSession> {
        let addr = &self.addrs[widx];
        let stream = super::connect(addr, self.cfg.io_timeout, &self.clock).inspect_err(|e| {
            if matches!(NetError::of(e), Some(NetError::PeerGone { .. })) {
                self.struck[widx].store(true, Ordering::Release);
            }
        })?;
        Ok(ShardSession {
            reader: BufReader::with_capacity(1 << 20, stream.try_clone()?),
            writer: BufWriter::with_capacity(1 << 20, stream),
            addr: addr.clone(),
        })
    }

    /// Back off (capped exponential, clipped to the job budget) before
    /// retry `attempt`, and count it.
    fn backoff(&self, attempt: u32) {
        self.retries.fetch_add(1, Ordering::AcqRel);
        self.clock.sleep(self.cfg.backoff_for(attempt));
    }

    /// When a send-side error is just the echo of the worker aborting,
    /// the worker's `ErrorReply` (already in flight) is the root cause —
    /// surface that instead.
    fn prefer_error_reply(&self, sess: &mut ShardSession, err: anyhow::Error) -> anyhow::Error {
        if matches!(NetError::of(&err), Some(NetError::PeerGone { .. })) {
            if let Ok((Tag::ErrorReply, payload)) = protocol::read_frame(&mut sess.reader) {
                return anyhow::Error::new(NetError::JobFailed {
                    worker: sess.addr.clone(),
                    reason: String::from_utf8_lossy(&payload).into_owned(),
                });
            }
        }
        err
    }

    /// One pass-1 attempt on an established session: job header, the
    /// shard's chunks, `VocabSync`, then the verified shard dump. On
    /// success the session is parked between the passes, ready for
    /// `VocabLoad`.
    fn pass1_attempt(
        &self,
        sess: &mut ShardSession,
        shard: &std::ops::Range<usize>,
        expected: u64,
    ) -> Result<Vec<Vec<u32>>> {
        let sent = (|| -> Result<()> {
            protocol::write_frame(&mut sess.writer, Tag::Job, &self.job.encode())?;
            for chunk in self.raw[shard.clone()].chunks(self.chunk_size.max(1)) {
                self.clock.check("sending pass 1")?;
                protocol::write_frame(&mut sess.writer, Tag::Pass1Chunk, chunk)?;
            }
            protocol::write_frame(&mut sess.writer, Tag::Pass1End, &[])?;
            protocol::write_frame(&mut sess.writer, Tag::VocabSync, &[])?;
            sess.writer.flush()?;
            Ok(())
        })();
        if let Err(e) = sent {
            return Err(self.prefer_error_reply(sess, e));
        }
        self.clock.check("awaiting shard dump")?;
        let (tag, payload) = protocol::read_frame(&mut sess.reader)?;
        match tag {
            Tag::VocabDump => {
                let (rows, cols) = protocol::unpack_shard_dump(&payload)?;
                anyhow::ensure!(
                    rows == expected,
                    NetError::Malformed {
                        what: format!(
                            "worker {} observed {rows} rows of a {expected}-row shard — \
                             pass-1 frames were lost",
                            sess.addr
                        ),
                    }
                );
                anyhow::ensure!(
                    cols.len() == self.job.schema.num_sparse,
                    NetError::Malformed {
                        what: format!(
                            "shard dump has {} vocab columns, schema wants {}",
                            cols.len(),
                            self.job.schema.num_sparse
                        ),
                    }
                );
                Ok(cols)
            }
            Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                worker: sess.addr.clone(),
                reason: String::from_utf8_lossy(&payload).into_owned(),
            }),
            other => anyhow::bail!(NetError::Malformed {
                what: format!("expected VocabDump, got {other:?}"),
            }),
        }
    }

    /// Pass 1 for one shard with split-level retry: each attempt gets a
    /// fresh session on the rotation's next surviving worker.
    fn pass1_shard(
        &self,
        shard_idx: usize,
        shard: &std::ops::Range<usize>,
        expected: u64,
    ) -> Result<(ShardSession, Vec<Vec<u32>>)> {
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            self.clock
                .check(&format!("dispatching shard {shard_idx} pass 1"))
                .map_err(|e| last_err.take().unwrap_or(e))?;
            let Some(widx) = self.pick_worker(shard_idx, attempt) else {
                let cause = last_err
                    .take()
                    .map(|e: anyhow::Error| format!(" (last error: {e:#})"))
                    .unwrap_or_default();
                anyhow::bail!(NetError::PeerGone {
                    what: format!("no surviving workers for shard {shard_idx}{cause}"),
                });
            };
            let attempt_result = self.connect_worker(widx).and_then(|mut sess| {
                let cols = self.pass1_attempt(&mut sess, shard, expected)?;
                Ok((sess, cols))
            });
            match attempt_result {
                Ok(out) => return Ok(out),
                Err(e) => {
                    self.faults.fetch_add(1, Ordering::AcqRel);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("no attempt ran"))
            .context(format!("shard {shard_idx}: pass-1 retries exhausted")))
    }

    /// One pass-2 attempt. `fresh` sessions (retries) open with an
    /// empty pass 1 — the merged vocabularies make re-observing
    /// unnecessary. A collector thread drains `ResultChunk`s while the
    /// shard streams out, so full socket buffers can't deadlock.
    fn pass2_attempt(
        &self,
        sess: &mut ShardSession,
        fresh: bool,
        packed_vocabs: &[u8],
        shard: &std::ops::Range<usize>,
        expected: u64,
    ) -> Result<(ProcessedColumns, RunStats)> {
        let schema = self.job.schema;
        let addr_str = sess.addr.clone();
        let ShardSession { reader, writer, addr } = &mut *sess;
        let (sent, collected) = std::thread::scope(|scope| {
            let clock = self.clock;
            let worker_addr = addr.clone();
            let collector =
                scope.spawn(move || -> Result<(ProcessedColumns, RunStats)> {
                    let mut cols = ProcessedColumns::with_schema(schema);
                    loop {
                        clock.check("collecting pass-2 results")?;
                        let (tag, payload) = protocol::read_frame(reader)?;
                        match tag {
                            Tag::ResultChunk => {
                                for row in protocol::unpack_rows(&payload, schema)? {
                                    cols.push_row(&row);
                                }
                            }
                            Tag::ResultEnd => {
                                return Ok((cols, RunStats::decode(&payload)?))
                            }
                            Tag::ErrorReply => anyhow::bail!(NetError::JobFailed {
                                worker: worker_addr,
                                reason: String::from_utf8_lossy(&payload).into_owned(),
                            }),
                            other => anyhow::bail!(NetError::Malformed {
                                what: format!("unexpected {other:?} in pass 2"),
                            }),
                        }
                    }
                });
            let sent = (|| -> Result<()> {
                if fresh {
                    protocol::write_frame(writer, Tag::Job, &self.job.encode())?;
                    protocol::write_frame(writer, Tag::Pass1End, &[])?;
                }
                protocol::write_frame(writer, Tag::VocabLoad, packed_vocabs)?;
                for chunk in self.raw[shard.clone()].chunks(self.chunk_size.max(1)) {
                    self.clock.check("sending pass 2")?;
                    protocol::write_frame(writer, Tag::Pass2Chunk, chunk)?;
                }
                protocol::write_frame(writer, Tag::Pass2End, &[])?;
                writer.flush()?;
                Ok(())
            })();
            let collected = collector
                .join()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("pass-2 collector panicked")));
            (sent, collected)
        });
        let (cols, stats) = match (sent, collected) {
            (_, Ok(out)) => out,
            // The collector usually holds the root cause (the worker's
            // ErrorReply); a send-side broken pipe is its echo.
            (Err(send_err), Err(collect_err)) => {
                return Err(
                    if matches!(NetError::of(&collect_err), Some(NetError::JobFailed { .. })) {
                        collect_err
                    } else {
                        send_err
                    },
                )
            }
            (Ok(()), Err(collect_err)) => return Err(collect_err),
        };
        // Every input row must be accounted for: emitted, skipped, or
        // quarantined. A shortfall means frames were lost in flight.
        let accounted = stats.rows + stats.rows_skipped + stats.rows_quarantined;
        anyhow::ensure!(
            accounted == expected && cols.num_rows() as u64 == stats.rows,
            NetError::Malformed {
                what: format!(
                    "worker {addr_str} returned {} rows (reported {} emitted + {} \
                     skipped + {} quarantined) of a {expected}-row shard — \
                     pass-2 frames were lost",
                    cols.num_rows(),
                    stats.rows,
                    stats.rows_skipped,
                    stats.rows_quarantined
                ),
            }
        );
        Ok((cols, stats))
    }

    /// Pass 2 for one shard with split-level retry. Attempt 0 reuses
    /// the shard's pass-1 session; every retry is a fresh session on
    /// the next surviving worker.
    fn pass2_shard(
        &self,
        shard_idx: usize,
        first_session: ShardSession,
        packed_vocabs: &[u8],
        shard: &std::ops::Range<usize>,
        expected: u64,
    ) -> Result<(ProcessedColumns, RunStats)> {
        let mut last_err = None;
        let mut first = Some(first_session);
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            self.clock
                .check(&format!("dispatching shard {shard_idx} pass 2"))
                .map_err(|e| last_err.take().unwrap_or(e))?;
            let session = match first.take() {
                Some(sess) => Ok((sess, false)),
                None => match self.pick_worker(shard_idx, attempt) {
                    Some(widx) => self.connect_worker(widx).map(|s| (s, true)),
                    None => {
                        let cause = last_err
                            .take()
                            .map(|e: anyhow::Error| format!(" (last error: {e:#})"))
                            .unwrap_or_default();
                        anyhow::bail!(NetError::PeerGone {
                            what: format!("no surviving workers for shard {shard_idx}{cause}"),
                        });
                    }
                },
            };
            let attempt_result = session.and_then(|(mut sess, fresh)| {
                self.pass2_attempt(&mut sess, fresh, packed_vocabs, shard, expected)
            });
            match attempt_result {
                Ok(cols) => return Ok(cols),
                Err(e) => {
                    self.faults.fetch_add(1, Ordering::AcqRel);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("no attempt ran"))
            .context(format!("shard {shard_idx}: pass-2 retries exhausted")))
    }
}

/// Run a sharded two-pass job against `addrs` workers with the default
/// [`NetConfig`] (30 s I/O deadline, 2 retries per shard).
pub fn run_cluster(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<ClusterRun> {
    run_cluster_cfg(addrs, job, raw, chunk_size, &NetConfig::default())
}

/// Run a sharded two-pass job against `addrs` workers.
///
/// The cluster path is inherently two-pass: the global vocabulary merge
/// is a barrier *between* the passes, so no worker may emit a row until
/// every worker has observed its whole shard — the fused single-pass
/// strategy cannot apply here, which is why the engine retains the
/// two-pass protocol at all. Shards dispatch in parallel (one thread
/// per shard) in both passes; failed shards are re-dispatched per the
/// module-level recovery rules, and the run fails — with a typed
/// [`NetError`], inside the job deadline — only when a shard exhausts
/// its retries or no worker survives.
pub fn run_cluster_cfg(
    addrs: &[String],
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    cfg: &NetConfig,
) -> Result<ClusterRun> {
    anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one worker");
    let start = Instant::now();
    let binary = matches!(job.format, super::stream::WireFormat::Binary);
    let shards = shard_rows(raw, job.schema, binary, addrs.len());
    let expected: Vec<u64> =
        shards.iter().map(|s| expected_rows(&raw[s.clone()], job.schema, binary)).collect();

    let struck: Vec<AtomicBool> = addrs.iter().map(|_| AtomicBool::new(false)).collect();
    let retries = AtomicU64::new(0);
    let faults = AtomicU64::new(0);
    let dispatch = Dispatch {
        addrs,
        job,
        raw,
        chunk_size,
        cfg,
        clock: cfg.clock(),
        struck: &struck,
        retries: &retries,
        faults: &faults,
    };

    // Pass 1: every shard in parallel; each thread owns its shard's
    // retry loop and parks its session between the passes.
    let pass1: Vec<Result<(ShardSession, Vec<Vec<u32>>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let dispatch = &dispatch;
                let expected = expected[i];
                scope.spawn(move || dispatch.pass1_shard(i, shard, expected))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("pass-1 shard thread panicked")))
            })
            .collect()
    });
    let mut sessions = Vec::with_capacity(pass1.len());
    let mut dumps = Vec::with_capacity(pass1.len());
    for r in pass1 {
        let (sess, cols) = r?;
        sessions.push(sess);
        dumps.push(cols);
    }

    // Gather sub-vocabularies, merge in shard order — deterministic no
    // matter which worker served which shard attempt.
    let mut merged: Vec<crate::ops::HashVocab> =
        (0..job.schema.num_sparse).map(|_| Default::default()).collect();
    for cols in dumps {
        use crate::ops::Vocab as _;
        for (dst, keys) in merged.iter_mut().zip(cols) {
            for k in keys {
                dst.observe(k);
            }
        }
    }
    let global: Vec<Vec<u32>> = merged
        .iter()
        .map(|v| v.iter_ordered().map(|(k, _)| k).collect())
        .collect();
    let vocab_entries: usize = global.iter().map(|c| c.len()).sum();

    // Broadcast merged vocabularies + pass 2, again one thread per
    // shard. The merged payload is serialized once — it can be many
    // megabytes for large per-column vocabularies.
    let packed = protocol::pack_vocabs(&global);
    let outputs: Vec<Result<(ProcessedColumns, RunStats)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .zip(sessions)
            .enumerate()
            .map(|(i, (shard, sess))| {
                let dispatch = &dispatch;
                let packed = &packed;
                let expected = expected[i];
                scope.spawn(move || dispatch.pass2_shard(i, sess, packed, shard, expected))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("pass-2 shard thread panicked")))
            })
            .collect()
    });

    // Concatenate shard outputs in order (the CFR step) and sum the
    // per-worker containment counters.
    let mut processed = ProcessedColumns::with_schema(job.schema);
    let (mut rows_skipped, mut rows_quarantined, mut illegal_bytes) = (0u64, 0u64, 0u64);
    for part in outputs {
        let (cols, stats) = part?;
        processed.extend_from(&cols);
        rows_skipped += stats.rows_skipped;
        rows_quarantined += stats.rows_quarantined;
        illegal_bytes += stats.illegal_bytes;
    }
    let rows = processed.num_rows() as u64;
    Ok(ClusterRun {
        processed,
        stats: RunStats {
            rows,
            vocab_entries: vocab_entries as u64,
            rows_skipped,
            rows_quarantined,
            illegal_bytes,
        },
        workers: addrs.len(),
        wallclock: start.elapsed(),
        retries: retries.load(Ordering::Acquire),
        faults: faults.load(Ordering::Acquire),
    })
}

/// Spawn `n` loopback workers and run a sharded job against them. The
/// workers run [`super::worker::serve_until`] accept loops — they
/// survive failed sessions and serve retries — and are shut down
/// (drained) when the run completes.
pub fn run_cluster_loopback(
    n: usize,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<ClusterRun> {
    run_cluster_loopback_cfg(n, job, raw, chunk_size, &NetConfig::default())
}

/// [`run_cluster_loopback`] with explicit fault-tolerance knobs.
pub fn run_cluster_loopback_cfg(
    n: usize,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    cfg: &NetConfig,
) -> Result<ClusterRun> {
    let mut addrs = Vec::new();
    let mut shutdowns = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n.max(1) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        let shutdown = super::worker::ShutdownHandle::new(&listener)?;
        shutdowns.push(shutdown.clone());
        handles.push(std::thread::spawn(move || {
            super::worker::serve_until(&listener, &shutdown, &super::worker::WorkerOptions::default())
        }));
    }
    let run = run_cluster_cfg(&addrs, job, raw, chunk_size, cfg);
    for s in &shutdowns {
        s.shutdown();
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::net::stream::WireFormat;
    use crate::ops::Modulus;

    fn reference(ds: &SynthDataset, m: Modulus) -> ProcessedColumns {
        let raw = utf8::encode_dataset(ds);
        crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        )
        .processed
    }

    #[test]
    fn cluster_sizes_agree_with_single_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(240));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let want = reference(&ds, m);
        for n in [1usize, 2, 4] {
            let run = run_cluster_loopback(n, &job, &raw, 777).unwrap();
            assert_eq!(run.workers, n);
            assert_eq!(run.processed, want, "{n} workers must equal sequential scan");
            assert_eq!((run.retries, run.faults), (0, 0), "clean run retries nothing");
        }
    }

    #[test]
    fn cluster_binary_format() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let m = Modulus::new(499);
        let raw = binary::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Binary);
        let run = run_cluster_loopback(3, &job, &raw, 512).unwrap();
        assert_eq!(run.stats.rows, 150);
        assert_eq!(run.processed, reference(&ds, m));
    }

    /// The cluster's vocabulary merge is per column, so per-column
    /// programs shard too: a heterogeneous job across workers equals
    /// the sequential reference.
    #[test]
    fn cluster_heterogeneous_spec_agrees_with_single_scan() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let spec = crate::ops::PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             sparse[5]: modulus:53; \
             dense[*]: neg2zero|log; \
             dense[1]: clip:0:50|bucketize:2:8:32",
        )
        .unwrap();
        let want = spec.execute(&ds.rows, ds.schema()).unwrap();
        let raw = utf8::encode_dataset(&ds);
        let job =
            Job { schema: ds.schema(), spec, format: WireFormat::Utf8, errors: Default::default() };
        for n in [1usize, 3] {
            let run = run_cluster_loopback(n, &job, &raw, 619).unwrap();
            assert_eq!(run.processed, want, "{n} workers");
        }
    }

    /// More workers than rows: the leader must not dispatch empty
    /// shards, and the output still equals the sequential scan.
    #[test]
    fn more_workers_than_rows_still_agrees() {
        let ds = SynthDataset::generate(SynthConfig::small(3));
        let m = Modulus::new(97);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let run = run_cluster_loopback(8, &job, &raw, 64).unwrap();
        assert_eq!(run.stats.rows, 3);
        assert_eq!(run.processed, reference(&ds, m));
    }

    #[test]
    fn shards_cover_and_respect_rows() {
        let ds = SynthDataset::generate(SynthConfig::small(101));
        let raw = utf8::encode_dataset(&ds);
        for n in [1usize, 2, 5, 8] {
            let shards = shard_rows(&raw, ds.schema(), false, n);
            assert!(!shards.is_empty() && shards.len() <= n);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, raw.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                // every shard ends on a row boundary
                if w[0].end > 0 {
                    assert_eq!(raw[w[0].end - 1], b'\n');
                }
            }
        }
    }

    /// Property test over row counts × shard counts × formats ×
    /// trailing-newline presence: shards are always contiguous,
    /// non-overlapping, non-empty, fully covering, row-aligned, and
    /// their expected-row counts sum to the input's row count.
    #[test]
    fn shard_rows_properties_hold_under_fuzz() {
        let mut g = crate::util::prng::XorShift64::new(0xC1A0_5EED);
        for case in 0..300 {
            let rows = (g.next_u64() % 40) as usize;
            let n = 1 + (g.next_u64() % 12) as usize;
            let binary_fmt = g.next_u64() % 2 == 0;
            let trailing_newline = g.next_u64() % 2 == 0;
            let ds = SynthDataset::generate(SynthConfig::small(rows.max(1)));
            let schema = ds.schema();
            let mut raw = if binary_fmt {
                binary::encode_dataset(&ds)
            } else {
                utf8::encode_dataset(&ds)
            };
            if rows == 0 {
                raw.clear();
            }
            if !binary_fmt && !trailing_newline && raw.last() == Some(&b'\n') {
                raw.pop(); // final row without its newline
            }
            let total_rows = if rows == 0 { 0 } else { ds.rows.len() } as u64;
            let shards = shard_rows(&raw, schema, binary_fmt, n);

            assert!(shards.len() <= n, "case {case}: {} shards for n={n}", shards.len());
            assert!(shards.iter().all(|s| !s.is_empty()), "case {case}: empty shard");
            if raw.is_empty() {
                assert!(shards.is_empty(), "case {case}");
                continue;
            }
            assert_eq!(shards[0].start, 0, "case {case}");
            assert_eq!(shards.last().unwrap().end, raw.len(), "case {case}: full coverage");
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start, "case {case}: contiguous, non-overlapping");
                if !binary_fmt {
                    assert_eq!(raw[w[0].end - 1], b'\n', "case {case}: row-aligned cut");
                }
            }
            let counted: u64 = shards
                .iter()
                .map(|s| expected_rows(&raw[s.clone()], schema, binary_fmt))
                .sum();
            assert_eq!(counted, total_rows, "case {case}: row counts partition the input");
        }
    }

    #[test]
    fn shard_exactly_at_raw_len_and_no_trailing_newline() {
        // A cut target landing past the last newline must clamp to
        // raw.len() exactly once, and the partial final row stays in
        // the last shard.
        let raw = b"1,2,3\n4,5,6\n7,8,9"; // no trailing newline
        let schema = crate::data::Schema::new(1, 1);
        for n in [2usize, 3, 5, 17] {
            let shards = shard_rows(raw, schema, false, n);
            assert_eq!(shards.last().unwrap().end, raw.len());
            assert!(shards.iter().all(|s| !s.is_empty()));
            let rows: u64 = shards.iter().map(|s| expected_rows(&raw[s.clone()], schema, false)).sum();
            assert_eq!(rows, 3, "n={n}");
        }
    }

    #[test]
    fn vocab_frame_roundtrip() {
        let cols = vec![vec![5u32, 1, 9], vec![], vec![42]];
        let packed = protocol::pack_vocabs(&cols);
        assert_eq!(protocol::unpack_vocabs(&packed).unwrap(), cols);
        assert!(protocol::unpack_vocabs(&packed[..packed.len() - 1]).is_err());
    }
}
