//! Deterministic fault injection at frame granularity — the chaos
//! harness behind `tests/chaos.rs`.
//!
//! A [`FaultPlan`] is a script of [`FaultRule`]s per lane (`rx` = frames
//! the wrapped endpoint *reads*, `tx` = frames it *writes*). Wrapping a
//! reader/writer pair with [`FaultPlan::wrap`] yields I/O objects that
//! speak plain `Read`/`Write` — the session code under test is the real
//! production code, byte for byte — but that drop, corrupt, truncate,
//! delay or sever whole protocol frames at scripted indices.
//!
//! Determinism is the point: a plan is data, [`FaultPlan::seeded`]
//! derives one from a PRNG seed, and replaying the same plan against the
//! same job must produce the same outcome (the chaos suite pins this).
//! Faults that kill the connection poison *both* lanes through a shared
//! flag, so a "crashed" worker neither reads nor writes again — like a
//! real process death, the peer observes reset/EOF, never a half-alive
//! socket.

use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::protocol::{FRAME_HEADER_BYTES, MAX_FRAME};
use crate::util::prng::XorShift64;

/// One fault class, applied to one whole frame as it crosses the wrap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Swallow the frame entirely: the session never sees it. Models a
    /// buggy peer that skips a send — detected downstream by row-count
    /// verification, never by the transport.
    DropFrame,
    /// XOR one byte of the frame (payload byte `offset % len`, or the
    /// tag byte for empty payloads). Detected by the frame checksum.
    Corrupt { offset: u64, xor: u8 },
    /// Forward only the first `keep` bytes of the frame, then sever the
    /// connection — a peer dying mid-send.
    Truncate { keep: u64 },
    /// Sleep before forwarding the frame — a wedged or overloaded peer.
    /// With a delay beyond the socket deadline this is the "hung worker"
    /// fault; below it, jitter the run must absorb.
    Delay { dur: Duration },
    /// Sever the connection at this frame boundary (crash).
    Close,
}

/// A fault applied at frame index `frame` (0-based, per lane).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRule {
    pub frame: u64,
    pub kind: FaultKind,
}

/// A deterministic per-connection fault script.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Faults on frames the wrapped endpoint reads.
    pub rx: Vec<FaultRule>,
    /// Faults on frames the wrapped endpoint writes.
    pub tx: Vec<FaultRule>,
}

impl FaultPlan {
    /// The empty plan: pass-through.
    pub fn clean() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_clean(&self) -> bool {
        self.rx.is_empty() && self.tx.is_empty()
    }

    /// Crash (sever both lanes) when the endpoint has *read* `n` frames.
    pub fn crash_after_rx(n: u64) -> FaultPlan {
        FaultPlan { rx: vec![FaultRule { frame: n, kind: FaultKind::Close }], tx: vec![] }
    }

    /// Crash when the endpoint is about to *write* its `n`-th frame.
    pub fn crash_after_tx(n: u64) -> FaultPlan {
        FaultPlan { tx: vec![FaultRule { frame: n, kind: FaultKind::Close }], rx: vec![] }
    }

    /// Add a rule on the read lane.
    pub fn with_rx(mut self, frame: u64, kind: FaultKind) -> FaultPlan {
        self.rx.push(FaultRule { frame, kind });
        self
    }

    /// Add a rule on the write lane.
    pub fn with_tx(mut self, frame: u64, kind: FaultKind) -> FaultPlan {
        self.tx.push(FaultRule { frame, kind });
        self
    }

    /// Derive a random plan from `seed`: one or two faults at early
    /// frame indices, mixing every class. Same seed → same plan → same
    /// run outcome; the chaos fuzz sweep iterates seeds.
    pub fn seeded(seed: u64) -> FaultPlan {
        let mut g = XorShift64::new(seed);
        let mut plan = FaultPlan::default();
        let nfaults = 1 + (g.next_u64() % 2);
        for _ in 0..nfaults {
            let frame = g.next_u64() % 8;
            let kind = match g.next_u64() % 5 {
                0 => FaultKind::DropFrame,
                1 => FaultKind::Corrupt { offset: g.next_u64(), xor: (g.next_u64() % 255) as u8 + 1 },
                2 => FaultKind::Truncate { keep: g.next_u64() % (FRAME_HEADER_BYTES as u64 + 4) },
                3 => FaultKind::Delay { dur: Duration::from_millis(g.next_u64() % 20) },
                _ => FaultKind::Close,
            };
            if g.next_u64() % 2 == 0 {
                plan.rx.push(FaultRule { frame, kind });
            } else {
                plan.tx.push(FaultRule { frame, kind });
            }
        }
        plan
    }

    /// Wrap a reader/writer pair. Returns the faulty pair plus a
    /// [`FaultHooks`] handle for asserting how many faults actually
    /// fired (a plan whose frame indices are never reached injects
    /// nothing).
    pub fn wrap<R: Read, W: Write>(
        &self,
        reader: R,
        writer: W,
    ) -> (FaultyReader<R>, FaultyWriter<W>, FaultHooks) {
        let hooks = FaultHooks {
            dead: Arc::new(AtomicBool::new(false)),
            injected: Arc::new(AtomicU64::new(0)),
        };
        let r = FaultyReader {
            inner: reader,
            rules: self.rx.clone(),
            frame: 0,
            out: Vec::new(),
            pos: 0,
            hooks: hooks.clone(),
        };
        let w = FaultyWriter {
            inner: writer,
            rules: self.tx.clone(),
            frame: 0,
            pending: Vec::new(),
            hooks: hooks.clone(),
        };
        (r, w, hooks)
    }
}

/// Shared observability for one wrapped connection.
#[derive(Debug, Clone)]
pub struct FaultHooks {
    dead: Arc<AtomicBool>,
    injected: Arc<AtomicU64>,
}

impl FaultHooks {
    /// Faults that actually fired on this connection.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Acquire)
    }

    /// Whether a Close/Truncate fault severed the connection.
    pub fn severed(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    fn fire(&self) {
        self.injected.fetch_add(1, Ordering::AcqRel);
    }

    fn sever(&self) -> std::io::Error {
        self.dead.store(true, Ordering::Release);
        std::io::Error::new(std::io::ErrorKind::ConnectionReset, "injected fault: connection severed")
    }

    fn dead_err(&self) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::ConnectionReset, "injected fault: connection severed")
    }
}

fn rule_for(rules: &[FaultRule], frame: u64) -> Option<FaultKind> {
    rules.iter().find(|r| r.frame == frame).map(|r| r.kind)
}

/// Frame-granular fault injection on the read side.
#[derive(Debug)]
pub struct FaultyReader<R> {
    inner: R,
    rules: Vec<FaultRule>,
    frame: u64,
    out: Vec<u8>,
    pos: usize,
    hooks: FaultHooks,
}

impl<R: Read> FaultyReader<R> {
    /// Pull the next frame from the inner reader and stage its bytes
    /// (after applying any fault). Returns false on clean EOF.
    fn fetch_frame(&mut self) -> std::io::Result<bool> {
        loop {
            let mut header = [0u8; FRAME_HEADER_BYTES];
            // Distinguish clean EOF (no header byte) from mid-frame EOF.
            match self.inner.read(&mut header[..1])? {
                0 => return Ok(false),
                _ => self.inner.read_exact(&mut header[1..])?,
            }
            let len = u64::from_le_bytes([
                header[1], header[2], header[3], header[4],
                header[5], header[6], header[7], header[8],
            ]);
            if len > MAX_FRAME {
                // Hand the hostile header through untouched — the frame
                // cap in read_frame owns this case.
                self.out = header.to_vec();
                self.pos = 0;
                return Ok(true);
            }
            let mut frame = vec![0u8; FRAME_HEADER_BYTES + len as usize];
            frame[..FRAME_HEADER_BYTES].copy_from_slice(&header);
            self.inner.read_exact(&mut frame[FRAME_HEADER_BYTES..])?;
            let rule = rule_for(&self.rules, self.frame);
            self.frame += 1;
            match rule {
                None => {}
                Some(FaultKind::Delay { dur }) => {
                    self.hooks.fire();
                    std::thread::sleep(dur);
                }
                Some(FaultKind::DropFrame) => {
                    self.hooks.fire();
                    continue; // swallow, fetch the next frame
                }
                Some(FaultKind::Corrupt { offset, xor }) => {
                    self.hooks.fire();
                    let at = if len == 0 { 0 } else { FRAME_HEADER_BYTES + (offset % len) as usize };
                    frame[at] ^= xor.max(1);
                }
                Some(FaultKind::Truncate { keep }) => {
                    self.hooks.fire();
                    frame.truncate((keep as usize).min(frame.len()));
                    self.hooks.sever();
                }
                Some(FaultKind::Close) => {
                    self.hooks.fire();
                    return Err(self.hooks.sever());
                }
            }
            self.out = frame;
            self.pos = 0;
            return Ok(true);
        }
    }
}

impl<R: Read> Read for FaultyReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos == self.out.len() {
            if self.hooks.severed() {
                return Err(self.hooks.dead_err());
            }
            if !self.fetch_frame()? {
                return Ok(0);
            }
            if self.out.is_empty() {
                // Truncate-to-zero: sever without delivering anything.
                return Err(self.hooks.dead_err());
            }
        }
        let n = buf.len().min(self.out.len() - self.pos);
        buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Frame-granular fault injection on the write side. Bytes buffer until
/// a whole frame is assembled, then the frame is forwarded (or dropped,
/// corrupted, truncated, delayed) in one piece.
#[derive(Debug)]
pub struct FaultyWriter<W> {
    inner: W,
    rules: Vec<FaultRule>,
    frame: u64,
    pending: Vec<u8>,
    hooks: FaultHooks,
}

impl<W: Write> FaultyWriter<W> {
    fn pump(&mut self) -> std::io::Result<()> {
        while self.pending.len() >= FRAME_HEADER_BYTES {
            let len = u64::from_le_bytes([
                self.pending[1], self.pending[2], self.pending[3], self.pending[4],
                self.pending[5], self.pending[6], self.pending[7], self.pending[8],
            ]);
            if len > MAX_FRAME {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("fault wrapper saw a {len}-byte frame; refusing to buffer it"),
                ));
            }
            let total = FRAME_HEADER_BYTES + len as usize;
            if self.pending.len() < total {
                return Ok(()); // rest of the frame is still being written
            }
            let rest = self.pending.split_off(total);
            let mut frame = std::mem::replace(&mut self.pending, rest);
            let rule = rule_for(&self.rules, self.frame);
            self.frame += 1;
            match rule {
                None => self.inner.write_all(&frame)?,
                Some(FaultKind::Delay { dur }) => {
                    self.hooks.fire();
                    std::thread::sleep(dur);
                    self.inner.write_all(&frame)?;
                }
                Some(FaultKind::DropFrame) => self.hooks.fire(),
                Some(FaultKind::Corrupt { offset, xor }) => {
                    self.hooks.fire();
                    let at = if len == 0 { 0 } else { FRAME_HEADER_BYTES + (offset % len) as usize };
                    frame[at] ^= xor.max(1);
                    self.inner.write_all(&frame)?;
                }
                Some(FaultKind::Truncate { keep }) => {
                    self.hooks.fire();
                    frame.truncate((keep as usize).min(frame.len()));
                    self.inner.write_all(&frame)?;
                    let _ = self.inner.flush();
                    return Err(self.hooks.sever());
                }
                Some(FaultKind::Close) => {
                    self.hooks.fire();
                    let _ = self.inner.flush();
                    return Err(self.hooks.sever());
                }
            }
        }
        Ok(())
    }
}

impl<W: Write> Write for FaultyWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.hooks.severed() {
            return Err(self.hooks.dead_err());
        }
        self.pending.extend_from_slice(buf);
        self.pump()?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.hooks.severed() {
            return Err(self.hooks.dead_err());
        }
        self.pump()?;
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::protocol::{read_frame, write_frame, NetError, Tag};

    fn roundtrip_with(plan: &FaultPlan, frames: &[(Tag, &[u8])]) -> (Vec<crate::Result<(Tag, Vec<u8>)>>, FaultHooks) {
        // Write through a faulty writer into a buffer, then read the
        // buffer back through a faulty *clean* reader (tx-lane tests),
        // or vice versa.
        let mut wire = Vec::new();
        let hooks = {
            let (_r, mut w, hooks) = plan.wrap(std::io::empty(), &mut wire);
            for (tag, payload) in frames {
                if write_frame(&mut w, *tag, payload).is_err() {
                    break;
                }
            }
            use std::io::Write as _;
            let _ = w.flush();
            hooks
        };
        let mut out = Vec::new();
        let mut r = &wire[..];
        for _ in 0..frames.len() {
            out.push(read_frame(&mut r));
        }
        (out, hooks)
    }

    #[test]
    fn clean_plan_is_transparent() {
        let frames: &[(Tag, &[u8])] = &[(Tag::Job, b"abc"), (Tag::Pass1Chunk, b""), (Tag::Pass1End, b"xyz")];
        let (got, hooks) = roundtrip_with(&FaultPlan::clean(), frames);
        for ((tag, payload), res) in frames.iter().zip(got) {
            let (t, p) = res.unwrap();
            assert_eq!((t, p.as_slice()), (*tag, *payload));
        }
        assert_eq!(hooks.injected(), 0);
        assert!(!hooks.severed());
    }

    #[test]
    fn drop_frame_swallows_exactly_one() {
        let frames: &[(Tag, &[u8])] = &[(Tag::Job, b"a"), (Tag::Pass1Chunk, b"b"), (Tag::Pass1End, b"c")];
        let plan = FaultPlan::clean().with_tx(1, FaultKind::DropFrame);
        let (got, hooks) = roundtrip_with(&plan, frames);
        assert_eq!(hooks.injected(), 1);
        let (t0, p0) = got[0].as_ref().unwrap().clone();
        assert_eq!((t0, p0.as_slice()), (Tag::Job, &b"a"[..]));
        let (t1, p1) = got[1].as_ref().unwrap().clone();
        assert_eq!((t1, p1.as_slice()), (Tag::Pass1End, &b"c"[..]), "middle frame dropped");
        assert!(got[2].is_err(), "wire exhausted");
    }

    #[test]
    fn corrupt_is_caught_by_checksum() {
        let plan = FaultPlan::clean().with_tx(0, FaultKind::Corrupt { offset: 2, xor: 0x80 });
        let (got, hooks) = roundtrip_with(&plan, &[(Tag::Job, b"payload")]);
        assert_eq!(hooks.injected(), 1);
        let err = got[0].as_ref().unwrap_err();
        assert!(matches!(NetError::of(err), Some(NetError::Malformed { .. })), "{err:#}");
    }

    #[test]
    fn truncate_and_close_sever_the_lane() {
        for kind in [FaultKind::Truncate { keep: 5 }, FaultKind::Close] {
            let plan = FaultPlan::clean().with_tx(0, kind);
            let (got, hooks) = roundtrip_with(&plan, &[(Tag::Job, b"payload"), (Tag::Pass1End, b"")]);
            assert!(hooks.severed());
            assert!(got[0].as_ref().is_err(), "{kind:?}");
        }
    }

    #[test]
    fn close_on_rx_poisons_reads() {
        let mut wire = Vec::new();
        write_frame(&mut wire, Tag::Job, b"abc").unwrap();
        write_frame(&mut wire, Tag::Pass1End, b"").unwrap();
        let plan = FaultPlan::crash_after_rx(1);
        let (mut r, _w, hooks) = plan.wrap(&wire[..], std::io::sink());
        let (t, p) = read_frame(&mut r).unwrap();
        assert_eq!((t, p.as_slice()), (Tag::Job, &b"abc"[..]));
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::PeerGone { .. })), "{err:#}");
        assert!(hooks.severed());
        assert!(read_frame(&mut r).is_err(), "stays dead");
    }

    #[test]
    fn seeded_plans_are_deterministic_and_varied() {
        let mut shapes = std::collections::HashSet::new();
        for seed in 0..64 {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            assert_eq!(a, b, "seed {seed} must be reproducible");
            assert!(!a.is_clean());
            shapes.insert(format!("{a:?}"));
        }
        assert!(shapes.len() > 32, "seeds should explore distinct plans, got {}", shapes.len());
    }
}
