//! Network-attached PIPER over real TCP (paper Fig. 7d).
//!
//! The paper attaches the FPGA directly to the network through a hardware
//! TCP/IP stack; datasets stream in, preprocessed rows stream out, and
//! nothing is ever staged in a host buffer. We reproduce the *structure*
//! with a real TCP implementation on loopback:
//!
//! * [`stream`] — the streaming preprocessor, speaking both execution
//!   strategies: fused (single-node default — observe and emit per
//!   chunk, the dataset arrives **once**) and two-pass (pass 1 builds
//!   the vocabularies, pass 2 re-streams and emits — retained because
//!   the cluster's global vocabulary merge is a barrier between the
//!   passes). Only the vocabularies are resident — the worker never
//!   holds the dataset ("the FPGA can process larger-than-memory
//!   datasets in a streaming fashion", §3.4.2).
//! * [`protocol`] — length-prefixed frames for jobs, data passes and
//!   results; the first data frame picks the strategy.
//! * [`worker`] — the accelerator node: accepts a job, runs either
//!   protocol, streams results back.
//! * [`leader`] — the client: sends the dataset (once or twice per the
//!   strategy), collects results.
//! * [`serve`] — online serving: small request/response batches against
//!   a frozen vocabulary artifact, with admission control and latency
//!   percentiles ([`serve::ServeReport`]).
//!
//! Functional times on loopback are measured; the 100 Gbps figure comes
//! from [`crate::accel::network`]'s line-rate model (tagged `sim`).

pub mod cluster;
pub mod leader;
pub mod protocol;
pub mod serve;
pub mod stream;
pub mod worker;

pub use cluster::{run_cluster, run_cluster_loopback};
pub use leader::{run_leader, run_leader_source};
pub use serve::{ServeClient, ServeJob, ServeReport, ServeResponse, ServeStatus};
pub use stream::StreamingPreprocessor;
pub use worker::{serve_forever, serve_one};
