//! Network-attached PIPER over real TCP (paper Fig. 7d).
//!
//! The paper attaches the FPGA directly to the network through a hardware
//! TCP/IP stack; datasets stream in, preprocessed rows stream out, and
//! nothing is ever staged in a host buffer. We reproduce the *structure*
//! with a real TCP implementation on loopback:
//!
//! * [`stream`] — the streaming two-pass preprocessor: pass 1 builds the
//!   vocabularies chunk by chunk, pass 2 re-streams the dataset and emits
//!   preprocessed rows immediately. Only the vocabularies are resident —
//!   the worker never holds the dataset ("the FPGA can process
//!   larger-than-memory datasets in a streaming fashion", §3.4.2).
//! * [`protocol`] — length-prefixed frames for jobs, data passes and
//!   results.
//! * [`worker`] — the accelerator node: accepts a job, runs the two
//!   passes, streams results back.
//! * [`leader`] — the client: sends the dataset twice, collects results.
//!
//! Functional times on loopback are measured; the 100 Gbps figure comes
//! from [`crate::accel::network`]'s line-rate model (tagged `sim`).

pub mod cluster;
pub mod leader;
pub mod protocol;
pub mod stream;
pub mod worker;

pub use cluster::{run_cluster, run_cluster_loopback};
pub use leader::{run_leader, run_leader_source};
pub use stream::StreamingPreprocessor;
pub use worker::serve_one;
