//! Network-attached PIPER over real TCP (paper Fig. 7d).
//!
//! The paper attaches the FPGA directly to the network through a hardware
//! TCP/IP stack; datasets stream in, preprocessed rows stream out, and
//! nothing is ever staged in a host buffer. We reproduce the *structure*
//! with a real TCP implementation on loopback:
//!
//! * [`stream`] — the streaming preprocessor, speaking both execution
//!   strategies: fused (the default — observe and emit per chunk, the
//!   dataset arrives **once**) and two-pass (pass 1 builds the
//!   vocabularies, pass 2 re-streams and emits — retained as the
//!   classic two-loop baseline). Only the vocabularies are resident —
//!   the worker never holds the dataset ("the FPGA can process
//!   larger-than-memory datasets in a streaming fashion", §3.4.2).
//! * [`protocol`] — length-prefixed frames for jobs, data passes and
//!   results; the first data frame picks the strategy.
//! * [`worker`] — the accelerator node: accepts a job, runs either
//!   protocol, streams results back. Also speaks the
//!   [`crate::service`] dispatch and key sessions, so one worker pool
//!   serves single-node submits and service jobs alike.
//! * [`leader`] — the client: sends the dataset (once or twice per the
//!   strategy), collects results.
//! * [`serve`] — online serving: small request/response batches against
//!   a frozen vocabulary artifact, with admission control and latency
//!   percentiles ([`serve::ServeReport`]).
//! * [`fault`] — the deterministic fault-injection harness: a seedable
//!   [`FaultPlan`] (drop/close/truncate/delay/corrupt at frame
//!   granularity) wrapped behind any reader/writer pair, driving the
//!   chaos suite that proves the retry/deadline machinery.
//!
//! Fault model: every socket carries read/write deadlines
//! ([`NetConfig`]), every job a wall-clock budget ([`JobClock`]), and
//! every failure a typed class ([`NetError`]). The service scheduler
//! re-dispatches failed splits to surviving workers with capped
//! exponential backoff; per-split row counts and frame checksums turn
//! silent corruption into typed, retryable errors.
//!
//! Functional times on loopback are measured; the 100 Gbps figure comes
//! from [`crate::accel::network`]'s line-rate model (tagged `sim`).

pub mod cluster;
pub mod fault;
pub mod leader;
pub mod protocol;
pub mod serve;
pub mod stream;
pub mod worker;

pub use cluster::{run_cluster, run_cluster_cfg, run_cluster_loopback};
pub use fault::{FaultKind, FaultPlan};
pub use leader::{run_leader, run_leader_source, run_leader_source_cfg};
pub use protocol::{NetError, RunStats};
pub use serve::{ServeClient, ServeJob, ServeReport, ServeResponse, ServeStatus};
pub use stream::StreamingPreprocessor;
pub use worker::{serve_forever, serve_one, serve_until, ShutdownHandle, WorkerOptions};

use crate::Result;
use std::time::{Duration, Instant};

/// Fault-tolerance knobs shared by every leader-side net path:
/// per-socket I/O deadlines, a whole-job wall-clock budget, and the
/// capped-exponential-backoff retry policy the cluster's split-level
/// re-dispatch and the serve client's overload handling follow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetConfig {
    /// Read/write timeout applied to every leader↔worker and serve
    /// socket. A blocked read or write past this surfaces as
    /// [`NetError::Timeout`]. `None` = block forever (opt-in only).
    pub io_timeout: Option<Duration>,
    /// Wall-clock budget for one whole job (all passes, all retries).
    /// Checked between frames and before every retry/backoff sleep, so
    /// a run errors out no later than roughly `job_deadline +
    /// io_timeout`. `None` = unbounded.
    pub job_deadline: Option<Duration>,
    /// Re-dispatch attempts per shard (or serve request) *beyond* the
    /// first try. 0 = fail on the first error.
    pub retries: u32,
    /// Base backoff before a retry; doubles per attempt.
    pub backoff: Duration,
    /// Cap on the doubled backoff.
    pub backoff_cap: Duration,
    /// Leader-side source read-ahead window (chunks), the `submit`
    /// analogue of the engine's `pipeline_depth`: at depth >= 2 a
    /// producer thread prefetches up to this many chunks ahead of the
    /// socket, overlapping disk reads with the network send. The wire
    /// protocol is unchanged — the worker still consumes strictly
    /// chunk-at-a-time; only the leader's I/O overlaps. 1 (the default)
    /// = the sequential read-then-send loop. Values below 1 are
    /// treated as 1.
    pub leader_window: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            io_timeout: Some(Duration::from_secs(30)),
            job_deadline: None,
            retries: 2,
            backoff: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            leader_window: 1,
        }
    }
}

impl NetConfig {
    /// Start this job's deadline clock.
    pub fn clock(&self) -> JobClock {
        JobClock { start: Instant::now(), budget: self.job_deadline }
    }

    /// Backoff before retry attempt `attempt` (1-based): capped
    /// exponential, `backoff * 2^(attempt-1)`.
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let exp = self.backoff.saturating_mul(1u32 << attempt.saturating_sub(1).min(16));
        exp.min(self.backoff_cap)
    }
}

/// The per-job wall-clock budget, threaded through every blocking step
/// of a run so no socket wait or backoff sleep can outlive the job.
#[derive(Debug, Clone, Copy)]
pub struct JobClock {
    start: Instant,
    budget: Option<Duration>,
}

impl JobClock {
    /// A clock with no budget (never expires).
    pub fn unbounded() -> JobClock {
        JobClock { start: Instant::now(), budget: None }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Remaining budget; `None` = unbounded, `Some(0)` = expired.
    pub fn remaining(&self) -> Option<Duration> {
        self.budget.map(|b| b.saturating_sub(self.start.elapsed()))
    }

    /// Error with [`NetError::Timeout`] once the budget is spent.
    pub fn check(&self, what: &str) -> Result<()> {
        if self.remaining() == Some(Duration::ZERO) {
            anyhow::bail!(NetError::Timeout {
                what: format!("job deadline exceeded during {what}"),
            });
        }
        Ok(())
    }

    /// The socket timeout to arm right now: the smaller of the
    /// configured I/O timeout and what's left of the job budget (a
    /// socket is never allowed to block past the job's deadline).
    pub fn io_timeout(&self, io: Option<Duration>) -> Option<Duration> {
        match (io, self.remaining()) {
            (Some(io), Some(rem)) => Some(io.min(rem)),
            (Some(io), None) => Some(io),
            (None, rem) => rem,
        }
        // set_read_timeout(Some(ZERO)) is an error; round up to 1ms so
        // an expired budget still arms a (immediately-firing) timeout.
        .map(|d| d.max(Duration::from_millis(1)))
    }

    /// Sleep `d`, clipped so the sleep cannot outlive the budget.
    pub fn sleep(&self, d: Duration) {
        let d = match self.remaining() {
            Some(rem) => d.min(rem),
            None => d,
        };
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

/// Connect with the clock's deadline and arm both socket timeouts —
/// the one entry point every leader-side connection goes through.
/// Refused/unreachable classifies as [`NetError::PeerGone`], an expired
/// connect as [`NetError::Timeout`].
pub(crate) fn connect(addr: &str, io: Option<Duration>, clock: &JobClock) -> Result<std::net::TcpStream> {
    use std::net::{TcpStream, ToSocketAddrs};
    clock.check(&format!("connect to {addr}"))?;
    let timeout = clock.io_timeout(io);
    let stream = match timeout {
        Some(t) => {
            let sock = addr
                .to_socket_addrs()
                .map_err(|e| NetError::from_io(&format!("resolving {addr}"), e))?
                .next()
                .ok_or_else(|| NetError::Malformed { what: format!("{addr} resolves to nothing") })?;
            TcpStream::connect_timeout(&sock, t)
        }
        None => TcpStream::connect(addr),
    }
    .map_err(|e| NetError::from_io(&format!("connecting to {addr}"), e))?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    Ok(stream)
}
