//! Wire protocol: length-prefixed, checksummed tagged frames over TCP.
//!
//! ```text
//! frame := tag:u8 len:u64le sum:u32le payload[len]
//! ```
//!
//! `sum` is a word-folded checksum of the tag and payload
//! ([`frame_sum`]): a frame corrupted in flight (or by a buggy peer)
//! surfaces as a typed [`NetError::Malformed`] at [`read_frame`] instead
//! of silently poisoning vocabularies or result rows downstream — the
//! property the chaos suite's corrupt-frame faults pin.
//!
//! Leader → worker, two-pass protocol: `Job`, `Pass1Chunk`*, `Pass1End`,
//! `Pass2Chunk`*, `Pass2End`. Fused single-pass protocol: `Job`,
//! `FusedChunk`*, `FusedEnd` — the dataset crosses the wire **once**,
//! appearance indices are assigned on the fly and results stream back
//! while the input is still arriving. Worker → leader: `ResultChunk`*
//! (packed processed rows), `ResultEnd` (stats). The strategy is not in
//! the job header — the first data frame picks the protocol, so old
//! leaders keep working and the cluster leader-merge path simply keeps
//! sending pass frames.
//!
//! I/O errors are classified into the [`NetError`] taxonomy at this
//! layer, so every caller up the stack (leader, cluster retry loop,
//! serve client) can distinguish retryable failures (timeout, peer
//! gone, overload) from fatal ones without string matching.

use crate::data::row::{ProcessedColumns, ProcessedRow};
use crate::data::Schema;
use crate::decode::{ErrorBudget, ErrorConfig, ErrorPolicy};
use crate::ops::{Modulus, PipelineSpec};
use crate::Result;
use std::io::{Read, Write};

use super::stream::WireFormat;

// ---------------------------------------------------------------------
// Typed error taxonomy
// ---------------------------------------------------------------------

/// Typed network/cluster failure taxonomy. Every failure on the net
/// paths is classified into one of these variants (carried inside
/// `anyhow::Error`; recover it with [`NetError::of`]), replacing the
/// old ad-hoc `bail!` strings so callers can tell retryable conditions
/// from fatal ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetError {
    /// An I/O deadline expired: a socket read/write timed out, or the
    /// per-job wall-clock budget ran out.
    Timeout { what: String },
    /// The peer vanished: connection refused/reset/aborted, broken
    /// pipe, or an unexpected EOF mid-frame.
    PeerGone { what: String },
    /// The bytes on the wire are wrong: unknown tag, frame over the
    /// size cap, checksum mismatch, or a payload that fails to decode.
    Malformed { what: String },
    /// The serving worker's admission control refused the request;
    /// retry with backoff.
    Overloaded,
    /// The worker executed the session and reported an application
    /// error (its `ErrorReply` message is in `reason`).
    JobFailed { worker: String, reason: String },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Timeout { what } => write!(f, "timeout: {what}"),
            NetError::PeerGone { what } => write!(f, "peer gone: {what}"),
            NetError::Malformed { what } => write!(f, "malformed: {what}"),
            NetError::Overloaded => write!(f, "overloaded: admission control refused the request"),
            NetError::JobFailed { worker, reason } => {
                write!(f, "job failed on worker {worker}: {reason}")
            }
        }
    }
}

impl std::error::Error for NetError {}

impl NetError {
    /// Recover the typed error from an `anyhow::Error` chain (context
    /// layers added with `.context(...)` are looked through).
    pub fn of(err: &anyhow::Error) -> Option<&NetError> {
        err.downcast_ref::<NetError>()
    }

    /// Whether the *same* operation against the *same* peer is worth
    /// retrying. Note the cluster re-dispatches a failed shard to a
    /// *different* worker, which can also cure `Malformed`/`JobFailed`
    /// caused by one sick node — its retry loop is deliberately broader
    /// than this predicate.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            NetError::Timeout { .. } | NetError::PeerGone { .. } | NetError::Overloaded
        )
    }

    /// Classify an I/O error from a socket operation.
    pub fn from_io(what: &str, e: std::io::Error) -> anyhow::Error {
        use std::io::ErrorKind as K;
        let err = match e.kind() {
            K::TimedOut | K::WouldBlock => NetError::Timeout { what: format!("{what}: {e}") },
            K::UnexpectedEof
            | K::ConnectionReset
            | K::ConnectionAborted
            | K::ConnectionRefused
            | K::BrokenPipe
            | K::NotConnected => NetError::PeerGone { what: format!("{what}: {e}") },
            _ => return anyhow::Error::new(e).context(what.to_string()),
        };
        anyhow::Error::new(err)
    }
}

/// Frame tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    Job = 1,
    Pass1Chunk = 2,
    Pass1End = 3,
    Pass2Chunk = 4,
    Pass2End = 5,
    ResultChunk = 6,
    ResultEnd = 7,
    /// Leader → worker (cluster mode, after Pass1End): request the
    /// worker's sub-vocabularies for the global merge.
    VocabSync = 8,
    /// Worker → leader: sub-vocabulary keys in appearance order.
    VocabDump = 9,
    /// Leader → worker: the merged global vocabularies to apply in pass 2.
    VocabLoad = 10,
    /// Leader → worker (fused single-pass protocol): a raw chunk to
    /// observe *and* process in one scan.
    FusedChunk = 11,
    /// Leader → worker: end of the fused stream.
    FusedEnd = 12,
    /// Client → worker, first frame of the serving protocol: a frozen
    /// artifact plus miss policy and admission settings
    /// ([`crate::net::serve::ServeJob`]).
    ServeJob = 13,
    /// Client → worker: one small-batch request
    /// (`req_id:u64` + raw rows in the session's wire format).
    ServeRequest = 14,
    /// Worker → client: the response to one request
    /// ([`crate::net::serve::ServeResponse`]).
    ServeResponse = 15,
    /// Client → worker: end of the serving session.
    ServeEnd = 16,
    /// Worker → client, final frame of a serving session: aggregate
    /// latency/miss statistics ([`crate::net::serve::ServeReport`]).
    ServeReport = 17,
    /// Worker → peer: a fatal protocol/session error, carried as a
    /// UTF-8 message just before the worker closes the connection — so
    /// a malformed stream diagnoses itself instead of surfacing as a
    /// bare hangup on the other side.
    ErrorReply = 18,
}

impl Tag {
    pub fn from_u8(v: u8) -> Result<Tag> {
        Ok(match v {
            1 => Tag::Job,
            2 => Tag::Pass1Chunk,
            3 => Tag::Pass1End,
            4 => Tag::Pass2Chunk,
            5 => Tag::Pass2End,
            6 => Tag::ResultChunk,
            7 => Tag::ResultEnd,
            8 => Tag::VocabSync,
            9 => Tag::VocabDump,
            10 => Tag::VocabLoad,
            11 => Tag::FusedChunk,
            12 => Tag::FusedEnd,
            13 => Tag::ServeJob,
            14 => Tag::ServeRequest,
            15 => Tag::ServeResponse,
            16 => Tag::ServeEnd,
            17 => Tag::ServeReport,
            18 => Tag::ErrorReply,
            other => anyhow::bail!("unknown frame tag {other}"),
        })
    }
}

/// Encode per-column vocabulary keys: `ncols:u32 (len:u32 keys:u32*)*`.
pub fn pack_vocabs(cols: &[Vec<u32>]) -> Vec<u8> {
    let total: usize = cols.iter().map(|c| c.len()).sum();
    let mut out = Vec::with_capacity(4 + cols.len() * 4 + total * 4);
    out.extend_from_slice(&(cols.len() as u32).to_le_bytes());
    for col in cols {
        out.extend_from_slice(&(col.len() as u32).to_le_bytes());
        for &k in col {
            out.extend_from_slice(&k.to_le_bytes());
        }
    }
    out
}

/// Decode [`pack_vocabs`] output.
pub fn unpack_vocabs(buf: &[u8]) -> Result<Vec<Vec<u32>>> {
    let rd_u32 = |at: usize| -> Result<u32> {
        let s = buf
            .get(at..at + 4)
            .ok_or_else(|| anyhow::anyhow!("vocab frame truncated at {at}"))?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    };
    let ncols = rd_u32(0)? as usize;
    anyhow::ensure!(ncols <= 4096, "unreasonable column count {ncols}");
    let mut cols = Vec::with_capacity(ncols);
    let mut at = 4;
    for _ in 0..ncols {
        let len = rd_u32(at)? as usize;
        at += 4;
        // Bound the reservation by the bytes actually present: a
        // malicious length field must produce a truncation error, not a
        // multi-gigabyte allocation.
        anyhow::ensure!(
            buf.len().saturating_sub(at) / 4 >= len,
            "vocab frame truncated: column claims {len} keys"
        );
        let mut col = Vec::with_capacity(len);
        for _ in 0..len {
            col.push(rd_u32(at)?);
            at += 4;
        }
        cols.push(col);
    }
    anyhow::ensure!(at == buf.len(), "trailing bytes in vocab frame");
    Ok(cols)
}

/// Bytes before the payload: `tag:u8 len:u64le sum:u32le`.
pub const FRAME_HEADER_BYTES: usize = 1 + 8 + 4;

/// Hard cap on a single frame's payload, enforced on read.
pub const MAX_FRAME: u64 = 1 << 30;

/// Word-folded checksum over tag + payload (xorshift-style mix per
/// 8-byte word — one multiply per 8 bytes, not per byte, so checking
/// never rivals the decode itself). Not cryptographic; it exists to
/// turn in-flight corruption into a typed [`NetError::Malformed`].
pub fn frame_sum(tag: u8, payload: &[u8]) -> u32 {
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15 ^ ((payload.len() as u64) << 8) ^ tag as u64;
    let mut chunks = payload.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]);
        h = (h ^ w).wrapping_mul(0x2545_F491_4F6C_DD1D).rotate_left(23);
    }
    let rest = chunks.remainder();
    if !rest.is_empty() {
        let mut w = [0u8; 8];
        w[..rest.len()].copy_from_slice(rest);
        h = (h ^ u64::from_le_bytes(w)).wrapping_mul(0x2545_F491_4F6C_DD1D).rotate_left(23);
    }
    (h ^ (h >> 32)) as u32
}

/// Write one frame. I/O errors are classified into [`NetError`].
pub fn write_frame<W: Write>(w: &mut W, tag: Tag, payload: &[u8]) -> Result<()> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    header[0] = tag as u8;
    header[1..9].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    header[9..13].copy_from_slice(&frame_sum(tag as u8, payload).to_le_bytes());
    w.write_all(&header)
        .and_then(|()| w.write_all(payload))
        .map_err(|e| NetError::from_io("writing frame", e))?;
    Ok(())
}

/// Read one frame. Payload size is capped to keep a corrupt peer from
/// forcing a huge allocation; the checksum is verified before the
/// payload is handed to any decoder. Timeouts, hangups and corruption
/// all surface as typed [`NetError`]s.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(Tag, Vec<u8>)> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut header)
        .map_err(|e| NetError::from_io("reading frame header", e))?;
    let len = u64::from_le_bytes([
        header[1], header[2], header[3], header[4],
        header[5], header[6], header[7], header[8],
    ]);
    if len > MAX_FRAME {
        anyhow::bail!(NetError::Malformed {
            what: format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        });
    }
    let sum = u32::from_le_bytes([header[9], header[10], header[11], header[12]]);
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| NetError::from_io("reading frame payload", e))?;
    if frame_sum(header[0], &payload) != sum {
        anyhow::bail!(NetError::Malformed {
            what: format!("frame checksum mismatch (tag {}, {len} bytes)", header[0]),
        });
    }
    let tag = Tag::from_u8(header[0]).map_err(|e| {
        anyhow::Error::new(NetError::Malformed { what: e.to_string() })
    })?;
    Ok((tag, payload))
}

/// Pack a cluster worker's pass-1 shard dump: the rows it observed plus
/// its sub-vocabularies (`rows:u64 || pack_vocabs`). The row count lets
/// the leader verify the shard was observed *in full* — a dropped or
/// swallowed pass-1 frame shows up as a count mismatch and triggers a
/// re-dispatch instead of silently skewing the global merge.
pub fn pack_shard_dump(rows: u64, cols: &[Vec<u32>]) -> Vec<u8> {
    let mut out = rows.to_le_bytes().to_vec();
    out.extend_from_slice(&pack_vocabs(cols));
    out
}

/// Decode [`pack_shard_dump`] output.
pub fn unpack_shard_dump(buf: &[u8]) -> Result<(u64, Vec<Vec<u32>>)> {
    anyhow::ensure!(buf.len() >= 8, "shard dump truncated: {} bytes", buf.len());
    let rows = u64::from_le_bytes([
        buf[0], buf[1], buf[2], buf[3], buf[4], buf[5], buf[6], buf[7],
    ]);
    Ok((rows, unpack_vocabs(&buf[8..])?))
}

/// Job header: schema, wire format and the full per-column operator
/// spec. The spec crosses the wire in its canonical [`PipelineSpec`]
/// display form and is re-parsed (and therefore re-validated) on the
/// worker — `parse(display(spec)) == spec` is pinned by the spec
/// round-trip property test.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub schema: Schema,
    pub spec: PipelineSpec,
    pub format: WireFormat,
    /// Malformed-row containment the worker decodes under. Quarantine
    /// raw bytes never cross the wire — a worker given the quarantine
    /// policy contains like `skip` and reports the count; the side file
    /// is a single-node (leader-local) artifact.
    pub errors: ErrorConfig,
}

impl Job {
    /// The classic fixed-pipeline job: the paper's DLRM preset at one
    /// uniform vocabulary size (what the old modulus-only header could
    /// express).
    pub fn dlrm(schema: Schema, modulus: Modulus, format: WireFormat) -> Job {
        Job {
            schema,
            spec: PipelineSpec::dlrm(modulus.range),
            format,
            errors: ErrorConfig::default(),
        }
    }

    /// Frame layout: `num_dense:u32 num_sparse:u32 format:u8 policy:u8
    /// budget_tag:u8 budget:f64le detail_cap:u32 spec:utf8` (the spec
    /// takes the rest of the frame — frames are already
    /// length-prefixed).
    pub fn encode(&self) -> Vec<u8> {
        let spec = self.spec.to_string();
        let mut out = Vec::with_capacity(23 + spec.len());
        out.extend_from_slice(&(self.schema.num_dense as u32).to_le_bytes());
        out.extend_from_slice(&(self.schema.num_sparse as u32).to_le_bytes());
        out.push(match self.format {
            WireFormat::Utf8 => 0,
            WireFormat::Binary => 1,
        });
        out.push(self.errors.policy.as_u8());
        let (btag, bval) = self.errors.budget.to_wire();
        out.push(btag);
        out.extend_from_slice(&bval.to_le_bytes());
        out.extend_from_slice(&(self.errors.detail_cap as u32).to_le_bytes());
        out.extend_from_slice(spec.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Job> {
        anyhow::ensure!(buf.len() >= 23, "job frame must be >= 23 bytes, got {}", buf.len());
        let rd = |i: usize| u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
        let format = match buf[8] {
            0 => WireFormat::Utf8,
            1 => WireFormat::Binary,
            v => anyhow::bail!("bad wire format {v}"),
        };
        let policy = ErrorPolicy::from_u8(buf[9])
            .ok_or_else(|| anyhow::anyhow!("bad error policy byte {}", buf[9]))?;
        let bval = f64::from_le_bytes([
            buf[11], buf[12], buf[13], buf[14], buf[15], buf[16], buf[17], buf[18],
        ]);
        let budget = ErrorBudget::from_wire(buf[10], bval)
            .ok_or_else(|| anyhow::anyhow!("bad error budget tag {}", buf[10]))?;
        let detail_cap = rd(19) as usize;
        anyhow::ensure!(detail_cap >= 1, "job error detail cap must be >= 1");
        let spec = std::str::from_utf8(&buf[23..])
            .map_err(|e| anyhow::anyhow!("job spec is not UTF-8: {e}"))?;
        Ok(Job {
            schema: Schema::new(rd(0) as usize, rd(4) as usize),
            spec: PipelineSpec::parse(spec)?,
            format,
            errors: ErrorConfig { policy, budget, detail_cap },
        })
    }
}

/// Pack processed rows for a ResultChunk: per row
/// `label:i32 dense...:f32 sparse...:u32`, all little-endian.
pub fn pack_rows(rows: &[ProcessedRow], schema: Schema) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * schema.binary_row_bytes());
    for r in rows {
        out.extend_from_slice(&r.label.to_le_bytes());
        for &d in &r.dense {
            out.extend_from_slice(&d.to_le_bytes());
        }
        for &s in &r.sparse {
            out.extend_from_slice(&s.to_le_bytes());
        }
    }
    out
}

/// Unpack a ResultChunk.
pub fn unpack_rows(buf: &[u8], schema: Schema) -> Result<Vec<ProcessedRow>> {
    let rb = schema.binary_row_bytes();
    anyhow::ensure!(buf.len() % rb == 0, "result chunk misaligned");
    let mut rows = Vec::with_capacity(buf.len() / rb);
    for chunk in buf.chunks_exact(rb) {
        let w = |i: usize| [chunk[4 * i], chunk[4 * i + 1], chunk[4 * i + 2], chunk[4 * i + 3]];
        let label = i32::from_le_bytes(w(0));
        let dense = (0..schema.num_dense)
            .map(|c| f32::from_le_bytes(w(1 + c)))
            .collect();
        let sparse = (0..schema.num_sparse)
            .map(|c| u32::from_le_bytes(w(1 + schema.num_dense + c)))
            .collect();
        rows.push(ProcessedRow { label, dense, sparse });
    }
    Ok(rows)
}

/// Pack a processed column block straight into the [`pack_rows`] wire
/// layout — same bytes, no intermediate [`ProcessedRow`] materialization
/// (the serving path packs every response, so the per-row allocation of
/// a `row()` round trip would be pure overhead).
pub fn pack_columns(cols: &ProcessedColumns, schema: Schema) -> Vec<u8> {
    let rows = cols.num_rows();
    let mut out = Vec::with_capacity(rows * schema.binary_row_bytes());
    for r in 0..rows {
        out.extend_from_slice(&cols.labels[r].to_le_bytes());
        for col in &cols.dense {
            out.extend_from_slice(&col[r].to_le_bytes());
        }
        for col in &cols.sparse {
            out.extend_from_slice(&col[r].to_le_bytes());
        }
    }
    out
}

/// Stats returned in ResultEnd. The containment counters let the
/// leader merge exact per-worker skip/quarantine totals into the
/// cluster report and verify every row was accounted for (kept,
/// skipped, or quarantined).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RunStats {
    pub rows: u64,
    pub vocab_entries: u64,
    /// Rows dropped under `on_error=skip`.
    pub rows_skipped: u64,
    /// Rows contained under `on_error=quarantine` (counters only — the
    /// raw bytes stay on the node that owns the quarantine file).
    pub rows_quarantined: u64,
    /// Illegal input bytes the decode skipped (zero-policy semantics).
    pub illegal_bytes: u64,
}

impl RunStats {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40);
        out.extend_from_slice(&self.rows.to_le_bytes());
        out.extend_from_slice(&self.vocab_entries.to_le_bytes());
        out.extend_from_slice(&self.rows_skipped.to_le_bytes());
        out.extend_from_slice(&self.rows_quarantined.to_le_bytes());
        out.extend_from_slice(&self.illegal_bytes.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunStats> {
        anyhow::ensure!(buf.len() == 40, "stats frame must be 40 bytes");
        let rd = |i: usize| {
            u64::from_le_bytes([
                buf[i], buf[i + 1], buf[i + 2], buf[i + 3],
                buf[i + 4], buf[i + 5], buf[i + 6], buf[i + 7],
            ])
        };
        Ok(RunStats {
            rows: rd(0),
            vocab_entries: rd(8),
            rows_skipped: rd(16),
            rows_quarantined: rd(24),
            illegal_bytes: rd(32),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::Pass1Chunk, b"hello").unwrap();
        write_frame(&mut buf, Tag::Pass1End, b"").unwrap();
        let mut r = &buf[..];
        let (t1, p1) = read_frame(&mut r).unwrap();
        assert_eq!((t1, p1.as_slice()), (Tag::Pass1Chunk, &b"hello"[..]));
        let (t2, p2) = read_frame(&mut r).unwrap();
        assert_eq!((t2, p2.len()), (Tag::Pass1End, 0));
    }

    #[test]
    fn bad_tag_rejected() {
        // A well-formed frame (correct length + checksum) with an
        // unknown tag must be rejected as Malformed, not panic.
        let mut buf = vec![99u8];
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&frame_sum(99, &[]).to_le_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::Malformed { .. })), "{err:#}");
    }

    #[test]
    fn corrupt_payload_rejected_by_checksum() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::ResultChunk, b"payload-bytes").unwrap();
        for at in 0..buf.len() {
            let mut bad = buf.clone();
            bad[at] ^= 0x40;
            let got = read_frame(&mut &bad[..]);
            // Any single-bit flip in header or payload must surface as
            // an error (usually Malformed; a flipped length bit can
            // also truncate → PeerGone). Never a silent success.
            assert!(got.is_err(), "flip at {at} went undetected");
        }
        // the original still reads fine
        let (tag, payload) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!((tag, payload.as_slice()), (Tag::ResultChunk, &b"payload-bytes"[..]));
    }

    #[test]
    fn io_errors_classified() {
        // EOF mid-frame → PeerGone
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::Pass1Chunk, b"0123456789").unwrap();
        let err = read_frame(&mut &buf[..buf.len() - 3]).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::PeerGone { .. })), "{err:#}");
        // taxonomy: retryability is part of the contract
        assert!(NetError::Timeout { what: "t".into() }.retryable());
        assert!(NetError::PeerGone { what: "p".into() }.retryable());
        assert!(NetError::Overloaded.retryable());
        assert!(!NetError::Malformed { what: "m".into() }.retryable());
        assert!(
            !NetError::JobFailed { worker: "w".into(), reason: "r".into() }.retryable()
        );
    }

    #[test]
    fn shard_dump_roundtrip() {
        let cols = vec![vec![5u32, 1, 9], vec![], vec![42]];
        let packed = pack_shard_dump(123, &cols);
        assert_eq!(unpack_shard_dump(&packed).unwrap(), (123, cols));
        assert!(unpack_shard_dump(&packed[..7]).is_err());
        assert!(unpack_shard_dump(&packed[..packed.len() - 1]).is_err());
    }

    #[test]
    fn job_roundtrip() {
        let job = Job::dlrm(Schema::new(13, 26), Modulus::VOCAB_5K, WireFormat::Binary);
        assert_eq!(Job::decode(&job.encode()).unwrap(), job);
    }

    #[test]
    fn job_roundtrip_heterogeneous_spec() {
        let job = Job {
            schema: Schema::new(13, 26),
            spec: PipelineSpec::parse(
                "sparse[*]: modulus:5000|genvocab|applyvocab; \
                 sparse[0..4]: modulus:100000|genvocab|applyvocab; \
                 dense[*]: neg2zero|log; dense[3]: clip:0:100|bucketize:1:10:100",
            )
            .unwrap(),
            format: WireFormat::Utf8,
            errors: ErrorConfig::default(),
        };
        assert_eq!(Job::decode(&job.encode()).unwrap(), job);
    }

    #[test]
    fn job_decode_rejects_garbage() {
        assert!(Job::decode(&[0u8; 4]).is_err(), "short frame");
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[8] = 9;
        assert!(Job::decode(&bad).is_err(), "bad format byte");
        let mut junk = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        junk.truncate(9);
        junk.extend_from_slice(b"frobnicate");
        assert!(Job::decode(&junk).is_err(), "invalid spec string");
    }

    #[test]
    fn rows_roundtrip() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            ProcessedRow { label: 1, dense: vec![0.5, -2.0], sparse: vec![1, 2, 3] },
            ProcessedRow { label: 0, dense: vec![1.5, 9.0], sparse: vec![4, 5, 6] },
        ];
        let packed = pack_rows(&rows, schema);
        assert_eq!(unpack_rows(&packed, schema).unwrap(), rows);
    }

    #[test]
    fn pack_columns_matches_pack_rows() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            ProcessedRow { label: 1, dense: vec![0.5, -2.0], sparse: vec![1, 2, u32::MAX] },
            ProcessedRow { label: 0, dense: vec![1.5, 9.0], sparse: vec![4, 5, 6] },
        ];
        let mut cols = ProcessedColumns::with_schema(schema);
        for r in &rows {
            cols.push_row(r);
        }
        assert_eq!(pack_columns(&cols, schema), pack_rows(&rows, schema));
    }

    #[test]
    fn vocab_roundtrip_and_hostile_lengths() {
        let cols = vec![vec![5, 1, 9], vec![], vec![42]];
        let packed = pack_vocabs(&cols);
        assert_eq!(unpack_vocabs(&packed).unwrap(), cols);
        // truncation anywhere is an error, never a panic
        for cut in 0..packed.len() {
            assert!(unpack_vocabs(&packed[..cut]).is_err(), "cut at {cut}");
        }
        // a column length far beyond the buffer must fail fast without
        // a giant reservation
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&1u32.to_le_bytes());
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(unpack_vocabs(&hostile).is_err());
        // trailing bytes rejected
        let mut trailing = pack_vocabs(&cols);
        trailing.push(0);
        assert!(unpack_vocabs(&trailing).is_err());
    }

    #[test]
    fn stats_roundtrip() {
        let s = RunStats {
            rows: 123,
            vocab_entries: 456,
            rows_skipped: 7,
            rows_quarantined: 8,
            illegal_bytes: 9,
        };
        assert_eq!(RunStats::decode(&s.encode()).unwrap(), s);
        assert!(RunStats::decode(&s.encode()[..16]).is_err(), "old 16-byte frame rejected");
    }

    #[test]
    fn job_roundtrip_error_config() {
        for (policy, budget) in [
            (ErrorPolicy::Fail, ErrorBudget::Unlimited),
            (ErrorPolicy::Skip, ErrorBudget::Count(42)),
            (ErrorPolicy::Quarantine, ErrorBudget::Rate(0.125)),
        ] {
            let job = Job {
                errors: ErrorConfig { policy, budget, detail_cap: 17 },
                ..Job::dlrm(Schema::new(13, 26), Modulus::VOCAB_5K, WireFormat::Utf8)
            };
            assert_eq!(Job::decode(&job.encode()).unwrap(), job);
        }
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[9] = 77;
        assert!(Job::decode(&bad).is_err(), "bad policy byte");
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[10] = 77;
        assert!(Job::decode(&bad).is_err(), "bad budget tag");
    }

    #[test]
    fn frame_cap_enforced() {
        let mut buf = vec![Tag::Job as u8];
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]);
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::Malformed { .. })), "{err:#}");
    }
}
